"""Paper Fig. 2 analog: peak-memory breakdown across GPT-2 sizes x batch.

PyTorch Memory Profiler -> ``compiled.memory_analysis()`` on the train step
(DESIGN.md Section 3).  Run on the host device (1 CPU): absolute bytes are
exact for the program; the paper's observation to verify is that ACTIVATIONS
(temp) dominate as batch grows, so quantizing gradients saves no peak memory
while quantizing weights/activations does.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from repro.configs.base import ArchConfig
from repro.core import paper_recipe
from repro.models import build_model
from repro.models.model_api import train_batch_specs
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig
from repro.train.step import init_train_state, make_train_step

# reduced GPT-2 family (small/medium/large ratios preserved; CPU-compilable)
GPT2_SIZES = {
    "gpt2-small-r": dict(n_layers=4, d_model=256, n_heads=4, d_ff=1024),
    "gpt2-medium-r": dict(n_layers=6, d_model=384, n_heads=6, d_ff=1536),
    "gpt2-large-r": dict(n_layers=9, d_model=512, n_heads=8, d_ff=2048),
}


def _cfg(name: str, sizes: dict) -> ArchConfig:
    return ArchConfig(
        name=name, family="dense", n_kv_heads=sizes["n_heads"],
        vocab_size=50304, act="gelu", mlp_kind="classic", norm="layernorm",
        pos="learned", use_bias=True, tie_embeddings=True, max_seq=1024,
        **sizes)


def measure(cfg: ArchConfig, batch: int, seq: int = 1024) -> dict:
    model = build_model(cfg)
    recipe = paper_recipe()
    opt = OptConfig()
    shape = ShapeConfig("probe", "train", seq, batch)
    state_shapes = jax.eval_shape(
        lambda k: init_train_state(model, k, recipe, opt),
        jax.random.PRNGKey(0))
    specs = train_batch_specs(cfg, shape)
    step = make_train_step(model, recipe, opt)
    lowered = jax.jit(lambda s, b: step(s, b, None)).lower(state_shapes, specs)
    ma = lowered.compile().memory_analysis()
    params = sum(x.size * 4 for x in
                 jax.tree_util.tree_leaves(state_shapes.params))
    return {
        "batch": batch,
        "params_plus_opt_bytes": int(ma.argument_size_in_bytes),
        "activations_and_workspace_bytes": int(ma.temp_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "raw_param_bytes_fp32": int(params),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", default="2,4,8")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "memory_breakdown.json"))
    args = ap.parse_args()
    out = {}
    for name, sizes in GPT2_SIZES.items():
        cfg = _cfg(name, sizes)
        rows = []
        for b in [int(x) for x in args.batches.split(",")]:
            r = measure(cfg, b, args.seq)
            rows.append(r)
            act_frac = r["activations_and_workspace_bytes"] / (
                r["activations_and_workspace_bytes"]
                + r["params_plus_opt_bytes"])
            print(f"{name:16s} batch={b:3d} act+ws="
                  f"{r['activations_and_workspace_bytes']/1e9:6.2f}GB "
                  f"state={r['params_plus_opt_bytes']/1e9:6.2f}GB "
                  f"act_frac={act_frac:.2f}", flush=True)
        out[name] = rows
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
