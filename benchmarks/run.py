"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * op micro-benchmarks (fake-quant granularities, quantized linear fwd/bwd,
    train steps) -- us_per_call on this host;
  * paper-table derived metrics (final valid CE delta vs baseline per
    quantization config) -- from experiments/paper/*.json if present, else
    quick 60-step runs are executed on the spot;
  * Fig 2/3 analogs (activation-memory fraction, linear-layer FLOP share);
  * roofline MFUs per dry-run cell (experiments/dryrun/*.json when present).

Full-fidelity runs:  python -m benchmarks.paper_tables --steps 300
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import LinearCtx, QuantPolicy, paper_recipe, parse_policy
from repro.core.qconfig import Granularity, QuantRecipe, QuantSpec
from repro.core.quantizer import fake_quant_nograd
from repro.core.qlinear import quantized_linear
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step

EXP = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _time(fn, *args, warmup=2, iters=10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived="") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------

def bench_quantizer_ops() -> None:
    """Section 3.1 op costs."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 1024), jnp.float32)
    for gran in Granularity:
        spec = QuantSpec(8, gran)
        f = jax.jit(lambda v, s=spec: fake_quant_nograd(v, s))
        row(f"qdq_8bit_{gran.value}", _time(f, x), "fake-quant 4M elems")
    w = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    r = paper_recipe()
    fwd = jax.jit(lambda a, b: quantized_linear(a, b, r))
    row("qlinear_fwd_w8a8", _time(fwd, x, w), "4096x1024x1024")
    bwd = jax.jit(jax.grad(lambda a, b: jnp.sum(
        quantized_linear(a, b, QuantRecipe(
            weights=QuantSpec(8, Granularity.PER_CHANNEL),
            acts=QuantSpec(8, Granularity.PER_TOKEN),
            grads=QuantSpec(8, Granularity.PER_TOKEN))) ** 2), argnums=1))
    row("qlinear_bwd_w8a8g8", _time(bwd, x, w), "dW path quantized")
    plain = jax.jit(lambda a, b: a @ b)
    row("linear_fp_baseline", _time(plain, x, w), "matmul only")


def bench_kernels() -> None:
    """Pallas kernels (interpret mode on CPU -- TPU is the target; timings
    here validate dispatch overhead only)."""
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 512))
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    f = jax.jit(lambda v: ops.fused_fake_quant(v, spec))
    row("pallas_qdq_row_interp", _time(f, x, iters=3),
        "interpret-mode; TPU target")
    g = jax.jit(lambda a, b: ops.int8_quantized_matmul(a, b))
    row("pallas_int8_matmul_interp", _time(g, x, w, iters=3),
        "interpret-mode; TPU target")


def bench_policy_backends() -> None:
    """QuantPolicy dispatch: fake-quant reference vs real-int8 Pallas on one
    W8A8 linear (interpret mode on CPU -- TPU is the target), plus the
    depth-switch overhead of a layer-banded policy."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2048, 1024))
    w = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    for name, pol in [
            ("policy_fake_quant", QuantPolicy(default=paper_recipe())),
            ("policy_int8_pallas", QuantPolicy(default=paper_recipe(),
                                               backend="int8_pallas"))]:
        f = jax.jit(lambda a, b, p=pol: p.linear(LinearCtx("mlp_up"), a, b))
        row(name, _time(f, x, w, iters=3), "2048x1024x1024 W8A8")
    banded = parse_policy("block[0:2].*=fp,*=w8c+a8t")
    f = jax.jit(lambda a, b, li: banded.linear(
        LinearCtx("mlp_up", layer=li, n_layers=12), a, b))
    row("policy_depth_switch", _time(f, x, w, jnp.int32(6)),
        "lax.switch over 2 depth classes")


def bench_train_steps() -> None:
    """Train-step wall time: fp baseline, global paper recipe, and a
    per-layer policy with fp end-blocks (mini GPT-2)."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    loader = Loader(corpus, cfg, batch_size=8, seq_len=128)
    batch = next(loader)
    for name, recipe in [
            ("fp", None), ("paper_w8a8", paper_recipe()),
            ("policy_banded", parse_policy(
                "block[0:1].*=fp,block[-1:].*=fp,*=w8c+a8t"))]:
        opt = OptConfig(lr=1e-3, total_steps=100)
        state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
        step = jax.jit(make_train_step(model, recipe, opt))
        f = lambda s, b: step(s, b, None)[0].opt.step
        row(f"train_step_{name}", _time(f, state, batch, warmup=1, iters=3),
            "mini gpt2 b8 s128")


def bench_train_throughput() -> None:
    """Training fast paths: fp vs fake-quant vs int8-fwd vs int8-fwd+bwd
    (step time, tokens/s, residual + optimizer-state bytes)."""
    from benchmarks.train_throughput import PATHS, bench_path
    for name, pol in PATHS:
        r = bench_path(name, pol, steps=2, batch=4, seq=64)
        row(f"train::{name}", r["us_per_step"],
            f"tok_s={r['tokens_per_s']:.1f};"
            f"residual_bytes={r['residual_bytes_linear']};"
            f"opt_bytes={r['opt_state_bytes']};"
            f"kernel={r['kernel_path']}")


def table_paper_results() -> None:
    """Tables 2-5 / Figs 9-13 derived metrics (valid-CE delta vs baseline)."""
    from benchmarks.paper_tables import CONFIGS, load_all, run_config
    out_dir = os.path.join(EXP, "paper")
    results = load_all(out_dir)
    need = [n for n in CONFIGS if n not in results]
    quick = [n for n in need if n in (
        "baseline", "w8_per_channel", "w4_per_tensor", "a8_per_token",
        "g8_per_token", "m2_8_per_channel", "w8a8")]
    for n in quick:
        results[n] = run_config(n, CONFIGS[n], steps=60, batch=8, seq=128,
                                lr=3e-3, eval_every=30, out_dir=out_dir)
    base = results.get("baseline", {}).get("final_valid_ce", float("nan"))
    for name, r in sorted(results.items()):
        ce = float("inf") if r["diverged"] else r["final_valid_ce"]
        delta = ce - base if math.isfinite(ce) else float("inf")
        row(f"paper::{name}", float(r.get("wall_s", 0)) * 1e6 /
            max(r.get("steps", 1), 1),
            f"valid_ce={ce:.4f};delta_vs_baseline={delta:+.4f};"
            f"diverged={r['diverged']}")


def table_memory_and_linear_share() -> None:
    """Fig 2 / Fig 3 analogs."""
    from benchmarks.linear_share import flops_split
    from repro.configs import get_config
    for arch in ("gpt2-small", "llama3-8b"):
        cfg = get_config(arch)
        for seq in (256, 1024, 4096, 32768):
            r = flops_split(cfg, seq)
            row(f"linear_share::{arch}::s{seq}", 0.0,
                f"linear_share={r['linear_share']:.3f}")
    path = os.path.join(EXP, "memory_breakdown.json")
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
        for name, rows_ in data.items():
            for r in rows_:
                act = r["activations_and_workspace_bytes"]
                st = r["params_plus_opt_bytes"]
                row(f"memfig::{name}::b{r['batch']}", 0.0,
                    f"activation_fraction={act / (act + st):.3f}")


def bench_opt_update() -> None:
    """Optimizer fast paths: fp vs fake vs int8-loop vs int8-fused AdamW
    (opt_ms, analytic HBM bytes, optimizer-state bytes)."""
    from benchmarks.opt_update import PATHS, bench_path
    for name, recipe_str, storage, fused in PATHS:
        r = bench_path(name, recipe_str, storage, fused, steps=1)
        row(f"opt::{name}", r["us_per_step"],
            f"opt_ms={r['opt_ms']:.2f};"
            f"hbm_bytes={r['hbm_bytes_per_step']};"
            f"opt_bytes={r['opt_state_bytes']};"
            f"path={r['kernel_path']}")


def bench_serve() -> None:
    """Engine serving throughput + KV residency, fp vs int8 policies."""
    from benchmarks.serve_throughput import POLICIES, bench_engine
    for pol in POLICIES:
        r = bench_engine(pol, slots=4, prompt_len=32, new_tokens=16)
        row(f"serve::{pol}", 0.0,
            f"prefill_tok_s={r['prefill_tok_s']:.1f};"
            f"decode_tok_s={r['decode_tok_s']:.1f};"
            f"kv_bytes={r['kv_bytes']};params_bytes={r['params_bytes']};"
            f"kv_read_bytes={r['kv_read_bytes']};path={r['path']}")


def bench_serve_trace() -> None:
    """Poisson-arrival trace through the paged engine's async scheduler:
    end-to-end latency percentiles, tokens/s, and peak live-KV bytes vs the
    dense engine's always-resident cache (emits BENCH_serve_trace.json)."""
    from benchmarks.serve_throughput import bench_serve_trace as trace
    r = trace(smoke=False)
    row("serve_trace::paged", 0.0,
        f"tok_s={r['tokens_per_s']:.1f};"
        f"p50_ms={r['latency_p50_s'] * 1e3:.2f};"
        f"p99_ms={r['latency_p99_s'] * 1e3:.2f};"
        f"live_kv_bytes={r['peak_live_kv_bytes']};"
        f"dense_kv_bytes={r['dense_resident_kv_bytes']};"
        f"parity={r['token_parity_vs_dense']};path={r['path']}")


def bench_serve_aot() -> None:
    """AOT-compiled (and, with multiple devices, mesh-sharded) serving:
    warmup compile cost + trace-free serving throughput (emits
    BENCH_serve.json)."""
    from benchmarks.serve_throughput import bench_aot_smoke
    r = bench_aot_smoke()
    row("serve_aot::kv_cache=a8t,*=w8c", 0.0,
        f"mesh={r['mesh']};n_exec={r['n_executables']};"
        f"compile_s={r['total_compile_s']:.2f};"
        f"decode_tok_s={r['decode_tok_s']:.1f};path={r['path']}")


def bench_resilience() -> None:
    """Fault-injection gates: sentinel skip/rollback/fallback ladder,
    checkpoint rotation fallback + atomic saves, scheduler watchdog and
    request deadlines (emits rows; the CI gate is --smoke)."""
    from benchmarks.resilience import run_all
    run_all(smoke=False)        # prints matching CSV rows itself


def bench_decode_attention() -> None:
    """Decode-attention hot path: fp cache vs int8 dequant-on-read vs the
    fused int8-KV kernel (per-step ms + analytic KV-bytes-read counter;
    interpret mode off-TPU -- dispatch validation, not kernel-speed truth)."""
    from benchmarks.serve_throughput import bench_decode_attn
    for mode in ("fp", "dequant", "fused"):
        r = bench_decode_attn(mode, slots=2, max_seq=64, kv_heads=2,
                              groups=2, head_dim=32, iters=2)
        row(f"decode_attn::{mode}", r["us_per_step"],
            f"decode_attn_ms={r['decode_attn_ms']:.3f};"
            f"kv_read_bytes={r['kv_read_bytes']}")


def table_roofline() -> None:
    """Dry-run roofline MFUs (train cells, single pod)."""
    from benchmarks.roofline import load
    rows_ = load(os.path.join(EXP, "dryrun"))
    for d in rows_:
        if d["status"] != "ok":
            row(f"roofline::{d['arch']}::{d['shape']}", 0.0, d["status"])
            continue
        r = d["roofline"]
        row(f"roofline::{d['arch']}::{d['shape']}", r["step_time_s"] * 1e6,
            f"dominant={r['dominant']};mfu={r.get('roofline_mfu', 0):.4f};"
            f"useful={r.get('useful_flops_ratio', 0):.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_quantizer_ops()
    bench_kernels()
    bench_policy_backends()
    bench_train_steps()
    bench_train_throughput()
    bench_opt_update()
    bench_serve()
    bench_serve_trace()
    bench_serve_aot()
    bench_decode_attention()
    bench_resilience()
    table_paper_results()
    table_memory_and_linear_share()
    table_roofline()


if __name__ == "__main__":
    main()
