"""Paper-table reproduction at reduced scale (DESIGN.md Section 6/7).

One pre-training run per quantization configuration of the paper's controlled
study (Tables 2-5, Figs 9-13), on the mini GPT-2 + deterministic synthetic
corpus.  Absolute OpenWebText perplexities are not reproducible in a CPU
container; the validation targets are the paper's QUALITATIVE orderings
(which configs track the baseline / degrade / diverge).

  PYTHONPATH=src python -m benchmarks.paper_tables --steps 300
  PYTHONPATH=src python -m benchmarks.paper_tables --check   # assert claims

Results are cached per-config in experiments/paper/<name>.json.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.qconfig import Granularity as G
from repro.core.qconfig import QuantRecipe, QuantSpec
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_eval_step, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "paper")

W = lambda b, g: QuantRecipe(weights=QuantSpec(b, g))
A = lambda b, g, sym=True: QuantRecipe(acts=QuantSpec(b, g, symmetric=sym))
GR = lambda b, g: QuantRecipe(grads=QuantSpec(b, g))
M1 = lambda b, g: QuantRecipe(adam_m1=QuantSpec(b, g))

CONFIGS = {
    # paper Table 2 / Fig 4 -- weights
    "baseline": QuantRecipe(),
    "w4_per_tensor": W(4, G.PER_TENSOR),
    "w4_per_channel": W(4, G.PER_CHANNEL),
    "w8_per_tensor": W(8, G.PER_TENSOR),
    "w8_per_channel": W(8, G.PER_CHANNEL),
    # Table 3 / Figs 6-8 -- activations
    "a4_per_tensor": A(4, G.PER_TENSOR),
    "a4_per_token": A(4, G.PER_TOKEN),
    "a4_per_token_asym": A(4, G.PER_TOKEN, sym=False),
    "a4_per_channel": A(4, G.PER_CHANNEL),
    "a8_per_tensor": A(8, G.PER_TENSOR),
    "a8_per_token": A(8, G.PER_TOKEN),
    # Table 4 / Figs 9-10 -- gradients (dW path only, Fig. 1)
    "g4_per_tensor": GR(4, G.PER_TENSOR),
    "g4_per_token": GR(4, G.PER_TOKEN),
    "g8_per_tensor": GR(8, G.PER_TENSOR),
    "g8_per_token": GR(8, G.PER_TOKEN),
    # Fig 10 (top) -- the input-gradient-path instability ablation
    "gdx8_per_token": QuantRecipe(grads_dx=QuantSpec(8, G.PER_TOKEN)),
    "gdx4_per_token": QuantRecipe(grads_dx=QuantSpec(4, G.PER_TOKEN)),
    # Table 5 / Fig 11 -- Adam m1
    "m1_4_per_tensor": M1(4, G.PER_TENSOR),
    "m1_4_per_channel": M1(4, G.PER_CHANNEL),
    "m1_8_per_tensor": M1(8, G.PER_TENSOR),
    "m1_8_per_channel": M1(8, G.PER_CHANNEL),
    # Fig 12 -- Adam m2: the paper's linear scheme vs the beyond-paper codec
    "m2_8_per_channel": QuantRecipe(adam_m2=QuantSpec(8, G.PER_CHANNEL)),
    "m2_8_blockwise_sqrt": QuantRecipe(adam_m2=QuantSpec(
        8, G.PER_CHANNEL, symmetric=False, block_size=128,
        sqrt_domain=True)),
    # Fig 13 / Section 4.5 -- combined
    "w8a8": QuantRecipe(weights=QuantSpec(8, G.PER_CHANNEL),
                        acts=QuantSpec(8, G.PER_TOKEN)),
    "w8a8g8": QuantRecipe(weights=QuantSpec(8, G.PER_CHANNEL),
                          acts=QuantSpec(8, G.PER_TOKEN),
                          grads=QuantSpec(8, G.PER_TOKEN)),
}


def run_config(name: str, recipe: QuantRecipe, steps: int, batch: int,
               seq: int, lr: float, eval_every: int, out_dir: str,
               force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("steps") >= steps:
            return cached

    cfg = get_smoke_config("gpt2-small")          # the paper's model, reduced
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                    total_steps=steps, state_storage="fake")
    state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    eval_step = jax.jit(make_eval_step(model, recipe))
    loader = Loader(corpus, cfg, batch_size=batch, seq_len=seq)
    valid = Loader(corpus, cfg, batch_size=batch, seq_len=seq, split="valid")

    t0 = time.time()
    train_curve, valid_curve = [], []
    diverged = False
    for i in range(steps):
        state, m = step(state, next(loader), jax.random.fold_in(
            jax.random.PRNGKey(0), i))
        ce = float(m["ce"])
        train_curve.append(ce)
        if not math.isfinite(ce) or ce > 30.0:
            diverged = True
            break
        if (i + 1) % eval_every == 0 or i == 0:
            vl = float(np.mean([
                float(eval_step(state.params, valid.peek(step=j))["ce"])
                for j in range(2)]))
            valid_curve.append({"step": i + 1, "ce": vl})

    final_valid = (valid_curve[-1]["ce"] if valid_curve else float("nan"))
    result = {
        "name": name, "recipe": recipe.describe(), "steps": len(train_curve),
        "diverged": diverged,
        "final_train_ce": train_curve[-1] if train_curve else None,
        "final_valid_ce": final_valid,
        "max_train_ce_after_warmup": (max(train_curve[10:])
                                      if len(train_curve) > 10 else None),
        "train_curve_every10": train_curve[::10],
        "valid_curve": valid_curve,
        "wall_s": round(time.time() - t0, 1),
    }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def load_all(out_dir: str) -> dict:
    out = {}
    for name in CONFIGS:
        path = os.path.join(out_dir, f"{name}.json")
        if os.path.exists(path):
            with open(path) as f:
                out[name] = json.load(f)
    return out


def check_claims(results: dict) -> list:
    """The paper's qualitative orderings; returns list of (claim, ok, detail)."""
    def ce(name):
        r = results.get(name)
        if r is None:
            return float("nan")
        if r["diverged"]:
            return float("inf")
        return r["final_valid_ce"]

    base = ce("baseline")
    checks = [
        ("W8 per-channel tracks baseline (Fig 4)",
         ce("w8_per_channel") < base + 0.1,
         f"{ce('w8_per_channel'):.3f} vs {base:.3f}"),
        ("W4 per-channel beats W4 per-tensor (Table 2)",
         ce("w4_per_channel") <= ce("w4_per_tensor") + 0.02,
         f"{ce('w4_per_channel'):.3f} vs {ce('w4_per_tensor'):.3f}"),
        ("W4 worse than W8 (Fig 4)",
         ce("w4_per_tensor") > ce("w8_per_tensor") - 0.02,
         f"{ce('w4_per_tensor'):.3f} vs {ce('w8_per_tensor'):.3f}"),
        ("A8 per-token tracks baseline (Fig 7)",
         ce("a8_per_token") < base + 0.1,
         f"{ce('a8_per_token'):.3f} vs {base:.3f}"),
        ("A4 worse than A8 (Table 3)",
         min(ce("a4_per_token"), ce("a4_per_tensor"))
         > ce("a8_per_token") - 0.02,
         f"a4 {ce('a4_per_token'):.3f}/{ce('a4_per_tensor'):.3f} "
         f"vs a8 {ce('a8_per_token'):.3f}"),
        ("A4 asym improves on A4 sym per-token (Fig 7)",
         ce("a4_per_token_asym") <= ce("a4_per_token") + 0.05,
         f"{ce('a4_per_token_asym'):.3f} vs {ce('a4_per_token'):.3f}"),
        ("G8 per-token converges but trails baseline (Fig 9)",
         math.isfinite(ce("g8_per_token"))
         and ce("g8_per_token") > base - 0.05,
         f"{ce('g8_per_token'):.3f} vs {base:.3f}"),
        ("G4 per-tensor much worse / diverges (Table 4)",
         ce("g4_per_tensor") > base + 0.2 or results.get(
             "g4_per_tensor", {}).get("diverged", False),
         f"{ce('g4_per_tensor'):.3f}"),
        ("Quantizing the dx path is the most unstable (Fig 10)",
         ce("gdx4_per_token") >= ce("g4_per_token") - 0.05,
         f"gdx4 {ce('gdx4_per_token'):.3f} vs g4 {ce('g4_per_token'):.3f}"),
        ("M1 8-bit per-channel tracks baseline (Fig 11)",
         ce("m1_8_per_channel") < base + 0.1,
         f"{ce('m1_8_per_channel'):.3f} vs {base:.3f}"),
        ("M1 4-bit per-channel feasible; per-tensor worst (Table 5)",
         ce("m1_4_per_channel") <= ce("m1_4_per_tensor") + 0.02,
         f"{ce('m1_4_per_channel'):.3f} vs {ce('m1_4_per_tensor'):.3f}"),
        ("M2 linear 8-bit hurts/diverges (Fig 12)",
         ce("m2_8_per_channel") > base + 0.15 or results.get(
             "m2_8_per_channel", {}).get("diverged", False),
         f"{ce('m2_8_per_channel'):.3f} vs {base:.3f}"),
        ("Beyond-paper m2 codec fixes it (Section 2 item 1)",
         ce("m2_8_blockwise_sqrt") < base + 0.1,
         f"{ce('m2_8_blockwise_sqrt'):.3f} vs {base:.3f}"),
        ("W8A8 recipe tracks baseline (Fig 13)",
         ce("w8a8") < base + 0.1, f"{ce('w8a8'):.3f} vs {base:.3f}"),
        ("Adding G8 degrades the combined recipe (Fig 13)",
         ce("w8a8g8") > ce("w8a8") - 0.05,
         f"{ce('w8a8g8'):.3f} vs {ce('w8a8'):.3f}"),
    ]
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--configs", default=None,
                    help="comma-separated subset")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.check:
        results = load_all(args.out)
        ok_all = True
        for claim, ok, detail in check_claims(results):
            print(f"{'PASS' if ok else 'FAIL'}  {claim}  [{detail}]")
            ok_all &= ok
        raise SystemExit(0 if ok_all else 1)

    names = (args.configs.split(",") if args.configs else list(CONFIGS))
    for name in names:
        r = run_config(name, CONFIGS[name], args.steps, args.batch, args.seq,
                       args.lr, args.eval_every, args.out, force=args.force)
        print(f"{name:22s} steps={r['steps']:4d} "
              f"final_valid={r['final_valid_ce']:.4f} "
              f"diverged={r['diverged']} ({r['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
