"""Serving throughput: engine prefill / decode tokens-per-second, KV-cache
residency, and the decode-attention hot path -- fp vs prepared-int8 weights
vs int8 KV, dequant-on-read vs fused kernel (gpt2-small smoke config).

Rows (CSV, matching benchmarks/run.py):

    serve::<policy>::prefill_tok_s   -- prompt tokens/s through admission
    serve::<policy>::decode_tok_s    -- batched decode steps x slots / s
    serve::<policy>::kv_bytes        -- resident decode-state bytes
    serve::<policy>::params_bytes    -- resident (prepared) parameter bytes
    decode_attn::<mode>              -- per-step decode-attention ms + the
                                        analytic KV-bytes-read counter
                                        (fp | dequant | fused)

Usage:
    PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]
        [--decode-smoke] [--aot-smoke] [--trace] [--trace-smoke] [--json]
        [--sweep]

``--smoke`` runs one tiny engine pass and asserts sane output (the CI
serve-smoke gate).  ``--decode-smoke`` is the decode-attention CI gate: it
pins the fused kernel on (interpret mode), asserts fused-vs-dequant logit
parity and that the fused path's analytic KV read is < 1/3 of the
dequant-on-read bytes.  ``--trace`` replays a Poisson-arrival request trace
through the paged engine's async scheduler and reports p50/p99 end-to-end
latency, tokens/s, and peak live-KV bytes vs the dense engine's resident
cache.  ``--trace-smoke`` is its CI gate: same trace, asserting per-request
token parity with a dense engine, finite p99, and peak paged live-token
bytes under half the dense resident bytes; writes ``BENCH_serve_trace.json``.
``--aot-smoke`` is the AOT/sharded serving gate: construct an
ahead-of-time-compiled engine (on a dp x tp2 mesh when the host exposes
multiple devices), then assert zero traces or compiles happen while
serving; writes ``BENCH_serve.json``.  ``--trace-overload-smoke`` is the
overload gate: an open-loop burst submits far past capacity into a
bounded-queue engine and asserts every overflow request is shed (finish
reason ``"shed"`` + retry-after hint, zero ``CapacityError`` escaping the
loop) while the admitted requests keep a finite p99 and positive goodput;
writes ``BENCH_serve_overload.json``.  ``--sweep`` times the fused kernel
across kv tile lengths (the ``REPRO_DECODE_BLOCK`` autotune hook, passed
explicitly so each size retraces).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.infer import Engine, Request, params_nbytes

POLICIES = ("*=fp", "*=w8c", "*=w8c+a8t", "kv_cache=a8t,*=w8c")

SWEEP_BLOCKS = (64, 128, 256, 512)


def build(policy: str, slots: int = 8, max_seq: int = 160):
    from repro.models import build_model
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return Engine(model, params, policy, max_slots=slots, max_seq=max_seq)


def bench_engine(policy: str, *, slots: int = 8, prompt_len: int = 64,
                 new_tokens: int = 32, vocab: int = 256) -> dict:
    eng = build(policy, slots=slots, max_seq=prompt_len + new_tokens + 1)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, vocab, (slots, prompt_len))

    # warmup: compile prefill (full-batch bucket) + decode
    eng.generate(prompts[:slots], 2)

    t0 = time.perf_counter()
    eng.generate(prompts, new_tokens)
    dt = time.perf_counter() - t0
    total_prefill = slots * prompt_len
    total_decode = slots * new_tokens
    # one timed run covers both phases; attribute by a second prefill-only run
    t1 = time.perf_counter()
    eng.generate(prompts, 1)
    dt_prefill = time.perf_counter() - t1
    dt_decode = max(dt - dt_prefill, 1e-9)
    return {
        "prefill_tok_s": total_prefill / max(dt_prefill, 1e-9),
        "decode_tok_s": total_decode / dt_decode,
        "kv_bytes": eng.kv_cache_nbytes(),
        "params_bytes": params_nbytes(eng.params),
        "kv_read_bytes": eng.kv_decode_read_bytes(),
        "path": eng.path_summary(),
    }


# ---------------------------------------------------------------------------
# Decode-attention micro-benchmark: one layer's attention read, three paths
# ---------------------------------------------------------------------------

def _decode_attn_inputs(slots: int, max_seq: int, kv_heads: int, groups: int,
                        head_dim: int, seed: int = 0):
    """Random ragged int8 cache + fp mirror + the step's fresh q/k/v rows
    (the shared fixture from kernels/ref.py, lengths spread over the slots)."""
    from repro.kernels.ref import decode_attn_inputs
    lengths = [(i * 7 + 3) % (max_seq - 1) for i in range(slots)]
    return decode_attn_inputs(slots, max_seq, kv_heads, groups, head_dim,
                              lengths, seed)


def _fp_attend(q, kf, vf, pos):
    s_ = jnp.einsum("bkgh,btkh->bkgt", q, kf,
                    preferred_element_type=jnp.float32)
    s_ = s_ / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    t = jnp.arange(kf.shape[1])
    s_ = jnp.where((t[None, :] <= pos[:, None])[:, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bkgt,btkh->bkgh", p, vf)


def bench_decode_attn(mode: str, *, slots: int = 8, max_seq: int = 512,
                      kv_heads: int = 4, groups: int = 4, head_dim: int = 64,
                      iters: int = 10, block_k=None) -> dict:
    """Per-step decode-attention time + the analytic KV-bytes-read counter
    for one layer.  ``fp`` attends on an fp cache, ``dequant`` dequantizes
    the whole int8 buffer (the reference), ``fused`` runs the Pallas kernel
    (interpret mode off-TPU: dispatch validation, not kernel-speed truth)."""
    from repro.kernels.decode_attn import decode_attention, decode_kv_read_bytes
    from repro.kernels.ref import decode_attn_ref
    q, kq, ks, vq, vs, kf, vf, nk, nv, pos = _decode_attn_inputs(
        slots, max_seq, kv_heads, groups, head_dim)

    if mode == "fp":
        rows = jnp.arange(slots)
        fn = jax.jit(lambda: _fp_attend(q, kf.at[rows, pos].set(nk),
                                        vf.at[rows, pos].set(nv), pos))
    elif mode == "dequant":
        fn = jax.jit(lambda: decode_attn_ref(q, kq, ks, vq, vs,
                                             nk, nv, pos)[0])
    elif mode == "fused":
        fn = jax.jit(lambda: decode_attention(q, kq, ks, vq, vs, nk, nv, pos,
                                              block_k=block_k)[0])
    else:
        raise ValueError(mode)

    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    ms = (time.perf_counter() - t0) / iters * 1e3
    return {
        "decode_attn_ms": ms,
        "us_per_step": ms * 1e3,
        "kv_read_bytes": decode_kv_read_bytes(
            mode, slots, max_seq, kv_heads, head_dim, fp_bytes=4),
    }


def decode_smoke() -> None:
    """CI gate: fused kernel parity vs the dequant oracle (interpret mode)
    plus the memory-roofline claim on the analytic byte counters."""
    from repro.kernels.decode_attn import decode_attention, decode_kv_read_bytes
    from repro.kernels.ref import decode_attn_ref
    q, kq, ks, vq, vs, _, _, nk, nv, pos = _decode_attn_inputs(
        4, 32, 2, 3, 16)
    ref, (rkq, rks, rvq, rvs) = decode_attn_ref(q, kq, ks, vq, vs,
                                                nk, nv, pos)
    out, fkq, fks, fvq, fvs = decode_attention(q, kq, ks, vq, vs, nk, nv,
                                               pos, block_k=8,
                                               interpret=True)
    diff = float(jnp.max(jnp.abs(out - ref)))
    assert diff < 1e-4, f"fused vs dequant logits diverge: {diff}"
    assert jnp.array_equal(fkq, rkq) and jnp.array_equal(fvq, rvq), \
        "fused scatter payload != reference"
    fused = decode_kv_read_bytes("fused", 8, 2048, 8, 128, fp_bytes=2)
    deq = decode_kv_read_bytes("dequant", 8, 2048, 8, 128, fp_bytes=2)
    fp = decode_kv_read_bytes("fp", 8, 2048, 8, 128, fp_bytes=2)
    assert fused * 3 < deq, (fused, deq)
    assert fused < fp, (fused, fp)
    # the engine reports the fused path when it is enabled
    eng = build("kv_cache=a8t,*=w8c", slots=2, max_seq=24)
    assert "int8-fused" in eng.path_summary(), eng.path_summary()
    assert eng.kv_decode_read_bytes() < build("*=fp", slots=2, max_seq=24
                                              ).kv_decode_read_bytes()
    eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=4))
    eng.submit(Request(tokens=[5, 6], max_new_tokens=3))
    out_ = eng.run()
    assert [len(r.tokens) for r in out_] == [4, 3], out_
    print(f"decode-attn smoke ok: max|fused-dequant|={diff:.2e}, "
          f"kv_read fused={fused} dequant={deq} fp={fp}, "
          f"engine path [{eng.path_summary()}]")


def bench_serve_trace(*, n_requests: int = 12, mean_gap_s: float = 0.02,
                      slots: int = 4, max_seq: int = 64, page_size: int = 8,
                      max_new: int = 6, seed: int = 0,
                      policy: str = "kv_cache=a8t,*=w8c",
                      smoke: bool = False,
                      out_path: str = "BENCH_serve_trace.json") -> dict:
    """Poisson-arrival trace through the paged engine's async scheduler.

    ``n_requests`` random prompts arrive with exponential inter-arrival gaps
    (mean ``mean_gap_s``) while the scheduler loop runs in its background
    thread -- admission, chunked prefill, decode, preemption-free page churn
    all overlap with the arrival process.  The same requests run through a
    dense engine synchronously as the memory baseline and the token oracle
    (greedy decode is batch-invariant, so arrival pattern must not change
    one token).

    Reports p50/p99 end-to-end latency, wall-clock generated tokens/s, and
    the peak live-KV bytes the trace ever held vs the dense engine's
    always-resident ``slots x max_seq`` cache.  ``smoke`` asserts the gate
    (parity, finite p99, live < dense/2) and writes ``out_path``."""
    from repro.models import build_model
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    dense = Engine(model, params, policy, max_slots=slots, max_seq=max_seq,
                   seed=0)
    paged = Engine(model, params, policy, max_slots=slots, max_seq=max_seq,
                   seed=0, paged=True, page_size=page_size)

    rng = np.random.RandomState(seed)
    # prompt + max_new stays well under half of max_seq: the trace's mean
    # live occupancy sits near 50% of one slot strip, which is exactly the
    # regime where paging should (must) beat the dense resident cache
    lens = rng.randint(4, 13, n_requests)
    prompts = [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]
    gaps = rng.exponential(mean_gap_s, n_requests)

    dense_ids = [dense.submit(Request(tokens=p, max_new_tokens=max_new))
                 for p in prompts]
    oracle = {i: r.tokens for i, r in
              zip(dense_ids, sorted(dense.run(),
                                    key=lambda r: r.request_id))}

    sched = paged.scheduler
    sched.start()
    t0 = time.monotonic()
    ids = []
    try:
        for p, g in zip(prompts, gaps):
            time.sleep(float(g))
            ids.append(paged.submit(Request(tokens=p,
                                            max_new_tokens=max_new)))
        sched.wait(ids, timeout=600)
    finally:
        sched.stop()
    wall_s = time.monotonic() - t0
    responses = {rid: sched.result(rid) for rid in ids}

    stats = sched.latency_stats()
    gen_tokens = sum(len(r.tokens) for r in responses.values())
    parity = all(responses[rid].tokens == oracle[did]
                 for rid, did in zip(ids, dense_ids))
    result = {
        "n_requests": n_requests,
        "mean_gap_s": mean_gap_s,
        "generated_tokens": gen_tokens,
        "wall_s": wall_s,
        "tokens_per_s": gen_tokens / max(wall_s, 1e-9),
        "latency_p50_s": stats["p50_s"],
        "latency_p99_s": stats["p99_s"],
        "latency_mean_s": stats["mean_s"],
        "peak_live_kv_bytes": sched.peak_live_bytes,
        "dense_resident_kv_bytes": dense.kv_cache_nbytes(),
        "live_over_dense": (sched.peak_live_bytes
                            / max(dense.kv_cache_nbytes(), 1)),
        "token_parity_vs_dense": parity,
        "scheduler_steps": sched.steps,
        "path": paged.path_summary(),
    }
    if smoke:
        assert parity, "paged trace tokens diverge from the dense engine"
        assert np.isfinite(stats["p99_s"]), stats
        assert result["peak_live_kv_bytes"] * 2 \
            < result["dense_resident_kv_bytes"], (
            "paged live-KV bytes not under half the dense resident cache: "
            f"{result['peak_live_kv_bytes']} vs "
            f"{result['dense_resident_kv_bytes']}")
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"serve trace smoke ok: {gen_tokens} tokens, "
              f"p50={stats['p50_s'] * 1e3:.1f}ms "
              f"p99={stats['p99_s'] * 1e3:.1f}ms, "
              f"live/dense={result['live_over_dense']:.3f}, "
              f"path [{result['path']}] -> {out_path}")
    return result


def bench_overload_trace(*, n_requests: int = 24, slots: int = 2,
                         max_queue: int = 6, max_seq: int = 64,
                         page_size: int = 8, max_new: int = 8,
                         timeout_s: float = 60.0, seed: int = 0,
                         policy: str = "kv_cache=a8t,*=w8c",
                         smoke: bool = False,
                         out_path: str = "BENCH_serve_overload.json") -> dict:
    """Open-loop overload: submit ``n_requests`` back-to-back (no pacing,
    no client backpressure) into a ``slots``-slot paged engine whose
    scheduler caps the submit queue at ``max_queue``.

    Past capacity the bounded queue sheds at submit time (finish reason
    ``"shed"`` with a retry-after hint) and the deadline sweep sheds queued
    requests that can no longer make their deadline -- so the admitted
    work keeps flowing: the gate asserts every request got exactly one
    outcome (completed / shed / timeout -- no ``CapacityError`` ever
    escapes the loop), that overload actually occurred (shed > 0) while
    goodput stayed positive, and that the completed requests' p99 stayed
    finite and bounded.  ``smoke`` asserts and writes ``out_path``."""
    from repro.models import build_model
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, policy, max_slots=slots, max_seq=max_seq,
                 seed=0, paged=True, page_size=page_size,
                 max_queue=max_queue)

    rng = np.random.RandomState(seed)
    lens = rng.randint(4, 13, n_requests)
    prompts = [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]

    # compile outside the timed burst (prefill buckets + decode); these two
    # warmup requests show up in the scheduler's outcome counters too
    n_warm = 2
    eng.generate(np.asarray([prompts[0][:4], prompts[1][:4]]), 2)

    sched = eng.scheduler
    sched.start()
    t0 = time.monotonic()
    ids = []
    try:
        for p in prompts:
            ids.append(eng.submit(Request(tokens=p, max_new_tokens=max_new,
                                          timeout_s=timeout_s)))
        sched.wait(ids, timeout=600)
    finally:
        sched.stop()                     # raises if the loop thread died
    wall_s = time.monotonic() - t0
    responses = {rid: sched.result(rid) for rid in ids}

    stats = sched.latency_stats()
    shed = [r for r in responses.values() if r.finish_reason == "shed"]
    done = [r for r in responses.values()
            if r.finish_reason in ("eos", "length")]
    result = {
        "n_requests": n_requests,
        "max_queue": max_queue,
        "slots": slots,
        "wall_s": wall_s,
        "completed": stats["completed"],
        "shed": stats["shed"],
        "timeout": stats["timeout"],
        "peak_queue_depth": stats["peak_queue_depth"],
        "goodput_tok_s": stats["goodput_tok_s"],
        "latency_p50_s": stats["p50_s"],
        "latency_p99_s": stats["p99_s"],
        "retry_after_s": [r.retry_after_s for r in shed[:3]],
        "path": eng.path_summary(),
    }
    if smoke:
        outcomes = stats["completed"] + stats["shed"] + stats["timeout"]
        assert outcomes == n_requests + n_warm, (stats, n_requests, n_warm)
        assert len(shed) > 0, "burst never overloaded the bounded queue"
        assert len(done) >= 1, stats
        assert all(r.retry_after_s is not None and r.retry_after_s > 0
                   for r in shed), "shed response missing retry-after hint"
        assert np.isfinite(stats["p99_s"]) and stats["p99_s"] < 120, stats
        assert stats["goodput_tok_s"] > 0, stats
        assert sched._loop_error is None, sched._loop_error
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"serve overload smoke ok: {result['completed']} completed / "
              f"{result['shed']} shed / {result['timeout']} timeout of "
              f"{n_requests + n_warm}, p99={stats['p99_s'] * 1e3:.1f}ms, "
              f"goodput={result['goodput_tok_s']:.1f} tok/s, "
              f"peak_depth={result['peak_queue_depth']} -> {out_path}")
    return result


def bench_aot_smoke(*, slots: int = 4, max_seq: int = 64,
                    prompt_len: int = 12, new_tokens: int = 8,
                    policy: str = "kv_cache=a8t,*=w8c",
                    out_path: str = "BENCH_serve.json") -> dict:
    """AOT serving gate: construct the engine ahead-of-time compiled (on a
    dp x tp2 mesh when the host exposes >= 2 devices, else single-device),
    assert the warmup report accounts for every executable, serve a batch,
    and assert *nothing* compiled or retraced during serving -- then write
    ``out_path`` with the compile/report/throughput numbers.

    CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    so the mesh branch is the one the gate actually exercises."""
    from repro.models import build_model
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    mesh = None
    n_dev = jax.device_count()
    if n_dev >= 2:
        from jax.sharding import Mesh
        tp = 2
        dp = n_dev // tp
        mesh = Mesh(np.asarray(jax.devices()[:dp * tp]).reshape(dp, tp),
                    ("data", "model"))
    t0 = time.perf_counter()
    eng = Engine(model, params, policy, max_slots=slots, max_seq=max_seq,
                 prefill_bucket=16, mesh=mesh, aot=True)
    construct_s = time.perf_counter() - t0

    rep = eng.warmup_report()
    names = [e["name"] for e in rep["executables"]]
    assert "decode" in names and rep["n_executables"] >= 2, rep
    traces = dict(eng._trace_counts)
    n_exec = rep["n_executables"]

    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (slots, prompt_len))
    t1 = time.perf_counter()
    out = eng.generate(prompts, new_tokens)
    serve_s = time.perf_counter() - t1
    assert out.shape == (slots, new_tokens), out.shape

    # the gate: serving an AOT engine never traces or compiles
    assert eng._trace_counts == traces, (traces, eng._trace_counts)
    assert eng.warmup_report()["n_executables"] == n_exec, \
        "serving compiled a new executable past warmup"

    # the CPU backend's compiled executables expose no generated-code size
    # (memory_analysis reports 0) -- report n/a rather than a misleading 0;
    # on a real TPU this is the per-core program size and should be nonzero
    code_bytes = int(rep["total_code_bytes"])
    result = {
        "devices": n_dev,
        "mesh": (f"dp{mesh.devices.shape[0]}xtp{mesh.devices.shape[1]}"
                 if mesh is not None else None),
        "policy": policy,
        "n_executables": rep["n_executables"],
        "executables": names,
        "total_compile_s": rep["total_compile_s"],
        "total_code_bytes": code_bytes if code_bytes else "n/a",
        "code_bytes_note": (None if code_bytes else
                            "backend reports no generated-code size "
                            "(expected on CPU; nonzero on real TPU)"),
        "construct_s": construct_s,
        "serve_s": serve_s,
        "decode_tok_s": slots * new_tokens / max(serve_s, 1e-9),
        "path": eng.path_summary(),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"serve aot smoke ok: {result['n_executables']} executables "
          f"compiled in {result['total_compile_s']:.2f}s "
          f"(mesh={result['mesh']}), zero traces/compiles while serving, "
          f"path [{result['path']}] -> {out_path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny engine pass + sanity assertions (CI gate)")
    ap.add_argument("--decode-smoke", action="store_true",
                    help="fused decode-attention parity + KV-bytes gate (CI)")
    ap.add_argument("--trace", action="store_true",
                    help="Poisson-arrival trace through the paged async "
                         "scheduler: latency percentiles + live-KV memory")
    ap.add_argument("--trace-smoke", action="store_true",
                    help="trace gate (CI): token parity vs dense, finite "
                         "p99, live bytes < dense/2; writes "
                         "BENCH_serve_trace.json")
    ap.add_argument("--aot-smoke", action="store_true",
                    help="AOT/sharded serving gate (CI): warmup report "
                         "complete, zero traces or compiles while serving; "
                         "writes BENCH_serve.json")
    ap.add_argument("--trace-overload", action="store_true",
                    help="open-loop burst past capacity: shed/goodput/"
                         "latency report for a bounded-queue engine")
    ap.add_argument("--trace-overload-smoke", action="store_true",
                    help="overload gate (CI): every request completed or "
                         "shed (zero CapacityError), finite p99, positive "
                         "goodput; writes BENCH_serve_overload.json")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON object instead of CSV rows")
    ap.add_argument("--sweep", action="store_true",
                    help="fused-kernel timing across kv tile lengths "
                         "(REPRO_DECODE_BLOCK values)")
    args = ap.parse_args()

    if args.decode_smoke:
        import os
        os.environ.setdefault("REPRO_FUSED_DECODE", "1")
        decode_smoke()
        return

    if args.aot_smoke:
        import os
        os.environ.setdefault("REPRO_FUSED_DECODE", "1")
        r = bench_aot_smoke()
        if args.json:
            print(json.dumps(r, indent=2))
        return

    if args.smoke:
        eng = build("kv_cache=a8t,*=w8c", slots=2, max_seq=32)
        eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=6))
        eng.submit(Request(tokens=[5, 6], max_new_tokens=4))
        out = eng.run()
        assert len(out) == 2 and [len(r.tokens) for r in out] == [6, 4], out
        fp = build("*=fp", slots=2, max_seq=32)
        assert eng.kv_cache_nbytes() < fp.kv_cache_nbytes(), "int8 KV not smaller"
        assert params_nbytes(eng.params) < params_nbytes(fp.params), \
            "prepared weights not smaller"
        print("serve smoke ok:", [(r.request_id, r.finish_reason) for r in out],
              f"kv {eng.kv_cache_nbytes()}B vs fp {fp.kv_cache_nbytes()}B,",
              f"path [{eng.path_summary()}]")
        return

    if args.trace_overload or args.trace_overload_smoke:
        r = bench_overload_trace(smoke=args.trace_overload_smoke)
        if args.json:
            print(json.dumps(r, indent=2))
        elif not args.trace_overload_smoke:
            print("name,us_per_call,derived")
            print(f"serve_overload::completed,0.0,{r['completed']}")
            print(f"serve_overload::shed,0.0,{r['shed']}")
            print(f"serve_overload::goodput_tok_s,0.0,"
                  f"{r['goodput_tok_s']:.1f}")
            print(f"serve_overload::p99_ms,0.0,{r['latency_p99_s'] * 1e3:.2f}")
            print(f"serve_overload::peak_depth,0.0,{r['peak_queue_depth']}")
        return

    if args.trace or args.trace_smoke:
        r = bench_serve_trace(smoke=args.trace_smoke)
        if args.json:
            print(json.dumps(r, indent=2))
        elif not args.trace_smoke:
            print("name,us_per_call,derived")
            print(f"serve_trace::tok_s,0.0,{r['tokens_per_s']:.1f}")
            print(f"serve_trace::p50_ms,0.0,{r['latency_p50_s'] * 1e3:.2f}")
            print(f"serve_trace::p99_ms,0.0,{r['latency_p99_s'] * 1e3:.2f}")
            print(f"serve_trace::live_kv_bytes,0.0,{r['peak_live_kv_bytes']}")
            print("serve_trace::dense_kv_bytes,0.0,"
                  f"{r['dense_resident_kv_bytes']}")
        return

    if args.sweep:
        rows = []
        for blk in SWEEP_BLOCKS:
            r = bench_decode_attn("fused", block_k=blk, iters=3)
            rows.append({"block_k": blk, **r})
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            print("name,us_per_call,derived")
            for r in rows:
                print(f"decode_attn::fused::b{r['block_k']},"
                      f"{r['us_per_step']:.1f},"
                      f"kv_read_bytes={r['kv_read_bytes']}")
        return

    results = {}
    for pol in POLICIES:
        results[pol] = bench_engine(pol)
    attn = {mode: bench_decode_attn(mode, iters=3)
            for mode in ("fp", "dequant", "fused")}
    if args.json:
        print(json.dumps({"engine": results, "decode_attn": attn}, indent=2))
        return
    print("name,us_per_call,derived")
    for pol, r in results.items():
        print(f"serve::{pol}::prefill_tok_s,0.0,{r['prefill_tok_s']:.1f}")
        print(f"serve::{pol}::decode_tok_s,0.0,{r['decode_tok_s']:.1f}")
        print(f"serve::{pol}::kv_bytes,0.0,{r['kv_bytes']}")
        print(f"serve::{pol}::params_bytes,0.0,{r['params_bytes']}")
        print(f"serve::{pol}::kv_read_bytes,0.0,{r['kv_read_bytes']}")
    for mode, r in attn.items():
        print(f"decode_attn::{mode},{r['us_per_step']:.1f},"
              f"decode_attn_ms={r['decode_attn_ms']:.3f};"
              f"kv_read_bytes={r['kv_read_bytes']}")


if __name__ == "__main__":
    main()
