"""Serving throughput: engine prefill / decode tokens-per-second and KV-cache
residency, fp vs prepared-int8 weights vs int8 KV (gpt2-small smoke config).

Rows (CSV, matching benchmarks/run.py):

    serve::<policy>::prefill_tok_s   -- prompt tokens/s through admission
    serve::<policy>::decode_tok_s    -- batched decode steps x slots / s
    serve::<policy>::kv_bytes        -- resident decode-state bytes
    serve::<policy>::params_bytes    -- resident (prepared) parameter bytes

Usage:
    PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]

``--smoke`` runs one tiny engine pass and asserts sane output -- the CI
serve-smoke gate.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.infer import Engine, Request, params_nbytes

POLICIES = ("*=fp", "*=w8c", "*=w8c+a8t", "kv_cache=a8t,*=w8c")


def build(policy: str, slots: int = 8, max_seq: int = 160):
    from repro.models import build_model
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return Engine(model, params, policy, max_slots=slots, max_seq=max_seq)


def bench_engine(policy: str, *, slots: int = 8, prompt_len: int = 64,
                 new_tokens: int = 32, vocab: int = 256) -> dict:
    eng = build(policy, slots=slots, max_seq=prompt_len + new_tokens + 1)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, vocab, (slots, prompt_len))

    # warmup: compile prefill (full-batch bucket) + decode
    eng.generate(prompts[:slots], 2)

    t0 = time.perf_counter()
    eng.generate(prompts, new_tokens)
    dt = time.perf_counter() - t0
    total_prefill = slots * prompt_len
    total_decode = slots * new_tokens
    # one timed run covers both phases; attribute by a second prefill-only run
    t1 = time.perf_counter()
    eng.generate(prompts, 1)
    dt_prefill = time.perf_counter() - t1
    dt_decode = max(dt - dt_prefill, 1e-9)
    return {
        "prefill_tok_s": total_prefill / max(dt_prefill, 1e-9),
        "decode_tok_s": total_decode / dt_decode,
        "kv_bytes": eng.kv_cache_nbytes(),
        "params_bytes": params_nbytes(eng.params),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny engine pass + sanity assertions (CI gate)")
    args = ap.parse_args()

    if args.smoke:
        eng = build("kv_cache=a8t,*=w8c", slots=2, max_seq=32)
        eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=6))
        eng.submit(Request(tokens=[5, 6], max_new_tokens=4))
        out = eng.run()
        assert len(out) == 2 and [len(r.tokens) for r in out] == [6, 4], out
        fp = build("*=fp", slots=2, max_seq=32)
        assert eng.kv_cache_nbytes() < fp.kv_cache_nbytes(), "int8 KV not smaller"
        assert params_nbytes(eng.params) < params_nbytes(fp.params), \
            "prepared weights not smaller"
        print("serve smoke ok:", [(r.request_id, r.finish_reason) for r in out],
              f"kv {eng.kv_cache_nbytes()}B vs fp {fp.kv_cache_nbytes()}B")
        return

    print("name,us_per_call,derived")
    for pol in POLICIES:
        r = bench_engine(pol)
        print(f"serve::{pol}::prefill_tok_s,0.0,{r['prefill_tok_s']:.1f}")
        print(f"serve::{pol}::decode_tok_s,0.0,{r['decode_tok_s']:.1f}")
        print(f"serve::{pol}::kv_bytes,0.0,{r['kv_bytes']}")
        print(f"serve::{pol}::params_bytes,0.0,{r['params_bytes']}")


if __name__ == "__main__":
    main()
