"""Resilience gate: drive the fault-injection harness through every guard.

Each scenario plants a deterministic fault (``train.faults.FaultPlan``) and
asserts the matching defense absorbed it:

    detect_and_skip    nan_grad mid-run, no checkpoint: the sentinel skips
                       the poisoned updates; params stay finite.
    recovery_ladder    nan_grad with checkpointing + a compiled fallback
                       step: skip -> rollback to the newest checkpoint ->
                       fallback window past the fault -> re-engage; the run
                       finishes with a full complement of applied updates.
    rotation_fallback  the newest checkpoint is corrupted on disk before
                       the rollback needs it: ``restore_latest`` walks the
                       rotation to the previous intact one.
    atomic_save        SIGTERM lands in the payload/commit window of a
                       save: nothing half-written is ever restorable.
    sched_watchdog     the serving scheduler's background thread dies:
                       blocked ``wait()`` callers are woken and re-raise
                       instead of hanging.
    request_timeout    a request past its deadline is cancelled (finish
                       reason ``"timeout"``), its slot freed, the engine
                       immediately reusable.

``--smoke`` runs all scenarios, asserts every gate AND that every planned
fault actually fired, then writes ``BENCH_resilience.json`` (the CI
artifact).  Default (no flag) prints the same CSV rows as benchmarks.run.

Usage:
    PYTHONPATH=src python -m benchmarks.resilience [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import json
import math
import signal
import time

import jax
import jax.numpy as jnp

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Shared smoke-trainer scaffolding
# ---------------------------------------------------------------------------

def _trainer_parts():
    from repro.configs import get_smoke_config
    from repro.core import beyond_paper_recipe
    from repro.data import Loader, SyntheticCorpus
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.train import init_train_state

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = beyond_paper_recipe()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                    state_storage="int")
    loader = Loader(corpus, cfg, batch_size=4, seq_len=32)
    state = init_train_state(model, KEY, recipe, opt)
    return cfg, model, recipe, opt, loader, state


def _guarded_run(fault_spec: str, tmp_dir, *, total_steps: int = 12,
                 ckpt_every: int = 10 ** 9, fallback: bool = False,
                 sentinel_kw=None):
    from repro.checkpoint import CheckpointManager
    from repro.core import fallback_policy
    from repro.train import (FaultPlan, LoopConfig, SentinelConfig,
                             StabilitySentinel, Trainer, make_train_step)

    _, model, recipe, opt, loader, state = _trainer_parts()
    faults = FaultPlan.parse(fault_spec)
    step = jax.jit(make_train_step(model, recipe, opt, faults=faults,
                                   health=True))
    fb = (jax.jit(make_train_step(model, fallback_policy(recipe), opt,
                                  health=True))
          if fallback else None)
    cfg_kw = dict(window=8, min_history=2, skip_limit=1, fallback_steps=4,
                  max_rollbacks=3)
    cfg_kw.update(sentinel_kw or {})
    sentinel = StabilitySentinel(SentinelConfig(**cfg_kw))
    mgr = CheckpointManager(str(tmp_dir)) if ckpt_every < 10 ** 9 else None
    t = Trainer(step, None, state, loader, ckpt=mgr,
                loop_cfg=LoopConfig(total_steps=total_steps,
                                    ckpt_every=ckpt_every, log_every=1),
                sentinel=sentinel, fallback_step=fb, faults=faults)
    hist = t.run(rng=KEY)
    summary = t.resilience_summary()
    summary["final_ce"] = float(hist[-1]["ce"]) if hist else float("nan")
    summary["params_finite"] = all(
        bool(jnp.all(jnp.isfinite(p))) for p in
        jax.tree_util.tree_leaves(t.state.params))
    summary["opt_step"] = int(t.state.opt.step)
    return summary


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------

def scenario_detect_and_skip(tmp_dir) -> dict:
    s = _guarded_run("nan_grad@3", tmp_dir, sentinel_kw={"skip_limit": 99})
    ok = (s["skipped_batches"] >= 1 and s["restores"] == 0
          and s["params_finite"] and math.isfinite(s["final_ce"])
          and "nan_grad@3" in s["faults_fired"])
    return {"ok": ok, "skipped": s["skipped_batches"],
            "final_ce": s["final_ce"]}


def scenario_recovery_ladder(tmp_dir) -> dict:
    s = _guarded_run("nan_grad@5", tmp_dir, ckpt_every=3, fallback=True)
    sent = s["sentinel"]
    ok = (sent["rollbacks"] >= 1 and s["restores"] >= 1
          and sent["fallback_steps_run"] >= 1 and s["params_finite"]
          and s["opt_step"] == 12 and math.isfinite(s["final_ce"])
          and "nan_grad@5" in s["faults_fired"])
    return {"ok": ok, "rollbacks": sent["rollbacks"],
            "restores": s["restores"], "skipped": s["skipped_batches"],
            "fallback_steps": sent["fallback_steps_run"],
            "opt_step": s["opt_step"], "final_ce": s["final_ce"]}


def scenario_rotation_fallback(tmp_dir) -> dict:
    # the 2nd completed save (the newest at rollback time) is corrupted on
    # disk; restore_latest must fall back to the older intact checkpoint
    s = _guarded_run("nan_grad@5;corrupt_ckpt@2:mode=flip", tmp_dir,
                     ckpt_every=2, fallback=True)
    sent = s["sentinel"]
    ok = (sent["rollbacks"] >= 1 and s["restores"] >= 1
          and s["params_finite"] and math.isfinite(s["final_ce"])
          and set(s["faults_fired"]) >= {"nan_grad@5",
                                         "corrupt_ckpt@2:mode=flip"})
    return {"ok": ok, "rollbacks": sent["rollbacks"],
            "restores": s["restores"], "final_ce": s["final_ce"]}


def scenario_atomic_save(tmp_dir) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.train import FaultPlan

    plan = FaultPlan.parse("sigterm_save@1")
    mgr = CheckpointManager(str(tmp_dir))
    plan.install(mgr)
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    aborted = False

    def raise_term(signum, frame):
        raise RuntimeError("SIGTERM")

    old = signal.signal(signal.SIGTERM, raise_term)
    try:
        try:
            mgr.save(1, tree)
        except RuntimeError:
            aborted = True
    finally:
        signal.signal(signal.SIGTERM, old)
    none_after_abort = mgr.all_steps() == []
    mgr.save(2, tree)                            # the fault is one-shot
    mgr.restore(2, jax.tree_util.tree_map(jnp.zeros_like, tree))
    ok = (aborted and none_after_abort and mgr.all_steps() == [2]
          and plan.fired == ["sigterm_save@1"])
    return {"ok": ok, "aborted": aborted,
            "none_after_abort": none_after_abort}


def _engine(max_slots=1, max_seq=256):
    from repro.configs import get_smoke_config
    from repro.infer import Engine
    from repro.models import build_model

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    return Engine(model, params, max_slots=max_slots, max_seq=max_seq)


def scenario_sched_watchdog() -> dict:
    from repro.infer import Request
    from repro.train import FaultInjected, FaultPlan

    eng = _engine(max_slots=2, max_seq=64)
    sched = eng.scheduler
    plan = FaultPlan.parse("dead_sched@2")
    sched.fault_hook = plan.scheduler_hook()
    sched.start()
    rid = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=50))
    t0 = time.monotonic()
    wait_raised = stop_raised = False
    try:
        sched.wait([rid], timeout=60)
    except FaultInjected:
        wait_raised = True
    try:
        sched.stop()
    except FaultInjected:
        stop_raised = True
    woke_s = time.monotonic() - t0
    ok = (wait_raised and stop_raised and woke_s < 60
          and plan.fired == ["dead_sched@2"])
    return {"ok": ok, "wait_raised": wait_raised, "stop_raised": stop_raised,
            "woke_s": woke_s}


def scenario_request_timeout() -> dict:
    from repro.infer import Request

    eng = _engine(max_slots=1, max_seq=256)
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=200,
                       timeout_s=0.01))
    [r] = eng.run()
    timed_out = r.finish_reason == "timeout" and len(r.tokens) < 200
    slot_freed = not eng._running and len(eng._free) == 1
    # the engine stays serviceable after the cancel
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=3))
    [r2] = eng.run()
    reusable = r2.finish_reason == "length" and len(r2.tokens) == 3
    ok = timed_out and slot_freed and reusable
    return {"ok": ok, "finish_reason": r.finish_reason,
            "partial_tokens": len(r.tokens), "slot_freed": slot_freed,
            "reusable": reusable}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def run_all(out_path: str = "BENCH_resilience.json", smoke: bool = False,
            emit_json: bool = False) -> dict:
    import tempfile

    results = {}
    t_all = time.monotonic()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as d3, \
            tempfile.TemporaryDirectory() as d4:
        for name, fn in (
                ("detect_and_skip", lambda: scenario_detect_and_skip(d1)),
                ("recovery_ladder", lambda: scenario_recovery_ladder(d2)),
                ("rotation_fallback", lambda: scenario_rotation_fallback(d3)),
                ("atomic_save", lambda: scenario_atomic_save(d4)),
                ("sched_watchdog", scenario_sched_watchdog),
                ("request_timeout", scenario_request_timeout)):
            t0 = time.monotonic()
            r = fn()
            r["wall_s"] = round(time.monotonic() - t0, 2)
            results[name] = r
            if not emit_json:
                print(f"resilience::{name},0.0,"
                      + ";".join(f"{k}={v}" for k, v in r.items()),
                      flush=True)
    results["total_wall_s"] = round(time.monotonic() - t_all, 2)
    if smoke:
        failed = [n for n, r in results.items()
                  if isinstance(r, dict) and not r.get("ok")]
        assert not failed, f"resilience scenarios failed: {failed}"
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"resilience smoke ok: 6 scenarios in "
              f"{results['total_wall_s']:.1f}s -> {out_path}")
    if emit_json:
        print(json.dumps(results, indent=2))
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert every gate; write BENCH_resilience.json")
    ap.add_argument("--json", action="store_true",
                    help="print results as JSON instead of CSV rows")
    args = ap.parse_args()
    run_all(smoke=args.smoke, emit_json=args.json)


if __name__ == "__main__":
    main()
