"""Resilience gate: drive the fault-injection harness through every guard.

Each scenario plants a deterministic fault (``train.faults.FaultPlan``) and
asserts the matching defense absorbed it:

    detect_and_skip    nan_grad mid-run, no checkpoint: the sentinel skips
                       the poisoned updates; params stay finite.
    recovery_ladder    nan_grad with checkpointing + a compiled fallback
                       step: skip -> rollback to the newest checkpoint ->
                       fallback window past the fault -> re-engage; the run
                       finishes with a full complement of applied updates.
    rotation_fallback  the newest checkpoint is corrupted on disk before
                       the rollback needs it: ``restore_latest`` walks the
                       rotation to the previous intact one.
    atomic_save        SIGTERM lands in the payload/commit window of a
                       save: nothing half-written is ever restorable.
    sched_watchdog     the serving scheduler's background thread dies:
                       blocked ``wait()`` callers are woken and re-raise
                       instead of hanging.
    request_timeout    a request past its deadline is cancelled (finish
                       reason ``"timeout"``), its slot freed, the engine
                       immediately reusable.
    overload_shed      six submissions against a one-slot engine with a
                       bounded submit queue: the overflow is rejected with
                       finish reason ``"shed"`` + a retry-after hint, the
                       admitted requests complete, no exception escapes.
    nan_quarantine     a non-finite logits row mid-decode evicts only that
                       request (finish reason ``"numerics"``); its batchmate
                       and the engine are unharmed.
    ladder_walk        a fused-kernel failure then repeated numeric faults
                       walk the engine down its degradation ladder (paged
                       fused -> dequant-on-read -> fp reference) exactly as
                       scripted, then healthy steps re-engage rung by rung
                       back to fused.
    oom_preempt        an injected page-pool drain mid-decode forces
                       preemption instead of CapacityError; every request
                       still completes and the pages come back.

``--smoke`` runs all scenarios, asserts every gate AND that every planned
fault actually fired, then writes ``BENCH_resilience.json`` (the CI
artifact).  Default (no flag) prints the same CSV rows as benchmarks.run.

Usage:
    PYTHONPATH=src python -m benchmarks.resilience [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import json
import math
import signal
import time

import jax
import jax.numpy as jnp

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Shared smoke-trainer scaffolding
# ---------------------------------------------------------------------------

def _trainer_parts():
    from repro.configs import get_smoke_config
    from repro.core import beyond_paper_recipe
    from repro.data import Loader, SyntheticCorpus
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.train import init_train_state

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = beyond_paper_recipe()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                    state_storage="int")
    loader = Loader(corpus, cfg, batch_size=4, seq_len=32)
    state = init_train_state(model, KEY, recipe, opt)
    return cfg, model, recipe, opt, loader, state


def _guarded_run(fault_spec: str, tmp_dir, *, total_steps: int = 12,
                 ckpt_every: int = 10 ** 9, fallback: bool = False,
                 sentinel_kw=None):
    from repro.checkpoint import CheckpointManager
    from repro.core import fallback_policy
    from repro.train import (FaultPlan, LoopConfig, SentinelConfig,
                             StabilitySentinel, Trainer, make_train_step)

    _, model, recipe, opt, loader, state = _trainer_parts()
    faults = FaultPlan.parse(fault_spec)
    step = jax.jit(make_train_step(model, recipe, opt, faults=faults,
                                   health=True))
    fb = (jax.jit(make_train_step(model, fallback_policy(recipe), opt,
                                  health=True))
          if fallback else None)
    cfg_kw = dict(window=8, min_history=2, skip_limit=1, fallback_steps=4,
                  max_rollbacks=3)
    cfg_kw.update(sentinel_kw or {})
    sentinel = StabilitySentinel(SentinelConfig(**cfg_kw))
    mgr = CheckpointManager(str(tmp_dir)) if ckpt_every < 10 ** 9 else None
    t = Trainer(step, None, state, loader, ckpt=mgr,
                loop_cfg=LoopConfig(total_steps=total_steps,
                                    ckpt_every=ckpt_every, log_every=1),
                sentinel=sentinel, fallback_step=fb, faults=faults)
    hist = t.run(rng=KEY)
    summary = t.resilience_summary()
    summary["final_ce"] = float(hist[-1]["ce"]) if hist else float("nan")
    summary["params_finite"] = all(
        bool(jnp.all(jnp.isfinite(p))) for p in
        jax.tree_util.tree_leaves(t.state.params))
    summary["opt_step"] = int(t.state.opt.step)
    return summary


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------

def scenario_detect_and_skip(tmp_dir) -> dict:
    s = _guarded_run("nan_grad@3", tmp_dir, sentinel_kw={"skip_limit": 99})
    ok = (s["skipped_batches"] >= 1 and s["restores"] == 0
          and s["params_finite"] and math.isfinite(s["final_ce"])
          and "nan_grad@3" in s["faults_fired"])
    return {"ok": ok, "skipped": s["skipped_batches"],
            "final_ce": s["final_ce"]}


def scenario_recovery_ladder(tmp_dir) -> dict:
    s = _guarded_run("nan_grad@5", tmp_dir, ckpt_every=3, fallback=True)
    sent = s["sentinel"]
    ok = (sent["rollbacks"] >= 1 and s["restores"] >= 1
          and sent["fallback_steps_run"] >= 1 and s["params_finite"]
          and s["opt_step"] == 12 and math.isfinite(s["final_ce"])
          and "nan_grad@5" in s["faults_fired"])
    return {"ok": ok, "rollbacks": sent["rollbacks"],
            "restores": s["restores"], "skipped": s["skipped_batches"],
            "fallback_steps": sent["fallback_steps_run"],
            "opt_step": s["opt_step"], "final_ce": s["final_ce"]}


def scenario_rotation_fallback(tmp_dir) -> dict:
    # the 2nd completed save (the newest at rollback time) is corrupted on
    # disk; restore_latest must fall back to the older intact checkpoint
    s = _guarded_run("nan_grad@5;corrupt_ckpt@2:mode=flip", tmp_dir,
                     ckpt_every=2, fallback=True)
    sent = s["sentinel"]
    ok = (sent["rollbacks"] >= 1 and s["restores"] >= 1
          and s["params_finite"] and math.isfinite(s["final_ce"])
          and set(s["faults_fired"]) >= {"nan_grad@5",
                                         "corrupt_ckpt@2:mode=flip"})
    return {"ok": ok, "rollbacks": sent["rollbacks"],
            "restores": s["restores"], "final_ce": s["final_ce"]}


def scenario_atomic_save(tmp_dir) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.train import FaultPlan

    plan = FaultPlan.parse("sigterm_save@1")
    mgr = CheckpointManager(str(tmp_dir))
    plan.install(mgr)
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    aborted = False

    def raise_term(signum, frame):
        raise RuntimeError("SIGTERM")

    old = signal.signal(signal.SIGTERM, raise_term)
    try:
        try:
            mgr.save(1, tree)
        except RuntimeError:
            aborted = True
    finally:
        signal.signal(signal.SIGTERM, old)
    none_after_abort = mgr.all_steps() == []
    mgr.save(2, tree)                            # the fault is one-shot
    mgr.restore(2, jax.tree_util.tree_map(jnp.zeros_like, tree))
    ok = (aborted and none_after_abort and mgr.all_steps() == [2]
          and plan.fired == ["sigterm_save@1"])
    return {"ok": ok, "aborted": aborted,
            "none_after_abort": none_after_abort}


def _engine(max_slots=1, max_seq=256):
    from repro.configs import get_smoke_config
    from repro.infer import Engine
    from repro.models import build_model

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    return Engine(model, params, max_slots=max_slots, max_seq=max_seq)


def scenario_sched_watchdog() -> dict:
    from repro.infer import Request
    from repro.train import FaultInjected, FaultPlan

    eng = _engine(max_slots=2, max_seq=64)
    sched = eng.scheduler
    plan = FaultPlan.parse("dead_sched@2")
    sched.fault_hook = plan.scheduler_hook()
    sched.start()
    rid = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=50))
    t0 = time.monotonic()
    wait_raised = stop_raised = False
    try:
        sched.wait([rid], timeout=60)
    except FaultInjected:
        wait_raised = True
    try:
        sched.stop()
    except FaultInjected:
        stop_raised = True
    woke_s = time.monotonic() - t0
    ok = (wait_raised and stop_raised and woke_s < 60
          and plan.fired == ["dead_sched@2"])
    return {"ok": ok, "wait_raised": wait_raised, "stop_raised": stop_raised,
            "woke_s": woke_s}


def scenario_request_timeout() -> dict:
    from repro.infer import Request

    eng = _engine(max_slots=1, max_seq=256)
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=200,
                       timeout_s=0.01))
    [r] = eng.run()
    timed_out = r.finish_reason == "timeout" and len(r.tokens) < 200
    slot_freed = not eng._running and len(eng._free) == 1
    # the engine stays serviceable after the cancel
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=3))
    [r2] = eng.run()
    reusable = r2.finish_reason == "length" and len(r2.tokens) == 3
    ok = timed_out and slot_freed and reusable
    return {"ok": ok, "finish_reason": r.finish_reason,
            "partial_tokens": len(r.tokens), "slot_freed": slot_freed,
            "reusable": reusable}


def scenario_overload_shed() -> dict:
    from repro.configs import get_smoke_config
    from repro.infer import Engine, Request
    from repro.models import build_model

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    eng = Engine(model, params, max_slots=1, max_seq=64, max_queue=2)
    for _ in range(6):
        eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    rs = eng.run()                       # no CapacityError may escape
    shed = [r for r in rs if r.finish_reason == "shed"]
    done = [r for r in rs if r.finish_reason == "length"]
    stats = eng.scheduler.latency_stats()
    ok = (len(rs) == 6 and len(shed) == 4 and len(done) == 2
          and all(r.retry_after_s is not None and r.retry_after_s > 0
                  for r in shed)
          and all(not r.tokens for r in shed)
          and all(len(r.tokens) == 4 for r in done)
          and stats["shed"] == 4 and stats["completed"] == 2
          and stats["n"] == 2)           # shed excluded from latency pctls
    return {"ok": ok, "shed": len(shed), "completed": len(done),
            "retry_after_s": (shed[0].retry_after_s if shed else None),
            "goodput_tok_s": round(stats["goodput_tok_s"], 1)}


def scenario_nan_quarantine() -> dict:
    from repro.infer import Request
    from repro.train import FaultPlan

    eng = _engine(max_slots=2, max_seq=64)
    plan = FaultPlan.parse("nan_logit@2:slot=0")
    eng.fault_hooks = plan.engine_hooks()
    rid_victim = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=8))
    rid_other = eng.submit(Request(tokens=[4, 5, 6], max_new_tokens=8))
    rs = {r.request_id: r for r in eng.run()}
    victim, other = rs[rid_victim], rs[rid_other]
    quarantined = (victim.finish_reason == "numerics"
                   and 0 < len(victim.tokens) < 8)
    survivor_ok = (other.finish_reason == "length"
                   and len(other.tokens) == 8)
    # the engine keeps serving after the quarantine
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=3))
    [r2] = eng.run()
    reusable = r2.finish_reason == "length" and len(r2.tokens) == 3
    s = eng.resilience_summary()
    ok = (quarantined and survivor_ok and reusable
          and s["quarantined"] == 1 and s["rung_index"] == 0
          and plan.fired == ["nan_logit@2:slot=0"])
    return {"ok": ok, "victim_reason": victim.finish_reason,
            "victim_tokens": len(victim.tokens),
            "survivor_tokens": len(other.tokens), "reusable": reusable}


def scenario_ladder_walk() -> dict:
    import os

    from repro.configs import get_smoke_config
    from repro.infer import Engine, MonitorConfig, Request
    from repro.models import build_model
    from repro.train import FaultPlan

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    old = os.environ.get("REPRO_FUSED_DECODE")
    os.environ["REPRO_FUSED_DECODE"] = "1"
    try:
        eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=2,
                     max_seq=64, paged=True, page_size=8, n_pages=16,
                     monitor=MonitorConfig(reprobe_after=4, numeric_limit=2,
                                           numeric_window=8))
    finally:
        if old is None:
            os.environ.pop("REPRO_FUSED_DECODE", None)
        else:
            os.environ["REPRO_FUSED_DECODE"] = old
    plan = FaultPlan.parse(
        "kernel_error@1;nan_logit@3:slot=1;nan_logit@5:slot=1")
    eng.fault_hooks = plan.engine_hooks()
    rid_a = eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=24))
    eng.submit(Request(tokens=[5, 6], max_new_tokens=16))
    eng.submit(Request(tokens=[7, 8], max_new_tokens=16))
    rs = {r.request_id: r for r in eng.run()}
    s = eng.resilience_summary()
    walk_down = [(d["step"], d["from"], d["to"]) for d in s["demotions"]]
    walk_up = [(p["step"], p["from"], p["to"]) for p in s["promotions"]]
    # the scripted walk, exactly: kernel fault at 1 demotes fused->dequant;
    # two quarantines inside the window demote dequant->fp at 5; 4-step
    # healthy streaks re-engage fp->dequant at 9 and dequant->fused at 13
    ok = (walk_down == [(1, "fused", "dequant"), (5, "dequant", "fp")]
          and walk_up == [(9, "fp", "dequant"), (13, "dequant", "fused")]
          and s["rung"] == "fused" and s["rung_index"] == 0
          and s["kernel_errors"] == 1 and s["quarantined"] == 2
          and rs[rid_a].finish_reason == "length"
          and len(rs[rid_a].tokens) == 24
          and len(plan.fired) == 3)
    return {"ok": ok, "demotions": walk_down, "promotions": walk_up,
            "final_rung": s["rung"], "survivor_tokens": len(rs[rid_a].tokens)}


def scenario_oom_preempt() -> dict:
    from repro.configs import get_smoke_config
    from repro.infer import Engine, Request
    from repro.models import build_model
    from repro.train import FaultPlan

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY)
    eng = Engine(model, params, max_slots=2, max_seq=64, paged=True,
                 page_size=4, n_pages=6)            # 5 allocatable pages
    plan = FaultPlan.parse("oom_pages@1:hold=2")
    eng.fault_hooks = plan.engine_hooks()
    free0 = eng.pool.free_pages
    rids = [eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=12)),
            eng.submit(Request(tokens=[5, 6, 7, 8], max_new_tokens=12))]
    rs = {r.request_id: r for r in eng.run()}       # no CapacityError
    all_done = all(rs[rid].finish_reason == "length"
                   and len(rs[rid].tokens) == 12 for rid in rids)
    pages_back = eng.pool.free_pages == free0
    ok = (all_done and eng.preemptions >= 1 and pages_back
          and plan.fired == ["oom_pages@1:hold=2"])
    return {"ok": ok, "preemptions": eng.preemptions,
            "pages_back": pages_back,
            "tokens": [len(rs[rid].tokens) for rid in rids]}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def run_all(out_path: str = "BENCH_resilience.json", smoke: bool = False,
            emit_json: bool = False) -> dict:
    import tempfile

    results = {}
    t_all = time.monotonic()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as d3, \
            tempfile.TemporaryDirectory() as d4:
        for name, fn in (
                ("detect_and_skip", lambda: scenario_detect_and_skip(d1)),
                ("recovery_ladder", lambda: scenario_recovery_ladder(d2)),
                ("rotation_fallback", lambda: scenario_rotation_fallback(d3)),
                ("atomic_save", lambda: scenario_atomic_save(d4)),
                ("sched_watchdog", scenario_sched_watchdog),
                ("request_timeout", scenario_request_timeout),
                ("overload_shed", scenario_overload_shed),
                ("nan_quarantine", scenario_nan_quarantine),
                ("ladder_walk", scenario_ladder_walk),
                ("oom_preempt", scenario_oom_preempt)):
            t0 = time.monotonic()
            r = fn()
            r["wall_s"] = round(time.monotonic() - t0, 2)
            results[name] = r
            if not emit_json:
                print(f"resilience::{name},0.0,"
                      + ";".join(f"{k}={v}" for k, v in r.items()),
                      flush=True)
    results["total_wall_s"] = round(time.monotonic() - t_all, 2)
    if smoke:
        failed = [n for n, r in results.items()
                  if isinstance(r, dict) and not r.get("ok")]
        assert not failed, f"resilience scenarios failed: {failed}"
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"resilience smoke ok: 10 scenarios in "
              f"{results['total_wall_s']:.1f}s -> {out_path}")
    if emit_json:
        print(json.dumps(results, indent=2))
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert every gate; write BENCH_resilience.json")
    ap.add_argument("--json", action="store_true",
                    help="print results as JSON instead of CSV rows")
    args = ap.parse_args()
    run_all(smoke=args.smoke, emit_json=args.json)


if __name__ == "__main__":
    main()
