"""Paper Fig. 3 analog: fraction of compute in linear layers vs attention.

Nsight kernel profiling -> loop-aware FLOP accounting over the model's own
structure (exact, since we own every matmul).  The paper's claim: linear
layers dominate (>80%) at small sequence lengths; the quadratic attention
term takes over as S grows -- which bounds the speedup available from
quantizing linear layers.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config


def flops_split(cfg, seq: int) -> dict:
    """Per-token forward FLOPs split: linear (quantizable) vs attention
    (score/context matmuls, not weight-bearing)."""
    d, h, k, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    qkv = 2 * d * (h * hd) + 2 * 2 * d * (k * hd)
    out = 2 * (h * hd) * d
    if cfg.mlp_kind == "gated":
        mlp = 3 * 2 * d * ff
    else:
        mlp = 2 * 2 * d * ff
    if cfg.n_experts:
        mlp = cfg.top_k * (3 * 2 * d * ff) + 2 * d * cfg.n_experts
    linear = (qkv + out + mlp) * cfg.n_layers
    # attention: QK^T + PV, causal halves the effective length
    attn = 2 * 2 * (h * hd) * (seq / 2) * cfg.n_layers
    head = 2 * d * cfg.vocab_size
    return {"linear": linear, "attention": attn, "lm_head": head,
            "linear_share": linear / (linear + attn + head)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="gpt2-small,llama3-8b,qwen3-32b")
    ap.add_argument("--seqs", default="256,1024,4096,16384,65536")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "linear_share.json"))
    args = ap.parse_args()
    out = {}
    for arch in args.archs.split(","):
        cfg = get_config(arch)
        rows = []
        for seq in [int(s) for s in args.seqs.split(",")]:
            r = flops_split(cfg, seq)
            r["seq"] = seq
            rows.append(r)
            print(f"{arch:12s} seq={seq:6d} linear_share={r['linear_share']:.3f}")
        out[arch] = rows
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
