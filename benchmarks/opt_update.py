"""Optimizer-update fast paths: one AdamW step over gpt2-small-smoke params
under fp / fake-quant / int8-loop / int8-fused moment storage -- per-step
``opt_ms``, optimizer-state bytes, and an analytic HBM-traffic counter that
prices what each path streams (the fused kernel's whole point is bandwidth:
one read + one write per buffer instead of ~6 round trips over moment-sized
fp32 materializations).

Rows (CSV, matching benchmarks/run.py):

    opt::<path>  us_per_step  opt_ms=..;hbm_bytes=..;opt_bytes=..;path=..

Analytic HBM model (bytes per parameter element, documented not measured --
CPU wall times exercise interpret-mode kernels and only validate dispatch;
TPU is the target):

  * every path pays the global-norm pre-pass read of g (4B);
  * ``fp``    : update reads g/p/m1/m2 and writes p/m1/m2 fp32 (one fused
                elementwise pass): 4+4+4+4 + 4+4+4 = 28B (+4 pre-pass);
  * ``fake``  : fp traffic + one extra fp32 round trip per moment for the
                blockwise qdq (the reshape/pad boundary materializes):
                28 + 2*8 = 44B (+4);
  * ``int8 loop``  : per moment, decode (read int8 1B, write fp32 4B), update
                (read 4B, write 4B), encode (read 4B, write int8 1B) = 18B;
                plus g read 4B and p read+write 8B: 48B (+4) -- the ~6
                moment-sized round trips the motivation names;
  * ``int8 fused`` : one read + one write per buffer: g 4 + p 4+4 + payloads
                1+1 in, 1+1 out = 16B (+4), sidecars 32/block_size.

Usage:
    PYTHONPATH=src python -m benchmarks.opt_update [--steps N] [--json PATH]
        [--smoke]

``--smoke`` asserts the fast-path invariants (fused HBM bytes < 1/2 the loop
path, fused-vs-loop parity, int8 state compression) -- the CI gate.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import qadam
from repro.core.qconfig import parse_recipe
from repro.models import build_model
from repro.optim import (OptConfig, adamw_update, init_adam_state,
                         opt_path_desc)

#: Moment recipe: both codecs blockwise (the fused-kernel contract); m2 is
#: the beyond-paper asymmetric sqrt-domain codec that fixes paper Fig. 12.
M_RECIPE = "m1:8c-b128,m2:8c-asym-b128-sqrt"

#: name -> (recipe string or None, state_storage, REPRO_FUSED_ADAM value)
PATHS = (
    ("fp", None, "fake", "0"),
    ("fake", M_RECIPE, "fake", "0"),
    ("int8_loop", M_RECIPE, "int", "0"),
    ("int8_fused", M_RECIPE, "int", "1"),
)


def _tree_bytes(tree) -> int:
    return sum(qadam.state_nbytes(l) for l in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, qadam.QState)))


def analytic_hbm_bytes(path: str, params, recipe) -> int:
    """Bytes streamed to/from HBM for one optimizer step under the model in
    the module docstring.  Non-quantizable leaves (1-D / tiny) always take
    the fp path."""
    per_elem = {"fp": 32.0, "fake": 48.0, "int8_loop": 52.0,
                "int8_fused": 20.0}
    total = 0.0
    bs = recipe.adam_m1.block_size if recipe and recipe.adam_m1 else 0
    for p in jax.tree_util.tree_leaves(params):
        if path != "fp" and qadam.quantizable(p):
            total += per_elem[path] * p.size
            if path == "int8_fused" and bs:
                total += 32.0 * p.size / bs          # scale/zero sidecars
        else:
            total += per_elem["fp"] * p.size
    return int(total)


def bench_path(name: str, recipe_str, storage: str, fused: str, *,
               steps: int = 3, lr: float = 1e-3) -> dict:
    """Time `steps` jitted AdamW updates over the gpt2-small smoke params."""
    recipe = parse_recipe(recipe_str) if recipe_str else None
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    grads = jax.tree_util.tree_map(
        lambda p: 0.01 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape, jnp.float32), params)
    opt_cfg = OptConfig(lr=lr, total_steps=max(steps, 10),
                        state_storage=storage)
    prev = os.environ.get("REPRO_FUSED_ADAM")
    os.environ["REPRO_FUSED_ADAM"] = fused
    try:
        state = init_adam_state(params, recipe, opt_cfg)
        step = jax.jit(lambda p, g, s: adamw_update(p, g, s, opt_cfg, recipe))
        params2, state, stats = step(params, grads, state)   # compile+warmup
        jax.block_until_ready(stats["update_norm"])
        t0 = time.perf_counter()
        for _ in range(steps):
            params2, state, stats = step(params2, grads, state)
        jax.block_until_ready(stats["update_norm"])
        dt = (time.perf_counter() - t0) / steps
        path_desc = opt_path_desc(recipe, opt_cfg)
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED_ADAM", None)
        else:
            os.environ["REPRO_FUSED_ADAM"] = prev
    moments_bytes = _tree_bytes(state.m1) + _tree_bytes(state.m2)
    return {
        "path": name,
        "recipe": recipe_str or "fp",
        "storage": storage,
        "opt_ms": dt * 1e3,
        "us_per_step": dt * 1e6,
        "update_norm": float(stats["update_norm"]),
        "opt_state_bytes": moments_bytes,
        "hbm_bytes_per_step": analytic_hbm_bytes(name, params, recipe),
        "kernel_path": path_desc,
        "final_params": params2,                    # for parity checks
    }


def run(steps: int) -> list:
    rows = [bench_path(name, r, st, f, steps=steps)
            for name, r, st, f in PATHS]
    for r in rows:
        r.pop("final_params")
    return rows


def smoke() -> None:
    """CI gate: fused path halves (at least) the analytic HBM traffic of the
    loop path, tracks it numerically, and int8 states actually compress."""
    rows = {name: bench_path(name, r, st, f, steps=1)
            for name, r, st, f in PATHS}
    loop, fused = rows["int8_loop"], rows["int8_fused"]
    assert fused["hbm_bytes_per_step"] < loop["hbm_bytes_per_step"] / 2, \
        (fused["hbm_bytes_per_step"], loop["hbm_bytes_per_step"])
    assert fused["opt_state_bytes"] == loop["opt_state_bytes"], rows
    assert fused["opt_state_bytes"] < rows["fake"]["opt_state_bytes"] / 3.5, \
        (fused["opt_state_bytes"], rows["fake"]["opt_state_bytes"])
    assert "int8-fused" in fused["kernel_path"], fused["kernel_path"]
    assert "int8-loop" in loop["kernel_path"], loop["kernel_path"]
    for r in rows.values():
        assert np.isfinite(r["update_norm"]) and r["update_norm"] > 0, r
    # fused parity vs the reference loop after 2 steps (<= 1 codec ulp per
    # moment -> param drift bounded well below one lr)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        loop["final_params"], fused["final_params"])
    worst = max(jax.tree_util.tree_leaves(diffs))
    assert worst < 1e-3, worst
    print("opt-update smoke ok:",
          {k: f"{v['hbm_bytes_per_step'] / 1e6:.1f}MB" for k, v in
           rows.items()}, f"parity={worst:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--json", default="",
                    help="also dump the result rows to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="fast-path assertions (CI gate)")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return

    rows = run(args.steps)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"opt::{r['path']},{r['us_per_step']:.1f},"
              f"opt_ms={r['opt_ms']:.2f};"
              f"hbm_bytes={r['hbm_bytes_per_step']};"
              f"opt_bytes={r['opt_state_bytes']};"
              f"path={r['kernel_path']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
