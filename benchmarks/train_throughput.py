"""Training throughput across quantization fast paths: step time, tokens/s,
custom-vjp residual bytes and optimizer-state bytes per policy -- fp baseline
vs fake-quant reference vs int8-forward vs the full int8 fwd+bwd path.

Rows (CSV, matching benchmarks/run.py):

    train::<path>  us_per_step  tok_s=..;residual_bytes=..;opt_bytes=..;kernel=..

Residual bytes are measured on one mlp_up-sized linear (2048 x 768 x 3072 by
default) via ``jax.eval_shape`` of the dispatched custom-vjp forward rule --
the activation-side memory the backward holds live per linear.  Step time is
wall clock on this host (CPU timings exercise interpret-mode kernels and only
validate dispatch; TPU is the target).

Usage:
    PYTHONPATH=src python -m benchmarks.train_throughput [--steps N]
        [--batch B] [--seq S] [--json PATH] [--smoke]

``--smoke`` runs a tiny pass over every path and asserts the fast-path
invariants (int8 residual compression, finite losses) -- the CI gate that
surfaces kernel regressions as step-time/memory deltas.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import as_policy
from repro.core.qlinear import _qlinear_fwd, _qlinear_int8_fwd
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step
from repro.train.step import train_path_summary

#: name -> policy string (None = fp baseline).  The G8 spec is what arms the
#: int8 backward; w8c+a8t alone runs int8 forward over the fake-quant vjp.
PATHS = (
    ("fp", None),
    ("fake_quant", "*=w8c+a8t+g8t"),
    ("int8_fwd", "*=w8c+a8t@int8_pallas"),
    ("int8_fwd_bwd", "*=w8c+a8t+g8t@int8_pallas"),
)


def _tree_bytes(tree) -> int:
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "dtype"))


def residual_bytes(policy, m: int = 2048, k: int = 768, n: int = 3072) -> int:
    """Custom-vjp residual footprint of one (m, k) x (k, n) block linear
    under this policy's effective backend (fp keeps the raw operands; fake
    keeps qdq'd fp copies; int8 keeps payloads + scales)."""
    pol = as_policy(policy)
    backend, _ = pol.effective_backend("mlp_up")
    recipe = pol.resolve("mlp_up").recipe
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    if backend == "fp":
        fwd = lambda xx, ww: (xx @ ww, (xx, ww))
    elif backend == "int8_pallas":
        fwd = lambda xx, ww: _qlinear_int8_fwd(xx, ww, None, recipe)
    else:
        fwd = lambda xx, ww: _qlinear_fwd(xx, ww, None, recipe)
    _, res = jax.eval_shape(fwd, x, w)
    return _tree_bytes(res)


def bench_path(name: str, policy, *, steps: int = 3, batch: int = 8,
               seq: int = 128, lr: float = 1e-3) -> dict:
    """Time `steps` jitted train steps of the gpt2-small smoke config under
    one quantization path; report throughput + memory metrics."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    loader = Loader(corpus, cfg, batch_size=batch, seq_len=seq)
    b = next(loader)
    opt = OptConfig(lr=lr, total_steps=max(steps, 10))
    state = init_train_state(model, jax.random.PRNGKey(0), policy, opt)
    step = jax.jit(make_train_step(model, policy, opt))
    state, m = step(state, b, None)                       # compile + warmup
    jax.block_until_ready(m["ce"])
    t0 = time.perf_counter()
    for _ in range(steps):
        state, m = step(state, b, None)
    jax.block_until_ready(m["ce"])
    dt = (time.perf_counter() - t0) / steps
    return {
        "path": name,
        "policy": "fp" if policy is None else policy,
        "us_per_step": dt * 1e6,
        "tokens_per_s": batch * seq / dt,
        "final_ce": float(m["ce"]),
        "residual_bytes_linear": residual_bytes(policy),
        "opt_state_bytes": _tree_bytes(state.opt),
        "kernel_path": train_path_summary(policy),
    }


def run(steps: int, batch: int, seq: int) -> list:
    return [bench_path(name, pol, steps=steps, batch=batch, seq=seq)
            for name, pol in PATHS]


def smoke() -> None:
    """CI gate: every path trains, and the quantized paths actually compress
    (the fake-quant reference stores int8 QState residuals too now -- both
    compare against the fp path's raw fp32 operands)."""
    rows = run(steps=2, batch=2, seq=32)
    by = {r["path"]: r for r in rows}
    for r in rows:
        assert np.isfinite(r["final_ce"]), r
    assert by["int8_fwd_bwd"]["residual_bytes_linear"] < \
        by["fp"]["residual_bytes_linear"] / 3.5, by
    assert by["fake_quant"]["residual_bytes_linear"] < \
        by["fp"]["residual_bytes_linear"] / 3.5, by
    assert by["int8_fwd"]["residual_bytes_linear"] == \
        by["int8_fwd_bwd"]["residual_bytes_linear"], by
    assert "bwd=int8" in by["int8_fwd_bwd"]["kernel_path"], by
    print("train-throughput smoke ok:",
          {k: f"{v['residual_bytes_linear']}B" for k, v in by.items()})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--json", default="",
                    help="also dump the result rows to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pass + fast-path assertions (CI gate)")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return

    rows = run(args.steps, args.batch, args.seq)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"train::{r['path']},{r['us_per_step']:.1f},"
              f"tok_s={r['tokens_per_s']:.1f};"
              f"residual_bytes={r['residual_bytes_linear']};"
              f"opt_bytes={r['opt_state_bytes']};"
              f"kernel={r['kernel_path']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
