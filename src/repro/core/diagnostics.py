"""Training diagnostics reproducing the paper's analysis figures.

* channel outlier statistics (Fig. 6 / Fig. 8): activations carry persistent
  per-channel outliers that break per-token/per-tensor quantization.
* gradient sparsity (Fig. 10 down): gradients are near-sparse, which makes
  absmax-scaled linear quantization lose most mass to the zero bin.
* m-sharpness (Fig. 5, Foret et al. 2021): quantized pre-training lands in
  sharper minima; measured as the average loss increase under worst-of-n
  random perturbations of radius rho on a batch.
* zero-bin fraction (Fig. 12): how much of a tensor quantizes to exactly 0 --
  the mechanism behind Adam-m2 divergence.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantSpec
from repro.core.quantizer import fake_quant_nograd


def channel_outlier_stats(acts: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-channel outlier profile of an activation tensor (..., channels).

    Returns the channel absmax vector plus summary ratios.  A large
    ``max_over_median`` with a small set of recurring argmax channels is the
    paper's Fig-6 signature.
    """
    flat = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    ch_absmax = jnp.max(jnp.abs(flat), axis=0)
    med = jnp.median(ch_absmax)
    return {
        "channel_absmax": ch_absmax,
        "max_over_median": jnp.max(ch_absmax) / jnp.maximum(med, 1e-9),
        "top_channel": jnp.argmax(ch_absmax),
        "p99_over_p50": (jnp.percentile(ch_absmax, 99.0)
                         / jnp.maximum(med, 1e-9)),
    }


def gradient_sparsity(g: jnp.ndarray, rel_threshold: float = 1e-3) -> jnp.ndarray:
    """Fraction of entries with |g| < rel_threshold * absmax(g) (Fig. 10)."""
    gf = g.astype(jnp.float32)
    thresh = rel_threshold * jnp.max(jnp.abs(gf))
    return jnp.mean((jnp.abs(gf) < thresh).astype(jnp.float32))


def zero_bin_fraction(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Fraction of entries that dequantize to exactly zero (Fig. 12)."""
    q = fake_quant_nograd(x.astype(jnp.float32), spec)
    return jnp.mean((q == 0.0).astype(jnp.float32))


def quant_snr_db(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Signal-to-quantization-noise ratio in dB (higher = better fidelity)."""
    xf = x.astype(jnp.float32)
    err = xf - fake_quant_nograd(xf, spec)
    return 10.0 * jnp.log10(jnp.sum(xf ** 2) /
                            jnp.maximum(jnp.sum(err ** 2), 1e-20))


def m_sharpness(loss_fn: Callable, params, batch, key: jax.Array,
                rho: float = 0.05, n_samples: int = 8) -> jnp.ndarray:
    """m-sharpness (Foret et al. 2021) via worst-of-n random filter-normalized
    perturbations: max_eps<=rho [ L(params + eps) - L(params) ].

    ``loss_fn(params, batch) -> scalar``.  Perturbations are scaled per-leaf by
    the leaf norm (filter normalization, Li et al. 2018) so the radius is
    comparable across layers.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    base = loss_fn(params, batch)

    def one(k):
        ks = jax.random.split(k, len(leaves))
        perturbed = []
        for leaf, lk in zip(leaves, ks):
            noise = jax.random.normal(lk, leaf.shape, dtype=jnp.float32)
            nn = jnp.linalg.norm(noise.reshape(-1)) + 1e-12
            ln = jnp.linalg.norm(leaf.astype(jnp.float32).reshape(-1))
            perturbed.append((leaf + (rho * ln / nn) * noise).astype(leaf.dtype))
        return loss_fn(jax.tree_util.tree_unflatten(treedef, perturbed), batch)

    losses = jax.lax.map(one, jax.random.split(key, n_samples))
    return jnp.max(losses) - base
