"""Training diagnostics reproducing the paper's analysis figures.

* channel outlier statistics (Fig. 6 / Fig. 8): activations carry persistent
  per-channel outliers that break per-token/per-tensor quantization.
* gradient sparsity (Fig. 10 down): gradients are near-sparse, which makes
  absmax-scaled linear quantization lose most mass to the zero bin.
* m-sharpness (Fig. 5, Foret et al. 2021): quantized pre-training lands in
  sharper minima; measured as the average loss increase under worst-of-n
  random perturbations of radius rho on a batch.
* zero-bin fraction (Fig. 12): how much of a tensor quantizes to exactly 0 --
  the mechanism behind Adam-m2 divergence.

Plus the *online* quantization-health counters the training stability
sentinel (``train/sentinel.py``) watches every step:

* int8 saturation rate against a *stored* scale sidecar
  (:func:`saturation_rate`): the overflow guard -- when incoming values
  outgrow the codec scale learned from previous steps, payloads pin at the
  grid edge and the quantized path silently loses magnitude information;
* relative quantization error (:func:`relative_quant_error`): per-step drift
  of the injected error -- a jump means the tensor's distribution left the
  regime the spec's granularity can represent;
* gradient-vs-moment saturation (:func:`moment_saturation_rate`): fraction
  of gradient blocks whose absmax exceeds what the stored int8 Adam-moment
  scales can absorb (the paper's m2-divergence mechanism, measured live).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantSpec
from repro.core.quantizer import fake_quant_nograd


def channel_outlier_stats(acts: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-channel outlier profile of an activation tensor (..., channels).

    Returns the channel absmax vector plus summary ratios.  A large
    ``max_over_median`` with a small set of recurring argmax channels is the
    paper's Fig-6 signature.
    """
    flat = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    ch_absmax = jnp.max(jnp.abs(flat), axis=0)
    med = jnp.median(ch_absmax)
    return {
        "channel_absmax": ch_absmax,
        "max_over_median": jnp.max(ch_absmax) / jnp.maximum(med, 1e-9),
        "top_channel": jnp.argmax(ch_absmax),
        "p99_over_p50": (jnp.percentile(ch_absmax, 99.0)
                         / jnp.maximum(med, 1e-9)),
    }


def gradient_sparsity(g: jnp.ndarray, rel_threshold: float = 1e-3) -> jnp.ndarray:
    """Fraction of entries with |g| < rel_threshold * absmax(g) (Fig. 10)."""
    gf = g.astype(jnp.float32)
    thresh = rel_threshold * jnp.max(jnp.abs(gf))
    return jnp.mean((jnp.abs(gf) < thresh).astype(jnp.float32))


def zero_bin_fraction(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Fraction of entries that dequantize to exactly zero (Fig. 12)."""
    q = fake_quant_nograd(x.astype(jnp.float32), spec)
    return jnp.mean((q == 0.0).astype(jnp.float32))


def quant_snr_db(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Signal-to-quantization-noise ratio in dB (higher = better fidelity)."""
    xf = x.astype(jnp.float32)
    err = xf - fake_quant_nograd(xf, spec)
    return 10.0 * jnp.log10(jnp.sum(xf ** 2) /
                            jnp.maximum(jnp.sum(err ** 2), 1e-20))


def saturation_rate(x: jnp.ndarray, spec: QuantSpec,
                    scale: jnp.ndarray) -> jnp.ndarray:
    """Fraction of entries that would pin at the integer grid edge when
    quantized with the given *stored* scale (an overflow counter).

    Against a fresh absmax scale the top bin is occupied by construction;
    saturation only means something against a scale carried from previous
    steps (a ``QState`` sidecar) -- entries with ``|x| > qmax * scale`` are
    the mass the codec can no longer represent.  ``scale`` broadcasts
    against ``x`` (scalar, per-channel keepdims, or blockwise rows)."""
    xf = jnp.abs(x.astype(jnp.float32))
    lim = spec.qmax * jnp.maximum(scale.astype(jnp.float32), 1e-30)
    return jnp.mean((xf > lim).astype(jnp.float32))


def relative_quant_error(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """||x - qdq(x)|| / ||x||: the per-step size of the injected
    quantization error.  The sentinel tracks its drift -- a jump means the
    tensor's distribution left the regime the spec's granularity absorbs
    (e.g. emergent channel outliers, Fig. 6)."""
    xf = x.astype(jnp.float32)
    err = xf - fake_quant_nograd(xf, spec)
    return jnp.sqrt(jnp.sum(jnp.square(err))) \
        / jnp.maximum(jnp.sqrt(jnp.sum(jnp.square(xf))), 1e-20)


def moment_saturation_rate(grads, moments, spec: Optional[QuantSpec],
                           beta1: float = 0.9,
                           headroom: float = 4.0) -> Optional[jnp.ndarray]:
    """Saturation rate of the *candidate* first moments against the stored
    int8 moment scales, over every integer-stored leaf.

    This is the live form of the paper's m2-divergence mechanism: the moment
    codec's scales were fit to previous steps' statistics, so the entries
    whose next value ``beta1 * dequant(m1) + (1 - beta1) * g`` exceeds
    ``headroom * qmax * stored_scale`` are the mass the codec cannot absorb
    by an ordinary blockwise re-fit.  The ``headroom`` margin is what makes
    this a *spike* detector rather than a drift meter: while the EMA warms
    up (or whenever the regime shifts slowly) the candidate routinely
    outgrows the previous step's absmax by small factors, which the next
    re-fit absorbs for ~lg(headroom) bits of transient resolution -- only
    mass beyond the margin signals a step change the codec must clip.
    Entries whose stored scale sits at the quantizer's absmax floor
    (``_EPS``-clamped, i.e. the block was all-zero when fit -- fresh init
    or a dead block) are excluded: such a scale encodes no regime, so
    "outgrowing" it is meaningless.  Returns None when no leaf stores
    integer moments (fp / fake storage -- nothing can saturate)."""
    from repro.core import qadam          # local: avoid import cycle at init
    if spec is None:
        return None
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    m_leaves = treedef.flatten_up_to(moments)
    hits = []
    valid = []
    for g, m in zip(g_leaves, m_leaves):
        if not isinstance(m, qadam.QState):
            continue
        if spec.block_size:
            gb = qadam.flatten_blocks(g.astype(jnp.float32), spec.block_size)
        else:
            gb = g.astype(jnp.float32)
        scale = m.scale.astype(jnp.float32)
        m1 = (m.q.astype(jnp.float32) + m.zero.astype(jnp.float32)) * scale
        cand = beta1 * m1 + (1.0 - beta1) * gb
        lim = headroom * spec.qmax * scale
        # quantizer.py clamps absmax to _EPS=1e-12: a scale at (or under)
        # the floor encodes an all-zero block, not a fitted regime
        fitted = jnp.broadcast_to(scale * spec.qmax > 2e-12, gb.shape)
        hits.append(jnp.sum((jnp.abs(cand) > lim) & fitted))
        valid.append(jnp.sum(fitted))
    if not hits:
        return None
    n = jnp.sum(jnp.stack(valid))
    return jnp.sum(jnp.stack(hits)) / jnp.maximum(n, 1.0)


def grad_quant_health(grads, moments, m1_spec: Optional[QuantSpec],
                      err_spec: Optional[QuantSpec],
                      beta1: float = 0.9) -> Dict[str, jnp.ndarray]:
    """The quant-health metric dict the train step emits for the sentinel
    (all scalars; cheap: two passes over the gradient leaves).

    * ``grad_sat``: :func:`moment_saturation_rate` vs the stored m1 scales;
    * ``grad_qerr``: :func:`relative_quant_error` of the concatenated 2-D+
      gradient leaves under ``err_spec`` (the policy's gradient/activation
      spec) -- its *drift* is the signal, not its level.
    """
    out: Dict[str, jnp.ndarray] = {}
    sat = moment_saturation_rate(grads, moments, m1_spec, beta1)
    if sat is not None:
        out["grad_sat"] = sat
    if err_spec is not None:
        flat = [g.astype(jnp.float32).reshape(-1)
                for g in jax.tree_util.tree_leaves(grads) if g.ndim >= 2]
        if flat:
            out["grad_qerr"] = relative_quant_error(
                jnp.concatenate(flat), err_spec)
    return out


def m_sharpness(loss_fn: Callable, params, batch, key: jax.Array,
                rho: float = 0.05, n_samples: int = 8) -> jnp.ndarray:
    """m-sharpness (Foret et al. 2021) via worst-of-n random filter-normalized
    perturbations: max_eps<=rho [ L(params + eps) - L(params) ].

    ``loss_fn(params, batch) -> scalar``.  Perturbations are scaled per-leaf by
    the leaf norm (filter normalization, Li et al. 2018) so the radius is
    comparable across layers.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    base = loss_fn(params, batch)

    def one(k):
        ks = jax.random.split(k, len(leaves))
        perturbed = []
        for leaf, lk in zip(leaves, ks):
            noise = jax.random.normal(lk, leaf.shape, dtype=jnp.float32)
            nn = jnp.linalg.norm(noise.reshape(-1)) + 1e-12
            ln = jnp.linalg.norm(leaf.astype(jnp.float32).reshape(-1))
            perturbed.append((leaf + (rho * ln / nn) * noise).astype(leaf.dtype))
        return loss_fn(jax.tree_util.tree_unflatten(treedef, perturbed), batch)

    losses = jax.lax.map(one, jax.random.split(key, n_samples))
    return jnp.max(losses) - base
