"""Core quantization library -- the paper's primary contribution in JAX."""
from repro.core.qconfig import (Granularity, QuantRecipe, QuantSpec, RoundMode,
                                beyond_paper_recipe, fp_baseline, get_recipe,
                                paper_recipe, paper_recipe_wag8, PRESETS)
from repro.core.qlinear import quantized_linear
from repro.core.quantizer import (compute_scale_zero, dequantize_int,
                                  fake_quant, fake_quant_nograd,
                                  maybe_fake_quant, quant_error, quantize_int)

__all__ = [
    "Granularity", "QuantRecipe", "QuantSpec", "RoundMode",
    "beyond_paper_recipe", "fp_baseline", "get_recipe", "paper_recipe",
    "paper_recipe_wag8", "PRESETS", "quantized_linear", "compute_scale_zero",
    "dequantize_int", "fake_quant", "fake_quant_nograd", "maybe_fake_quant",
    "quant_error", "quantize_int",
]
