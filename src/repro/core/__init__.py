"""Core quantization library -- the paper's primary contribution in JAX."""
from repro.core.qconfig import (Granularity, QuantRecipe, QuantSpec, RoundMode,
                                beyond_paper_recipe, fp_baseline, get_recipe,
                                paper_recipe, paper_recipe_wag8, parse_recipe,
                                parse_spec, PRESETS)
from repro.core.qadam import QState
from repro.core.qlinear import (int8_backend_supported, int8_bwd_supported,
                                int8_quantized_linear, quantized_linear)
from repro.core.qpolicy import (FP_POLICY, KERNEL_BACKENDS, LinearCtx,
                                PolicyRule, QuantPolicy, ROLES, as_policy,
                                fallback_policy, parse_policy,
                                register_backend)
from repro.core.quantizer import (compute_scale_zero, dequantize_int,
                                  fake_quant, fake_quant_nograd,
                                  maybe_fake_quant, quant_error, quantize_int)

__all__ = [
    "Granularity", "QuantRecipe", "QuantSpec", "RoundMode",
    "beyond_paper_recipe", "fp_baseline", "get_recipe", "paper_recipe",
    "paper_recipe_wag8", "parse_recipe", "parse_spec", "PRESETS",
    "QState", "quantized_linear", "int8_backend_supported",
    "int8_bwd_supported", "int8_quantized_linear",
    "FP_POLICY", "KERNEL_BACKENDS", "LinearCtx", "PolicyRule", "QuantPolicy",
    "ROLES", "as_policy", "fallback_policy", "parse_policy",
    "register_backend",
    "compute_scale_zero", "dequantize_int", "fake_quant", "fake_quant_nograd",
    "maybe_fake_quant", "quant_error", "quantize_int",
]
