"""Layer-aware quantization policy with pluggable kernel backends.

The paper's central finding is that quantization tolerance is component- AND
layer-dependent: embeddings and the lm-head stay fp, the dx gradient path
must stay real-valued, and first/last blocks are more sensitive than the
middle of the stack (Bondarenko et al. 2021 show per-sublayer activation
ranges differ sharply).  A :class:`QuantPolicy` makes that first-class:

* ordered pattern **rules** map a *layer role* (``attn_qkv``, ``mlp_down``,
  ``block[0:2].*`` ...) to a :class:`~repro.core.qconfig.QuantRecipe` (or fp)
  plus a **kernel backend**;
* every weight-bearing matmul in the model zoo calls
  ``policy.linear(ctx, x, w)`` where the :class:`LinearCtx` carries the role,
  the (possibly traced) layer index, and an optional PRNG key;
* backends are looked up in a registry: ``"fake_quant"`` is the reference
  error-injection einsum (paper methodology), ``"int8_pallas"`` runs the real
  W8A8 MXU kernel for supported specs and silently falls back to the
  reference path otherwise.

``QuantPolicy.from_recipe(recipe)`` reproduces the legacy single-recipe
behaviour exactly (block linears quantized; embed / lm-head / router /
patch-adapter fp), so existing presets migrate mechanically.

Layer indices inside ``jax.lax.scan`` over the stacked block params are
traced values; when a policy is depth-sensitive the dispatch groups layers
into equivalence classes and selects the class with ``jax.lax.switch`` -- a
depth-insensitive policy (every ``from_recipe`` policy) keeps the exact
single-branch HLO of the legacy path.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as _np

from repro.core.qadam import QState
from repro.core.qconfig import (Granularity, QuantRecipe, QuantSpec,
                                RoundMode, get_recipe)
from repro.core.qlinear import (int8_backend_supported, int8_bwd_supported,
                                int8_decode_attn_supported,
                                int8_quantized_linear, quantized_linear)
from repro.core.quantizer import fake_quant, maybe_fake_quant

# Layer roles understood by the model zoo.  ``embed`` / ``lm_head`` govern the
# (weight-only) quantization of the embedding table and output head;
# ``patch_proj`` / ``frame_proj`` are the VLM / audio input adapters;
# ``shared_proj`` is the zamba2 shared-block down-projection.  ``kv_cache``
# governs the *storage* precision of the decode KV cache (int8 payload +
# per-head-per-position scales, dequant-on-read) -- fp unless a rule names it.
ROLES = ("embed", "lm_head", "attn_qkv", "attn_out", "mlp_up", "mlp_down",
         "router", "ssm_in", "ssm_out", "shared_proj", "frame_proj",
         "patch_proj", "kv_cache")


# ---------------------------------------------------------------------------
# Kernel backend registry
# ---------------------------------------------------------------------------

class KernelBackend(NamedTuple):
    """A quantized-matmul implementation.

    ``fn(x, w, recipe, key) -> y`` computes the forward (and owns its custom
    VJP); ``supports(recipe)`` gates eligibility -- unsupported recipes fall
    back to the ``fake_quant`` reference automatically.  ``bwd_supports``
    reports whether the backend's backward also runs real quantized compute
    for the recipe (capability metadata -- the backend's own vjp is expected
    to apply the same predicate and degrade gracefully on its own).
    ``decode_attn_supports(kv_spec)`` reports whether the backend ships
    attention kernels that consume a KV cache stored under that spec
    *directly* (int8 payload + scale sidecars, no fp materialization) --
    the serving decode/prefill hot path dispatches on it.
    """
    fn: Callable
    supports: Callable
    bwd_supports: Callable = lambda recipe: False
    decode_attn_supports: Callable = lambda spec: False


KERNEL_BACKENDS: Dict[str, KernelBackend] = {}


def register_backend(name: str, fn: Callable,
                     supports: Callable = lambda recipe: True,
                     bwd_supports: Callable = lambda recipe: False,
                     decode_attn_supports: Callable = lambda spec: False,
                     ) -> None:
    KERNEL_BACKENDS[name] = KernelBackend(fn, supports, bwd_supports,
                                          decode_attn_supports)


register_backend("fake_quant", quantized_linear)
register_backend("int8_pallas", int8_quantized_linear,
                 supports=int8_backend_supported,
                 bwd_supports=int8_bwd_supported,
                 decode_attn_supports=int8_decode_attn_supported)


def _prepared_int8_ok(recipe: Optional[QuantRecipe], w: QState) -> bool:
    """Can the real-int8 MXU kernel consume this prepared weight directly?
    Needs the full W8A8 contract (symmetric, nearest, unblocked) and a plain
    2-D payload (stacked / per-expert payloads run the dequant matmul)."""
    return (int8_backend_supported(recipe) and w.q.ndim == 2
            and w.q.dtype == jnp.int8)


def _prepared_matmul(resolved: "Resolved", x: jnp.ndarray, w: QState,
                     key) -> jnp.ndarray:
    """Serving path: the weight arrives as a stored integer payload + scales
    (quantized ONCE at engine construction -- see ``repro.infer.prepare``), so
    the trace contains *no* weight quantize step (no absmax reduce, no round).
    Activations still follow the resolved recipe."""
    recipe = resolved.recipe
    a_spec = recipe.acts if recipe is not None else None
    if (resolved.backend == "int8_pallas" and a_spec is not None
            and _prepared_int8_ok(recipe, w)):
        from repro.kernels.ops import int8_prepared_linear   # lazy: pallas
        return int8_prepared_linear(x, w.q, w.scale, a_spec,
                                    out_dtype=x.dtype)
    xq = maybe_fake_quant(x, a_spec, key)
    wd = ((w.q.astype(jnp.float32) + w.zero) * w.scale).astype(x.dtype)
    return jnp.matmul(xq, wd)


def _dispatch(resolved: "Resolved", x: jnp.ndarray, w: jnp.ndarray,
              key) -> jnp.ndarray:
    recipe = resolved.recipe
    if isinstance(w, QState):
        return _prepared_matmul(resolved, x, w, key)
    if recipe is None or not recipe.any_linear_quant:
        return jnp.matmul(x, w)
    try:
        be = KERNEL_BACKENDS[resolved.backend]
    except KeyError:
        raise KeyError(f"unknown kernel backend {resolved.backend!r}; "
                       f"registered: {sorted(KERNEL_BACKENDS)}") from None
    if not be.supports(recipe):
        be = KERNEL_BACKENDS["fake_quant"]       # automatic fallback
    return be.fn(x, w, recipe, key)


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ordered pattern rule: ``block[lo:hi].role = recipe @ backend``.

    ``role`` is a name from :data:`ROLES` or ``"*"``; ``lo``/``hi`` bound the
    layer depth (python slice semantics, negatives relative to ``n_layers``,
    ``None`` = unbounded).  ``recipe=None`` means fp.  ``backend=None``
    inherits the policy's backend at resolution time (so rule order never
    changes which kernel runs).  Depth-bounded rules never match depth-less
    call sites (embed, lm-head, shared blocks).
    """
    role: str = "*"
    lo: Optional[int] = None
    hi: Optional[int] = None
    recipe: Optional[QuantRecipe] = None
    backend: Optional[str] = None

    @property
    def depth_bounded(self) -> bool:
        return self.lo is not None or self.hi is not None

    def matches(self, role: str, layer: Optional[int], n_layers: int = 0) -> bool:
        if self.role != "*" and self.role != role:
            return False
        if not self.depth_bounded:
            return True
        if layer is None:
            return False
        lo = self.lo if self.lo is not None else 0
        hi = self.hi if self.hi is not None else (n_layers or 1 << 30)
        if lo < 0:
            lo += n_layers
        if hi < 0:
            hi += n_layers
        return lo <= layer < hi

    def describe(self) -> str:
        pat = self.role
        if self.depth_bounded:
            lo = "" if self.lo is None else str(self.lo)
            hi = "" if self.hi is None else str(self.hi)
            pat = f"block[{lo}:{hi}].{pat}"
        spec = "fp" if self.recipe is None else \
            self.recipe.describe_compact().replace(",", "+")
        s = f"{pat}={spec}"
        if self.backend is not None:
            s += f"@{self.backend}"
        return s


@dataclasses.dataclass(frozen=True)
class Resolved:
    """Outcome of role resolution: what to run and on which backend."""
    recipe: Optional[QuantRecipe]
    backend: str = "fake_quant"


@dataclasses.dataclass(frozen=True)
class LinearCtx:
    """Call-site context for one quantized matmul.

    ``layer`` may be a python int (static), a traced scalar (inside the layer
    scan; requires ``n_layers``), or None for depth-less sites.
    """
    role: str
    layer: Union[int, jnp.ndarray, None] = None
    n_layers: int = 0
    rng: Optional[jax.Array] = None


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered pattern rules + default recipe + default backend.

    Resolution: first matching rule wins; otherwise ``(default, backend)``.
    Optimizer-moment specs (``adam_m1`` / ``adam_m2``) come from the default
    recipe -- moments are per-parameter, not per-role.
    """
    rules: Tuple[PolicyRule, ...] = ()
    default: Optional[QuantRecipe] = None
    backend: str = "fake_quant"

    # -- construction ------------------------------------------------------

    @classmethod
    def from_recipe(cls, recipe: Optional[QuantRecipe],
                    backend: str = "fake_quant") -> "QuantPolicy":
        """Legacy-equivalent policy: block linears get ``recipe``; the
        embedding table, lm-head, MoE router and the VLM patch adapter stay
        fp (exactly the seed ``quantized_linear(x, w, recipe)`` scoping).
        ``recipe.include_embeddings`` lifts the embed/lm-head exclusion."""
        rules = ()
        if not (recipe is not None and recipe.include_embeddings):
            rules += (PolicyRule(role="embed"), PolicyRule(role="lm_head"))
        rules += (PolicyRule(role="patch_proj"), PolicyRule(role="router"),
                  PolicyRule(role="kv_cache"))
        return cls(rules=rules, default=recipe, backend=backend)

    # -- optimizer-moment pass-through (duck-types a QuantRecipe) ----------

    @property
    def adam_m1(self) -> Optional[QuantSpec]:
        return self.default.adam_m1 if self.default is not None else None

    @property
    def adam_m2(self) -> Optional[QuantSpec]:
        return self.default.adam_m2 if self.default is not None else None

    # -- resolution --------------------------------------------------------

    def resolve(self, role: str, layer: Optional[int] = None,
                n_layers: int = 0) -> Resolved:
        for rule in self.rules:
            if rule.matches(role, layer, n_layers):
                return Resolved(rule.recipe, rule.backend or self.backend)
        return Resolved(self.default, self.backend)

    def depth_sensitive(self, role: str) -> bool:
        """Could resolution of ``role`` depend on the layer index?"""
        return any(r.depth_bounded for r in self.rules
                   if r.role in ("*", role))

    def effective_backend(self, role: str, layer: Optional[int] = None,
                          n_layers: int = 0) -> Tuple[str, Tuple[str, ...]]:
        """``(backend_name, caps)`` that :meth:`linear` will actually run for
        this role, with the registry fallback applied.  ``caps`` lists which
        passes execute real quantized kernels: ``('fwd', 'bwd')`` for the
        full int8 training path, ``('fwd',)`` for int8-forward-only, ``()``
        for the fake-quant reference einsum; backend name ``'fp'`` means a
        plain matmul (no quantization resolved)."""
        res = self.resolve(role, layer, n_layers)
        recipe = res.recipe
        if recipe is None or not recipe.any_linear_quant:
            return "fp", ()
        name, be = res.backend, KERNEL_BACKENDS[res.backend]
        if not be.supports(recipe):
            name, be = "fake_quant", KERNEL_BACKENDS["fake_quant"]
        if name == "fake_quant":
            return name, ()
        caps = ("fwd", "bwd") if be.bwd_supports(recipe) else ("fwd",)
        return name, caps

    def decode_attn_backend(self) -> Tuple[str, Tuple[str, ...]]:
        """``(backend_name, caps)`` for the KV-cache *consumption* path,
        :meth:`effective_backend`-style.  ``('fp', ())`` when the cache is
        stored fp; ``('<backend>', ('decode', 'prefill'))`` when a registered
        backend's attention kernels consume the stored payload directly
        (fused decode step + q8 prefill); ``('dequant', ())`` when the cache
        is quantized but no kernel fits the spec, i.e. the reference
        dequantize-on-read path runs.

        Unlike :meth:`linear` dispatch this is a capability scan, not a
        rule-backend lookup: ``fake_quant`` has no attention kernels, so a
        plain ``kv_cache=a8t`` rule (default backend) still finds the
        ``int8_pallas`` kernels.  The resolved rule backend is preferred when
        several backends qualify; ``REPRO_FUSED_DECODE=0`` opts out at the
        call site (see models/attention.py).

        ``decode_attn_supports`` is capability *metadata* (like
        ``bwd_supports``): the kernel entry points are not carried on the
        registry record, so ``int8_pallas`` is currently the only backend
        models/attention.py knows how to run -- a new backend registering
        this capability must also be threaded through ``_fused_kv_ok`` /
        the fused branches there.
        """
        spec = self.kv_spec()
        if spec is None:
            return "fp", ()
        preferred = self.resolve("kv_cache").backend
        names = [preferred] + [n for n in KERNEL_BACKENDS if n != preferred]
        for name in names:
            if KERNEL_BACKENDS[name].decode_attn_supports(spec):
                return name, ("decode", "prefill")
        return "dequant", ()

    def kv_spec(self) -> Optional[QuantSpec]:
        """Storage spec for the decode KV cache (role ``kv_cache``), or None
        for fp storage.  The spec is read from the resolved recipe's ``acts``
        component (falling back to ``weights`` -- cache entries are cached
        activations): ``kv_cache=a8t`` stores int8 K/V with one scale per
        (position, head) row.  Per-channel scales cannot key a (B,S,K,1)
        sidecar buffer and asymmetric/blockwise/stochastic codecs are not
        plumbed through the cache write, so those specs are rejected."""
        res = self.resolve("kv_cache")
        r = res.recipe
        if r is None:
            return None
        spec = r.acts if r.acts is not None else r.weights
        if spec is None:
            return None
        if (spec.granularity is Granularity.PER_CHANNEL
                or not spec.symmetric or spec.block_size
                or spec.sqrt_domain
                or spec.round_mode is not RoundMode.NEAREST):
            raise ValueError(
                f"kv_cache spec [{spec.describe()}] unsupported: the cache "
                "codec is symmetric nearest-rounded per-token (one scale per "
                "position x head) or per-tensor (per write-block)")
        return spec

    # -- dispatch ----------------------------------------------------------

    def linear(self, ctx: LinearCtx, x: jnp.ndarray, w: jnp.ndarray,
               b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """The quantized matmul: resolve (role, layer) -> spec+backend, run.
        ``b`` is an optional bias added outside the quantized op (biases are
        not quantized -- paper scope is the matmul)."""
        y = self._matmul(ctx, x, w)
        return y if b is None else y + b

    def _matmul(self, ctx, x, w):
        layer = ctx.layer
        static = layer is None or isinstance(layer, (int, _np.integer))
        if static or not self.depth_sensitive(ctx.role):
            res = self.resolve(ctx.role, layer if static else None,
                               ctx.n_layers)
            return _dispatch(res, x, w, ctx.rng)
        # traced layer index + depth-sensitive policy: group layers into
        # resolution classes and lax.switch between the (few) distinct ones.
        if not ctx.n_layers:
            raise ValueError(
                "depth-bounded policy rules need ctx.n_layers when the layer "
                "index is traced (inside the layer scan)")
        variants = [self.resolve(ctx.role, i, ctx.n_layers)
                    for i in range(ctx.n_layers)]
        uniq = []
        for v in variants:
            if v not in uniq:
                uniq.append(v)
        if len(uniq) == 1:
            return _dispatch(uniq[0], x, w, ctx.rng)
        class_of = jnp.asarray([uniq.index(v) for v in variants], jnp.int32)
        rng = ctx.rng
        branches = [
            (lambda x_, w_, res=res: _dispatch(res, x_, w_, rng))
            for res in uniq]
        return jax.lax.switch(class_of[layer], branches, x, w)

    def quantize_weight(self, role: str, w: jnp.ndarray) -> jnp.ndarray:
        """Weight-only qdq for non-matmul sites (embedding lookup, lm-head
        einsum).  STE: the table gradient flows unchanged.  No-op when the
        role resolves to fp (the default for embed/lm_head)."""
        res = self.resolve(role)
        spec = res.recipe.weights if res.recipe is not None else None
        if spec is None:
            return w
        return fake_quant(w, spec)

    def describe(self) -> str:
        parts = [r.describe() for r in self.rules]
        # only spell out the default when no depth-less wildcard rule covers it
        if not any(r.role == "*" and not r.depth_bounded for r in self.rules):
            spec = "fp" if self.default is None else \
                self.default.describe_compact().replace(",", "+")
            tail = f"*={spec}"
            if self.backend != "fake_quant":
                tail += f"@{self.backend}"
            parts.append(tail)
        return ",".join(parts)


#: The fp baseline policy: no rules, fp default -- every linear is a plain
#: matmul.  ``as_policy(None)`` returns this so model code never branches.
FP_POLICY = QuantPolicy()


def fallback_policy(policy: "QuantPolicy", mode: str = "fake_quant"
                    ) -> "QuantPolicy":
    """Stability-fallback variant of a policy -- the train sentinel's
    recovery action after a rollback (a temporary, step-indexed override:
    the trainer runs the fallback-compiled step for N steps, then re-engages
    the primary policy; see ``train/sentinel.py``).

    ``mode='fake_quant'`` keeps every resolved recipe (the quantization
    *error* stays, preserving the paper's methodology) but forces every rule
    and the policy default off the real-int8 kernels onto the ``fake_quant``
    reference einsum -- recovery from kernel-path numerical trouble without
    changing the optimization problem.

    ``mode='fp'`` additionally drops linear quantization (weights/acts/grads
    -> fp) from every rule and the default -- the Nielsen-et-al-style
    precision transition for when the int8 formulation itself destabilizes.

    Both modes PRESERVE the optimizer-moment specs (``adam_m1``/``adam_m2``)
    of the default recipe: the fallback step must consume and produce the
    exact same ``AdamState`` pytree (int8 ``QState`` payloads + sidecars) as
    the primary step, or rollback/re-engage could not hand states across.
    """
    if mode not in ("fake_quant", "fp"):
        raise ValueError(f"unknown fallback mode {mode!r} "
                         "(want 'fake_quant' or 'fp')")
    policy = as_policy(policy)

    def degrade(recipe: Optional[QuantRecipe]) -> Optional[QuantRecipe]:
        if recipe is None:
            return None
        if mode == "fake_quant":
            return recipe
        return dataclasses.replace(recipe, weights=None, acts=None,
                                   grads=None, grads_dx=None)

    rules = tuple(dataclasses.replace(r, recipe=degrade(r.recipe),
                                      backend="fake_quant")
                  for r in policy.rules)
    return QuantPolicy(rules=rules, default=degrade(policy.default),
                       backend="fake_quant")


def as_policy(obj: Union[None, QuantRecipe, QuantPolicy, str]) -> QuantPolicy:
    """Normalize the public ``recipe=`` / ``policy=`` surface: accepts None
    (fp), a QuantRecipe (wrapped via from_recipe), a QuantPolicy, or a policy
    string (parsed)."""
    if obj is None:
        return FP_POLICY
    if isinstance(obj, QuantPolicy):
        return obj
    if isinstance(obj, QuantRecipe):
        return QuantPolicy.from_recipe(obj)
    if isinstance(obj, str):
        return parse_policy(obj)
    raise TypeError(f"expected QuantRecipe / QuantPolicy / str / None, "
                    f"got {type(obj).__name__}")


# ---------------------------------------------------------------------------
# Policy string codec:  "embed=fp,block[0:2].*=fp,*=w8c+a8t@int8_pallas"
# ---------------------------------------------------------------------------

_PATTERN_RE = re.compile(
    r"^(?:(block\[)(-?\d+)?(:)?(-?\d+)?\]\.)?([a-z_][a-z0-9_]*|\*)$")


def _parse_pattern(pat: str) -> Tuple[str, Optional[int], Optional[int]]:
    m = _PATTERN_RE.match(pat.strip())
    if not m:
        raise ValueError(
            f"bad policy pattern {pat!r} (want 'role', '*', 'block[2].role' "
            "or 'block[0:4].*')")
    prefix, lo_s, colon, hi_s, role = m.groups()
    if role != "*" and role not in ROLES:
        raise ValueError(f"unknown role {role!r}; roles: {ROLES}")
    if prefix is None:
        return role, None, None
    if lo_s is None and hi_s is None:
        if colon is None:
            raise ValueError(f"bad policy pattern {pat!r}: block[] needs an "
                             "index or slice (block[2], block[0:4], block[:])")
        return role, 0, None            # block[:] -> every depth, but still
        #                                 depth-bounded: never matches the
        #                                 depth-less embed/lm_head/... sites
    lo = int(lo_s) if lo_s is not None else 0
    if colon is None:                       # block[i] -> exactly layer i
        if lo == -1:
            return role, -1, None           # block[-1] -> last layer
        return role, lo, lo + 1             # negative i: [-k, -k+1)
    hi = int(hi_s) if hi_s is not None else None
    return role, lo, hi


def _parse_value(spec: str) -> Tuple[Optional[QuantRecipe], Optional[str]]:
    """``spec[@backend]`` where spec is 'fp', a preset name, or a compact
    recipe string with '+' separators."""
    backend = None
    if "@" in spec:
        spec, backend = spec.split("@", 1)
        backend = backend.strip()
        if backend not in KERNEL_BACKENDS:
            raise ValueError(f"unknown kernel backend {backend!r}; "
                             f"registered: {sorted(KERNEL_BACKENDS)}")
    spec = spec.strip()
    recipe = None if spec == "fp" else get_recipe(spec)
    return recipe, backend


#: Roles the paper scopes out of block-linear quantization (plus the KV-cache
#: storage role, which is opt-in); parse_policy pins them fp unless a rule
#: names them explicitly (same as from_recipe).
_DEFAULT_FP_ROLES = ("embed", "lm_head", "patch_proj", "router", "kv_cache")


def parse_policy(text: str, backend: str = "fake_quant") -> QuantPolicy:
    """Parse a comma-separated rule list into a :class:`QuantPolicy`.

    Each entry is ``pattern=spec[@backend]``; earlier entries win.  A
    depth-less ``*`` entry also sets the policy default (and so the
    optimizer-moment specs).  Example::

        block[0:2].*=fp,*=w8c+a8t@int8_pallas

    The paper-scope exclusions (``embed``, ``lm_head``, ``router``,
    ``patch_proj`` stay fp) are seeded automatically so a wildcard means
    "every block linear", matching ``from_recipe``; name a role explicitly
    (``embed=w8c``) -- or put ``emb`` in the wildcard recipe -- to quantize
    it.
    """
    rules = []
    default: Optional[QuantRecipe] = None
    default_backend = backend
    for entry in text.split(","):
        entry = entry.strip()
        if not entry:
            continue
        if "=" not in entry:
            raise ValueError(f"bad policy entry {entry!r} (want pattern=spec)")
        pat, spec = entry.split("=", 1)
        role, lo, hi = _parse_pattern(pat)
        recipe, be = _parse_value(spec)
        rules.append(PolicyRule(role=role, lo=lo, hi=hi, recipe=recipe,
                                backend=be))
        if role == "*" and lo is None and hi is None and default is None:
            default = recipe
            if be is not None:
                default_backend = be
    for rule in rules:
        # optimizer moments are per-parameter, not per-role: they are only
        # honoured on the policy default (the depth-less '*' entry) -- reject
        # them elsewhere instead of silently running fp moments
        r = rule.recipe
        if (r is not None and (r.adam_m1 is not None or r.adam_m2 is not None)
                and r != default):
            raise ValueError(
                f"rule '{rule.describe()}' carries optimizer-moment specs "
                "(m1:/m2:), but moments are read from the depth-less '*' "
                "entry only -- move them there")
    named = {r.role for r in rules if r.role != "*"}
    include_emb = default is not None and default.include_embeddings
    exclusions = tuple(
        PolicyRule(role=role) for role in _DEFAULT_FP_ROLES
        if role not in named
        and not (include_emb and role in ("embed", "lm_head")))
    return QuantPolicy(rules=exclusions + tuple(rules), default=default,
                       backend=default_backend)
