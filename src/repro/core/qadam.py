"""Quantized Adam optimizer states (paper Section 4.4).

The paper stores Adam's first/second moments quantized between steps and
dequantizes them for the update.  Two storage modes are provided:

``fake`` (paper-faithful)
    Moments live in fp32 but are passed through quantize->dequantize after
    every update.  Numerically identical to integer storage (the qdq grid is
    a fixed point of the codec) while keeping the study's "simulated
    low-precision" methodology.

``int`` (production)
    Moments are stored as real int8/int16 payloads plus per-granularity fp32
    scales -- the actual memory saving (this is what shows up in the dry-run's
    ``memory_analysis``).  This is the Dettmers-et-al-style deployment path.

The paper's Fig-12 failure (m2 diverges because symmetric linear quantization
collapses small second moments into the zero bin) is reproduced by the plain
specs; the beyond-paper fix is ``QuantSpec(..., block_size=128,
sqrt_domain=True)`` which quantizes sqrt(m2) blockwise.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantSpec, RoundMode
from repro.core.quantizer import (dequantize_int, fake_quant_nograd,
                                  quantize_int)

# Parameters smaller than this (or 1-D) keep fp moments: per-channel scales on
# tiny tensors cost more than they save, matching 8-bit-optimizer practice.
MIN_QUANT_SIZE = 4096


def quantizable(param: jnp.ndarray) -> bool:
    return param.ndim >= 2 and param.size >= MIN_QUANT_SIZE


class QState(NamedTuple):
    """Integer-stored moment: payload + codec sidecar."""
    q: jnp.ndarray          # int8/int16 payload (blockwise: (nblocks, bs))
    scale: jnp.ndarray      # fp32 scales, granularity-shaped
    zero: jnp.ndarray       # fp32 zero points (zeros when symmetric)


def encode(value: jnp.ndarray, spec: Optional[QuantSpec], storage: str) -> Any:
    """Compress one moment tensor according to the spec + storage mode."""
    if spec is None or not quantizable(value):
        return value
    if spec.sqrt_domain:
        # sqrt-domain codecs always run through the fake path: squaring the
        # dequantized sqrt is cheap and keeps int payload semantics simple.
        if storage == "int":
            root = jnp.sqrt(jnp.maximum(value, 0.0))
            q, scale, zero = quantize_int(root, spec)
            return QState(q, scale, zero)
        return fake_quant_nograd(value, spec)
    if storage == "int":
        q, scale, zero = quantize_int(value, spec)
        return QState(q, scale, zero)
    if storage == "fake":
        return fake_quant_nograd(value, spec)
    raise ValueError(f"unknown storage mode {storage!r}")


def decode(state: Any, spec: Optional[QuantSpec], shape, dtype=jnp.float32) -> jnp.ndarray:
    """Recover the fp moment for the Adam update."""
    if spec is None or not isinstance(state, QState):
        return state
    deq = dequantize_int(state.q, state.scale, state.zero, spec,
                         shape=shape, dtype=dtype)
    if spec.sqrt_domain:
        deq = jnp.square(deq)
    return deq


def init_state(param: jnp.ndarray, spec: Optional[QuantSpec], storage: str) -> Any:
    """Zero moment in the chosen representation."""
    zeros = jnp.zeros(param.shape, dtype=jnp.float32)
    return encode(zeros, spec, storage)


def state_nbytes(state: Any) -> int:
    """Actual bytes held by one moment (for memory accounting/benchmarks)."""
    if isinstance(state, QState):
        return sum(int(x.size) * x.dtype.itemsize for x in state)
    return int(state.size) * state.dtype.itemsize


# ---------------------------------------------------------------------------
# Blockwise layout helpers + fused-kernel eligibility (kernels/opt_update.py).
#
# Codec invariant the fused AdamW path leans on: for a blockwise spec, encode
# flattens the tensor, zero-pads the tail to a block multiple, and stores
#   q     : (nblocks, block_size)  int8
#   scale : (nblocks, 1)           fp32   one quantization block per row
#   zero  : (nblocks, 1)           fp32   (zeros when symmetric)
# so per-leaf states of equal block_size concatenate along rows into one
# kernel bucket and split back without re-laying-out anything.
# ---------------------------------------------------------------------------

def blockwise_state_shapes(shape, spec: QuantSpec
                           ) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """((nblocks, block_size), (nblocks, 1)) for a blockwise-encoded tensor
    of ``shape`` -- the payload / sidecar layout contract above."""
    n = 1
    for d in shape:
        n *= d
    nblocks = -(-n // spec.block_size)
    return (nblocks, spec.block_size), (nblocks, 1)


def flatten_blocks(x: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Flatten to the (nblocks, block_size) codec layout, zero-padding the
    tail block (identical to the quantizer's internal blocked view)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_size)


def unflatten_blocks(blocks: jnp.ndarray, shape) -> jnp.ndarray:
    """Inverse of :func:`flatten_blocks`: strip tail padding, restore shape."""
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def fused_spec_eligible(spec: Optional[QuantSpec]) -> bool:
    """Can kernels/opt_update.py hold this moment codec in-register?  The
    kernel covers the blockwise int8-storage family: block_size > 0 (row-
    aligned scales), <= 8 bits (int8 payload), nearest rounding (no key
    stream inside the grid).  Symmetric/asymmetric and sqrt-domain are all
    in-contract."""
    return (spec is not None and spec.block_size > 0 and spec.bits <= 8
            and spec.round_mode is RoundMode.NEAREST)


def fused_pair_eligible(m1_spec: Optional[QuantSpec],
                        m2_spec: Optional[QuantSpec]) -> bool:
    """Both moments must be kernel-eligible with a SHARED block size (grad
    and param tiles are laid out once per bucket)."""
    return (fused_spec_eligible(m1_spec) and fused_spec_eligible(m2_spec)
            and m1_spec.block_size == m2_spec.block_size)
