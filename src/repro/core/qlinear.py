"""Quantized linear layer with the paper's Fig-1 forward/backward semantics.

Forward  : y = qdq_A(x) @ qdq_W(w)
Backward : dx = g        @ qdq_W(w)^T        (REAL-valued g -- paper finds that
                                              propagating quantization error
                                              through the input-gradient path
                                              destabilizes training, Fig. 10)
           dW = qdq_A(x)^T @ qdq_G(g)        (output-grad quantized ONLY on the
                                              weight-update path)
STE everywhere: the w / x cotangents pass straight through their quantizers.

``grads_dx`` in the recipe turns on the paper's instability ablation where the
dx path also sees quantized gradients.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qconfig import Granularity, QuantRecipe, RoundMode
from repro.core.quantizer import fake_quant_nograd, maybe_fake_quant


def _flat2d(a: jnp.ndarray) -> jnp.ndarray:
    return a.reshape(-1, a.shape[-1])


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear(x: jnp.ndarray, w: jnp.ndarray, key, recipe: QuantRecipe):
    xq = maybe_fake_quant(x, recipe.acts)
    wq = maybe_fake_quant(w, recipe.weights)
    return jnp.matmul(xq, wq)


def _qlinear_fwd(x, w, key, recipe):
    # Error injection happens here; the *quantized* tensors are the residuals
    # (they are what the matmul actually consumed).
    xq = fake_quant_nograd(x, recipe.acts) if recipe.acts is not None else x
    wq = fake_quant_nograd(w, recipe.weights) if recipe.weights is not None else w
    y = jnp.matmul(xq, wq)
    return y, (xq, wq, key, x.shape)


def _qlinear_bwd(recipe, res, g):
    xq, wq, key, x_shape = res

    # Independent subkeys per backward path: when both grads_dx and grads are
    # stochastic, the dW rounding noise must be uncorrelated with the dx
    # noise (and neither path may consume the caller's parent key raw).
    k_dx = k_dw = None
    if key is not None:
        key_dx, key_dw = jax.random.split(key)
        if (recipe.grads_dx is not None
                and recipe.grads_dx.round_mode is RoundMode.STOCHASTIC):
            k_dx = key_dx
        if (recipe.grads is not None
                and recipe.grads.round_mode is RoundMode.STOCHASTIC):
            k_dw = key_dw

    # --- dx path: real-valued output gradient (paper Fig. 1). -------------
    g_dx = g
    if recipe.grads_dx is not None:                      # instability ablation
        g_dx = fake_quant_nograd(g, recipe.grads_dx, k_dx)
    dx = jnp.matmul(g_dx, wq.T).reshape(x_shape)

    # --- dW path: quantized output gradient. ------------------------------
    g_dw = g
    if recipe.grads is not None:
        g_dw = fake_quant_nograd(g, recipe.grads, k_dw)
    g2 = _flat2d(g_dw)
    x2 = _flat2d(xq)
    dw = jax.lax.dot_general(
        x2, g2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(wq.dtype)

    key_ct = (None if key is None
              else np.zeros(key.shape, dtype=jax.dtypes.float0))
    return dx, dw, key_ct


_qlinear.defvjp(_qlinear_fwd, _qlinear_bwd)


def quantized_linear(x: jnp.ndarray, w: jnp.ndarray, recipe: Optional[QuantRecipe],
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Public entry point.  Falls back to a plain matmul when the recipe does
    not quantize any linear-layer component (keeps the fp baseline's HLO free
    of custom_vjp scaffolding)."""
    if recipe is None or not recipe.any_linear_quant:
        return jnp.matmul(x, w)
    return _qlinear(x, w, key, recipe)


# ---------------------------------------------------------------------------
# Real-int8 forward backend: the Pallas W8A8 kernel replaces the fake-quant
# einsum on the forward; the backward keeps the exact Fig-1 semantics above
# (the kernel's integer payloads match fake_quant_nograd bit-exactly, so the
# qdq residuals are what the MXU actually consumed).
# ---------------------------------------------------------------------------

_INT8_GRANS_W = (Granularity.PER_CHANNEL, Granularity.PER_TENSOR)
_INT8_GRANS_A = (Granularity.PER_TOKEN, Granularity.PER_TENSOR)


def int8_backend_supported(recipe: Optional[QuantRecipe]) -> bool:
    """True when the recipe's forward is expressible as the int8 kernel's
    rank-1-rescale W8A8 contract: symmetric 8-bit weights+acts, nearest
    rounding, no block-wise codec (per-tensor/per-channel W x per-tensor/
    per-token A)."""
    if recipe is None:
        return False
    w, a = recipe.weights, recipe.acts
    return (w is not None and a is not None
            and w.bits == 8 and a.bits == 8
            and w.symmetric and a.symmetric
            and w.block_size == 0 and a.block_size == 0
            and not w.sqrt_domain and not a.sqrt_domain
            and w.round_mode is RoundMode.NEAREST
            and a.round_mode is RoundMode.NEAREST
            and w.granularity in _INT8_GRANS_W
            and a.granularity in _INT8_GRANS_A)


def _int8_forward(x, w, recipe):
    from repro.kernels.ops import int8_linear    # lazy: pallas import
    return int8_linear(x, w, recipe.acts, recipe.weights, out_dtype=x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear_int8(x: jnp.ndarray, w: jnp.ndarray, key, recipe: QuantRecipe):
    return _int8_forward(x, w, recipe)


def _qlinear_int8_fwd(x, w, key, recipe):
    y = _int8_forward(x, w, recipe)
    # residuals: same qdq grid the kernel quantized onto
    xq = fake_quant_nograd(x, recipe.acts)
    wq = fake_quant_nograd(w, recipe.weights)
    return y, (xq, wq, key, x.shape)


_qlinear_int8.defvjp(_qlinear_int8_fwd, _qlinear_bwd)


def int8_quantized_linear(x: jnp.ndarray, w: jnp.ndarray, recipe: QuantRecipe,
                          key: Optional[jax.Array] = None) -> jnp.ndarray:
    """W8A8 linear with real integer compute on the forward (TPU MXU path;
    interpret-mode on CPU).  Caller must check :func:`int8_backend_supported`;
    unsupported recipes should route to :func:`quantized_linear` instead."""
    if not int8_backend_supported(recipe):
        raise ValueError(
            f"recipe [{recipe.describe() if recipe else 'fp'}] is outside the "
            "int8 kernel contract; use quantized_linear")
    return _qlinear_int8(x, w, key, recipe)
