"""Quantized linear layer with the paper's Fig-1 forward/backward semantics.

Forward  : y = qdq_A(x) @ qdq_W(w)
Backward : dx = g        @ qdq_W(w)^T        (REAL-valued g -- paper finds that
                                              propagating quantization error
                                              through the input-gradient path
                                              destabilizes training, Fig. 10)
           dW = qdq_A(x)^T @ qdq_G(g)        (output-grad quantized ONLY on the
                                              weight-update path)
STE everywhere: the w / x cotangents pass straight through their quantizers.

``grads_dx`` in the recipe turns on the paper's instability ablation where the
dx path also sees quantized gradients.

Two implementations share these semantics:

* the fake-quant reference (fp einsums over qdq'd tensors -- the paper's
  simulation methodology; symmetric nearest codecs store their custom-vjp
  residuals as int8 QState payloads and dequantize-on-read, ~4x less residual
  memory with bit-identical values, no kernel dependency), and
* the real-int8 Pallas path (:func:`int8_quantized_linear`): the forward
  quantizes each operand ONCE into int8 payload + scales, runs the W8A8 MXU
  kernel, and threads the payloads through as custom_vjp residuals (~4x less
  residual memory than qdq'd fp copies).  When the recipe also carries an
  in-contract G8 spec (:func:`int8_bwd_supported`) both backward matmuls run
  on transposed int8 kernels against the stored payloads; otherwise the
  backward dequantizes-on-read and replays the reference vjp bit-exactly.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadam import QState
from repro.core.qconfig import Granularity, QuantRecipe, RoundMode
from repro.core.quantizer import (dequantize_int, fake_quant_nograd,
                                  maybe_fake_quant, quantize_int)


def _flat2d(a: jnp.ndarray) -> jnp.ndarray:
    return a.reshape(-1, a.shape[-1])


def _train_fake_quant(x: jnp.ndarray, spec, key=None) -> jnp.ndarray:
    """``fake_quant_nograd`` with the hot symmetric 2-D cases routed through
    the fused Pallas kernel (one HBM round trip instead of three -- see
    kernels/qdq.py).  The route engages where the kernel actually compiles
    (TPU); under interpret mode (CPU) the reference einsum is both the oracle
    and the faster path.  ``REPRO_FUSED_FQ=1/0`` forces the choice either way
    (tests pin ``1`` to exercise the routed path off-TPU)."""
    force = os.environ.get("REPRO_FUSED_FQ", "")
    fused = (force == "1") if force in ("0", "1") \
        else jax.default_backend() == "tpu"
    if fused and key is None:
        from repro.kernels import ops              # lazy: pallas import
        if ops.fused_fake_quant_eligible(spec, x):
            return ops.fused_fake_quant(x, spec)
    return fake_quant_nograd(x, spec, key)


def residual_compressible(spec) -> bool:
    """Can the custom-vjp residual for this operand be stored as an int8
    ``QState`` (payload + scales) instead of the qdq'd fp copy?  Requires a
    codec whose ``dequantize_int(quantize_int(x))`` reproduces
    ``fake_quant_nograd(x)`` bit-exactly: symmetric (zero == 0 by
    construction, so only scale multiplies on read), nearest rounding (no key
    stream to replay), <= 8 bits (int8 payload), no sqrt domain.  Blockwise
    codecs qualify -- the stored shape recovers the tail padding."""
    return (spec is not None and spec.symmetric
            and spec.round_mode is RoundMode.NEAREST
            and spec.bits <= 8 and not spec.sqrt_domain)


def _encode_residual(t: jnp.ndarray, spec):
    """(value the matmul consumes, residual to store).  Compressible specs
    pay the quantize ONCE and keep the int8 payload (~4x smaller residual --
    the PR-3 trick, no kernel dependency); everything else stores the qdq'd
    fp tensor as before."""
    if spec is None:
        return t, t
    if residual_compressible(spec):
        q, scale, zero = quantize_int(t, spec)
        deq = dequantize_int(q, scale, zero, spec, shape=t.shape,
                             dtype=t.dtype)
        return deq, QState(q, scale, zero)
    tq = _train_fake_quant(t, spec)
    return tq, tq


def _decode_residual(res, spec, shape, dtype) -> jnp.ndarray:
    """Dequantize-on-read: recover the exact tensor the forward matmul
    consumed from either residual representation."""
    if isinstance(res, QState):
        return dequantize_int(res.q, res.scale, res.zero, spec, shape=shape,
                              dtype=dtype)
    return res


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear(x: jnp.ndarray, w: jnp.ndarray, key, recipe: QuantRecipe):
    xq = maybe_fake_quant(x, recipe.acts)
    wq = maybe_fake_quant(w, recipe.weights)
    return jnp.matmul(xq, wq)


def _qlinear_fwd(x, w, key, recipe):
    # Error injection happens here; the residuals hold the *quantized*
    # tensors (they are what the matmul actually consumed) -- as int8
    # QState payloads when the codec allows, qdq'd fp copies otherwise.
    xv, xr = _encode_residual(x, recipe.acts)
    wv, wr = _encode_residual(w, recipe.weights)
    y = jnp.matmul(xv, wv)
    return y, (xr, wr, key, x.shape, w.shape,
               jnp.zeros((0,), x.dtype), jnp.zeros((0,), w.dtype))


def _qlinear_bwd(recipe, res, g):
    xr, wr, key, x_shape, w_shape, x_proto, w_proto = res
    xq = _decode_residual(xr, recipe.acts, x_shape, x_proto.dtype)
    wq = _decode_residual(wr, recipe.weights, w_shape, w_proto.dtype)
    return _qlinear_bwd_core(recipe, xq, wq, key, x_shape, g)


def _qlinear_bwd_core(recipe, xq, wq, key, x_shape, g):
    """Reference Fig-1 vjp over the (dequantized) forward operands -- shared
    by the fake-quant path and the int8 path's out-of-contract fallback."""
    # Independent subkeys per backward path: when both grads_dx and grads are
    # stochastic, the dW rounding noise must be uncorrelated with the dx
    # noise (and neither path may consume the caller's parent key raw).
    k_dx = k_dw = None
    if key is not None:
        key_dx, key_dw = jax.random.split(key)
        if (recipe.grads_dx is not None
                and recipe.grads_dx.round_mode is RoundMode.STOCHASTIC):
            k_dx = key_dx
        if (recipe.grads is not None
                and recipe.grads.round_mode is RoundMode.STOCHASTIC):
            k_dw = key_dw

    # --- dx path: real-valued output gradient (paper Fig. 1). -------------
    g_dx = g
    if recipe.grads_dx is not None:                      # instability ablation
        g_dx = _train_fake_quant(g, recipe.grads_dx, k_dx)
    dx = jnp.matmul(g_dx, wq.T).reshape(x_shape)

    # --- dW path: quantized output gradient. ------------------------------
    g_dw = g
    if recipe.grads is not None:
        g_dw = _train_fake_quant(g, recipe.grads, k_dw)
    g2 = _flat2d(g_dw)
    x2 = _flat2d(xq)
    dw = jax.lax.dot_general(
        x2, g2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(wq.dtype)

    key_ct = (None if key is None
              else np.zeros(key.shape, dtype=jax.dtypes.float0))
    return dx, dw, key_ct


_qlinear.defvjp(_qlinear_fwd, _qlinear_bwd)


def quantized_linear(x: jnp.ndarray, w: jnp.ndarray, recipe: Optional[QuantRecipe],
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Public entry point.  Falls back to a plain matmul when the recipe does
    not quantize any linear-layer component (keeps the fp baseline's HLO free
    of custom_vjp scaffolding)."""
    if recipe is None or not recipe.any_linear_quant:
        return jnp.matmul(x, w)
    return _qlinear(x, w, key, recipe)


# ---------------------------------------------------------------------------
# Real-int8 backend: the Pallas W8A8 kernel replaces the fake-quant einsum on
# the forward, each operand is quantized exactly ONCE and threaded through as
# an int8 QState residual (payload + scales, ~4x smaller than the qdq'd fp
# copies), and -- when the recipe carries an in-contract G8 spec -- both
# backward matmuls run on the transposed int8 kernels against those stored
# payloads.  Out-of-contract backwards dequantize-on-read and replay the
# reference Fig-1 vjp (dequantize_int reproduces fake_quant_nograd
# bit-exactly: same scale, round, clip, cast).
# ---------------------------------------------------------------------------

_INT8_GRANS_W = (Granularity.PER_CHANNEL, Granularity.PER_TENSOR)
_INT8_GRANS_A = (Granularity.PER_TOKEN, Granularity.PER_TENSOR)


def int8_backend_supported(recipe: Optional[QuantRecipe]) -> bool:
    """True when the recipe's forward is expressible as the int8 kernel's
    rank-1-rescale W8A8 contract: symmetric 8-bit weights+acts, nearest
    rounding, no block-wise codec (per-tensor/per-channel W x per-tensor/
    per-token A)."""
    if recipe is None:
        return False
    w, a = recipe.weights, recipe.acts
    return (w is not None and a is not None
            and w.bits == 8 and a.bits == 8
            and w.symmetric and a.symmetric
            and w.block_size == 0 and a.block_size == 0
            and not w.sqrt_domain and not a.sqrt_domain
            and w.round_mode is RoundMode.NEAREST
            and a.round_mode is RoundMode.NEAREST
            and w.granularity in _INT8_GRANS_W
            and a.granularity in _INT8_GRANS_A)


def int8_decode_attn_supported(spec) -> bool:
    """True when the fused Pallas decode-attention / q8-prefill kernels can
    consume a KV cache stored under ``spec`` (see kernels/decode_attn.py):
    symmetric 8-bit nearest-rounded PER_TOKEN -- one scale per (position,
    head) row, the sidecar layout the kernels fold in-register.  Per-tensor
    KV specs scale per *slot write block* (a reduction across heads and
    positions that cannot map onto the per-(slot, head) kernel grid) and stay
    on the dequantize-on-read reference path."""
    return (spec is not None and spec.bits == 8 and spec.symmetric
            and spec.block_size == 0 and not spec.sqrt_domain
            and spec.round_mode is RoundMode.NEAREST
            and spec.granularity is Granularity.PER_TOKEN)


def int8_bwd_supported(recipe: Optional[QuantRecipe]) -> bool:
    """True when the backward is expressible as the transposed int8 kernels'
    contract: the forward contract plus a symmetric 8-bit nearest-rounded
    PER_TOKEN gradient spec and no dx-path ablation.

    The hardware path necessarily quantizes the output gradient on *both*
    backward matmuls (an int8 dot needs two int8 operands); the paper's
    Fig-1 semantics of a real-valued dx-path gradient survive only up to
    that 8-bit per-token rounding of g (with the weight scales folded in).
    Recipes outside this contract -- no G spec (fp dW path), stochastic
    rounding, ``grads_dx`` ablations, coarser granularities -- fall back to
    the reference vjp on dequantized residuals.
    """
    if not int8_backend_supported(recipe):
        return False
    g = recipe.grads
    return (g is not None and recipe.grads_dx is None
            and g.bits == 8 and g.symmetric
            and g.block_size == 0 and not g.sqrt_domain
            and g.round_mode is RoundMode.NEAREST
            and g.granularity is Granularity.PER_TOKEN)


def _int8_forward(x, w, recipe):
    from repro.kernels.ops import int8_linear    # lazy: pallas import
    return int8_linear(x, w, recipe.acts, recipe.weights, out_dtype=x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear_int8(x: jnp.ndarray, w: jnp.ndarray, key, recipe: QuantRecipe):
    return _int8_forward(x, w, recipe)


def _qlinear_int8_fwd(x, w, key, recipe):
    from repro.kernels.ops import int8_payload_linear   # lazy: pallas import
    x2 = _flat2d(x)
    xq, x_scale, _ = quantize_int(x2, recipe.acts)      # zero == 0 (symmetric)
    wq, w_scale, _ = quantize_int(w, recipe.weights)
    y = int8_payload_linear(xq, x_scale, wq, w_scale, out_dtype=x.dtype)
    y = y.reshape(*x.shape[:-1], w.shape[-1])
    # Residuals are the int8 payloads the MXU actually consumed -- stored as
    # QState (the optimizer-state / prepared-weight container) plus 0-size
    # dtype carriers so the backward can emit exactly-typed cotangents.
    zero = jnp.zeros((), jnp.float32)
    res = (QState(xq, x_scale, zero), QState(wq, w_scale, zero), key, x.shape,
           jnp.zeros((0,), x.dtype), jnp.zeros((0,), w.dtype))
    return y, res


def _qlinear_int8_bwd(recipe, res, g):
    xs, ws, key, x_shape, x_proto, w_proto = res
    if int8_bwd_supported(recipe):
        from repro.kernels.ops import int8_bwd_dw, int8_bwd_dx   # lazy
        g2 = _flat2d(g)
        dx = int8_bwd_dx(g2, ws.q, ws.scale,
                         out_dtype=x_proto.dtype).reshape(x_shape)
        dw = int8_bwd_dw(xs.q, xs.scale, g2, out_dtype=w_proto.dtype)
        key_ct = (None if key is None
                  else np.zeros(key.shape, dtype=jax.dtypes.float0))
        return dx, dw, key_ct
    # Out-of-contract recipe (fp dW path, stochastic g, grads_dx ablation,
    # coarse granularity): dequantize-on-read and replay the reference vjp.
    xq = dequantize_int(xs.q, xs.scale, xs.zero, recipe.acts,
                        dtype=x_proto.dtype)
    wq = dequantize_int(ws.q, ws.scale, ws.zero, recipe.weights,
                        dtype=w_proto.dtype)
    return _qlinear_bwd_core(recipe, xq, wq, key, x_shape, g)


_qlinear_int8.defvjp(_qlinear_int8_fwd, _qlinear_int8_bwd)


def int8_quantized_linear(x: jnp.ndarray, w: jnp.ndarray, recipe: QuantRecipe,
                          key: Optional[jax.Array] = None) -> jnp.ndarray:
    """W8A8 linear with real integer compute (TPU MXU path; interpret-mode on
    CPU): always on the forward, and on both backward matmuls too when
    :func:`int8_bwd_supported` accepts the recipe.  Caller must check
    :func:`int8_backend_supported`; unsupported recipes should route to
    :func:`quantized_linear` instead."""
    if not int8_backend_supported(recipe):
        raise ValueError(
            f"recipe [{recipe.describe() if recipe else 'fp'}] is outside the "
            "int8 kernel contract; use quantized_linear")
    return _qlinear_int8(x, w, key, recipe)
