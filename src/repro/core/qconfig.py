"""Quantization configuration for the paper's controlled study.

The paper (EMNLP 2024 Findings) quantizes four component groups of a
transformer during pre-training:

  * weights        (linear-layer weights, forward pass)
  * activations    (linear-layer inputs, forward pass)
  * gradients      (output-gradient used on the dW path only -- Fig. 1)
  * optimizer m1/m2 (Adam moments, stored quantized between steps)

Each component gets a :class:`QuantSpec` (bits / granularity / symmetry) and
the whole study is a :class:`QuantRecipe` bundling them.  ``QuantRecipe``
instances are plain frozen dataclasses so they hash into jit static args.
"""
from __future__ import annotations

import dataclasses
import enum
import re
from typing import Optional


class Granularity(str, enum.Enum):
    """Scale-factor granularity (paper Section 3.2).

    PER_TENSOR  : one scale for the whole tensor.
    PER_CHANNEL : one scale per feature channel (last dim for activations,
                  output dim for weights; "per-column" in the paper's
                  optimizer tables).
    PER_TOKEN   : one scale per token row (all dims except the last).
    """

    PER_TENSOR = "per_tensor"
    PER_CHANNEL = "per_channel"
    PER_TOKEN = "per_token"


class RoundMode(str, enum.Enum):
    NEAREST = "nearest"          # paper default: round-to-nearest
    STOCHASTIC = "stochastic"    # beyond-paper option for gradients


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """One component's quantization scheme (paper Eq. 1)."""

    bits: int = 8
    granularity: Granularity = Granularity.PER_TENSOR
    symmetric: bool = True               # z = 0 (paper default)
    round_mode: RoundMode = RoundMode.NEAREST
    # Beyond-paper: block-wise quantization (Dettmers et al. 2021) used to fix
    # the m2 divergence.  block_size == 0 disables blocking.
    block_size: int = 0
    # Beyond-paper codec for strictly-positive tensors (Adam m2): quantize in
    # sqrt-space so small values do not collapse into the zero bin (Fig. 12).
    sqrt_domain: bool = False

    def __post_init__(self):
        if self.bits < 2 or self.bits > 16:
            raise ValueError(f"unsupported bit width {self.bits}")
        if self.block_size < 0:
            raise ValueError("block_size must be >= 0")

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    def describe(self) -> str:
        sym = "sym" if self.symmetric else "asym"
        extra = ""
        if self.block_size:
            extra += f",block{self.block_size}"
        if self.sqrt_domain:
            extra += ",sqrt"
        if self.round_mode is RoundMode.STOCHASTIC:
            extra += ",sr"
        return f"int{self.bits}/{self.granularity.value}/{sym}{extra}"

    def describe_compact(self) -> str:
        """Compact codec form, e.g. ``8c-asym-b128-sqrt`` (see parse_spec)."""
        s = f"{self.bits}{_GRAN_TO_CODE[self.granularity]}"
        if not self.symmetric:
            s += "-asym"
        if self.round_mode is RoundMode.STOCHASTIC:
            s += "-sr"
        if self.block_size:
            s += f"-b{self.block_size}"
        if self.sqrt_domain:
            s += "-sqrt"
        return s


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Full pre-training quantization recipe (paper Section 4.5).

    ``None`` disables quantization for that component (fp path).

    ``grads`` quantizes the *output gradient on the dW path only*; the real
    valued gradient always flows to dx (paper Fig. 1).  ``grads_dx`` enables
    the paper's instability ablation (Fig. 10 top) where the input-gradient
    path is quantized too.
    """

    weights: Optional[QuantSpec] = None
    acts: Optional[QuantSpec] = None
    grads: Optional[QuantSpec] = None
    grads_dx: Optional[QuantSpec] = None     # ablation only -- diverges
    adam_m1: Optional[QuantSpec] = None
    adam_m2: Optional[QuantSpec] = None
    # Quantize embedding / lm-head linears too?  Paper scopes to transformer
    # block linears; embeddings stay fp by default.
    include_embeddings: bool = False

    def describe(self) -> str:
        parts = []
        for name in ("weights", "acts", "grads", "grads_dx", "adam_m1", "adam_m2"):
            spec = getattr(self, name)
            if spec is not None:
                parts.append(f"{name}={spec.describe()}")
        return "fp-baseline" if not parts else " ".join(parts)

    @property
    def any_linear_quant(self) -> bool:
        return any(s is not None for s in (self.weights, self.acts, self.grads, self.grads_dx))

    def describe_compact(self) -> str:
        """Compact string codec, the inverse of :func:`parse_recipe`:
        ``w8c,a8t,g8t,m1:4c``.  ``fp`` for the baseline recipe."""
        parts = []
        for code, name in _COMP_CODES.items():
            spec = getattr(self, name)
            if spec is not None:
                sep = ":" if code.startswith("m") else ""
                parts.append(f"{code}{sep}{spec.describe_compact()}")
        if self.include_embeddings:
            parts.append("emb")
        return "fp" if not parts else ",".join(parts)


# ---------------------------------------------------------------------------
# Presets used throughout the study / benchmarks.
# ---------------------------------------------------------------------------

def fp_baseline() -> QuantRecipe:
    return QuantRecipe()


def paper_recipe() -> QuantRecipe:
    """The paper's recommended recipe (Section 4.5): W8 per-channel + A8
    per-token, gradients and optimizer states left in fp."""
    return QuantRecipe(
        weights=QuantSpec(8, Granularity.PER_CHANNEL),
        acts=QuantSpec(8, Granularity.PER_TOKEN),
    )


def paper_recipe_wag8() -> QuantRecipe:
    """Section 4.5's 'all three' variant: W8/A8/G8 (worse -- gradient noise)."""
    return QuantRecipe(
        weights=QuantSpec(8, Granularity.PER_CHANNEL),
        acts=QuantSpec(8, Granularity.PER_TOKEN),
        grads=QuantSpec(8, Granularity.PER_TOKEN),
    )


def beyond_paper_recipe() -> QuantRecipe:
    """Beyond-paper: paper recipe + 4-bit per-channel m1 (paper shows it is
    feasible) + blockwise sqrt-domain 8-bit m2 (fixes the paper's Fig-12
    divergence)."""
    return QuantRecipe(
        weights=QuantSpec(8, Granularity.PER_CHANNEL),
        acts=QuantSpec(8, Granularity.PER_TOKEN),
        adam_m1=QuantSpec(4, Granularity.PER_CHANNEL),
        adam_m2=QuantSpec(8, Granularity.PER_CHANNEL, symmetric=False,
                          block_size=128, sqrt_domain=True),
    )


PRESETS = {
    "fp": fp_baseline,
    "paper": paper_recipe,
    "paper_wag8": paper_recipe_wag8,
    "beyond": beyond_paper_recipe,
}


def get_recipe(name: str) -> QuantRecipe:
    """Resolve a preset name OR a compact recipe string (``w8c,a8t``)."""
    if name in PRESETS:
        return PRESETS[name]()
    try:
        return parse_recipe(name)
    except ValueError as e:
        raise KeyError(
            f"unknown recipe {name!r}; options: {sorted(PRESETS)} "
            f"or a compact spec like 'w8c,a8t,g8t,m1:4c' ({e})") from None


# ---------------------------------------------------------------------------
# Compact string codec (inverse of describe_compact): ad-hoc recipes on the
# CLI without registering a preset -- e.g. ``--recipe w8c,a8t,m2:8c-b128-sqrt``.
# ---------------------------------------------------------------------------

_GRAN_CODES = {"c": Granularity.PER_CHANNEL, "t": Granularity.PER_TOKEN,
               "n": Granularity.PER_TENSOR}
_GRAN_TO_CODE = {v: k for k, v in _GRAN_CODES.items()}
# component codes; insertion order fixes describe_compact() field order
_COMP_CODES = {"w": "weights", "a": "acts", "g": "grads", "gx": "grads_dx",
               "m1": "adam_m1", "m2": "adam_m2"}

_SPEC_RE = re.compile(r"^(\d+)([ctn])((?:-(?:asym|sr|sqrt|b\d+))*)$")
_TOKEN_RE = re.compile(r"^(gx|g|w|a|m1|m2):?(.*)$")


def parse_spec(text: str) -> QuantSpec:
    """``<bits><gran>[-asym][-sr][-b<N>][-sqrt]`` -> QuantSpec.

    Granularity codes: ``c`` per-channel, ``t`` per-token, ``n`` per-tensor.
    """
    m = _SPEC_RE.match(text.strip())
    if not m:
        raise ValueError(f"bad quant spec {text!r} "
                         "(want e.g. '8c', '4t-sr', '8c-asym-b128-sqrt')")
    bits, gran, flags = int(m.group(1)), _GRAN_CODES[m.group(2)], m.group(3)
    kw = {}
    for flag in filter(None, flags.split("-")):
        if flag == "asym":
            kw["symmetric"] = False
        elif flag == "sr":
            kw["round_mode"] = RoundMode.STOCHASTIC
        elif flag == "sqrt":
            kw["sqrt_domain"] = True
        elif flag.startswith("b"):
            kw["block_size"] = int(flag[1:])
    return QuantSpec(bits, gran, **kw)


def parse_recipe(text: str) -> QuantRecipe:
    """Inverse of :meth:`QuantRecipe.describe_compact`.

    ``"w8c,a8t,g8t,m1:4c"`` -> W8 per-channel + A8 per-token + G8 per-token
    + 4-bit per-channel Adam m1.  ``"fp"`` (or empty) is the fp baseline;
    ``"emb"`` sets ``include_embeddings``.  ``+`` is accepted as a component
    separator so recipe strings can be embedded in comma-separated policy
    rules (``--policy '*=w8c+a8t'``).
    """
    text = text.strip()
    if text in ("", "fp"):
        return QuantRecipe()
    kw = {}
    for token in re.split(r"[,+]", text):
        token = token.strip()
        if not token:
            continue
        if token == "emb":
            kw["include_embeddings"] = True
            continue
        m = _TOKEN_RE.match(token)
        if not m:
            raise ValueError(f"bad recipe component {token!r} "
                             "(want e.g. 'w8c', 'a8t', 'm1:4c')")
        name = _COMP_CODES[m.group(1)]
        if name in kw:
            raise ValueError(f"duplicate component {m.group(1)!r} in {text!r}")
        kw[name] = parse_spec(m.group(2))
    return QuantRecipe(**kw)
