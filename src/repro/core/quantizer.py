"""Linear quantization primitives (paper Section 3.1, Eq. 1).

Everything here implements *fake quantization*: values are quantized to the
integer grid and immediately dequantized, so the error is injected while the
computation stays in floating point -- exactly the paper's methodology.  The
integer-storage variants (:func:`quantize_int` / :func:`dequantize_int`) back
the quantized optimizer states and the real-int8 Pallas kernels.

Scale granularity convention (uniform across the codebase):

  * PER_TENSOR  : scalar scale.
  * PER_CHANNEL : one scale per element of the LAST dim (for a weight stored
    as (in, out) that is the output channel; for activations the feature dim;
    the paper's "per-column" for optimizer states).
  * PER_TOKEN   : one scale per row, i.e. reduced over the LAST dim only.

Gradient flow uses the straight-through estimator (STE, Bengio et al. 2013):
d qdq(x)/dx == 1.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import Granularity, QuantSpec, RoundMode

_EPS = 1e-12


def _reduce_axes(ndim: int, granularity: Granularity) -> Optional[Tuple[int, ...]]:
    """Axes over which the scale statistic is computed (keepdims=True)."""
    if granularity is Granularity.PER_TENSOR:
        return tuple(range(ndim))
    if granularity is Granularity.PER_CHANNEL:
        # one scale per last-dim element -> reduce everything else
        return tuple(range(ndim - 1))
    if granularity is Granularity.PER_TOKEN:
        # one scale per row -> reduce last dim only
        return (ndim - 1,)
    raise ValueError(granularity)


def compute_scale_zero(x: jnp.ndarray, spec: QuantSpec,
                       axes: Optional[Tuple[int, ...]] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (scale, zero_point) with keepdims-shaped leading axes.

    Symmetric (paper default): s = absmax / P, z = 0.
    Asymmetric: full-range affine -- s = (max - min) / (P - N),
    z = round(min / s) - N, so min -> N and max -> P.  (The paper's prose
    formula wastes half the signed range; we use the standard full-range
    affine mapping which is what its asymmetric experiment intends.)

    ``axes`` overrides the granularity-derived reduction axes -- used where
    leading batch/stack dims must each keep their own grid (prepared stacked
    weights, per-slot KV write blocks) so the scale formula lives here once.
    """
    if axes is None:
        axes = _reduce_axes(x.ndim, spec.granularity)
    xf = x.astype(jnp.float32)
    if spec.symmetric:
        absmax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
        scale = jnp.maximum(absmax, _EPS) / spec.qmax
        zero = jnp.zeros_like(scale)
    else:
        xmin = jnp.min(xf, axis=axes, keepdims=True)
        xmax = jnp.max(xf, axis=axes, keepdims=True)
        scale = jnp.maximum(xmax - xmin, _EPS) / (spec.qmax - spec.qmin)
        zero = jnp.round(xmin / scale) - spec.qmin
    return scale, zero


def _round(x: jnp.ndarray, mode: RoundMode, key: Optional[jax.Array]) -> jnp.ndarray:
    if mode is RoundMode.NEAREST:
        return jnp.round(x)
    if key is None:
        raise ValueError("stochastic rounding requires a PRNG key")
    noise = jax.random.uniform(key, x.shape, dtype=x.dtype)
    return jnp.floor(x + noise)


def _fake_quant_raw(x: jnp.ndarray, spec: QuantSpec,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
    """quantize -> dequantize without STE wrapping (paper Eq. 1)."""
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    scale, zero = compute_scale_zero(xf, spec)
    x_int = jnp.clip(_round(xf / scale, spec.round_mode, key) - zero,
                     spec.qmin, spec.qmax)
    return (scale * (x_int + zero)).astype(orig_dtype)


def _blocked_view(x: jnp.ndarray, block_size: int) -> Tuple[jnp.ndarray, int]:
    """Flatten to (n_blocks, block_size), zero-padding the tail."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_size), n


def _fake_quant_blockwise(x: jnp.ndarray, spec: QuantSpec,
                          key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Beyond-paper: Dettmers-style block-wise quantization.

    The tensor is flattened into contiguous blocks of ``spec.block_size``; each
    block gets its own (asymmetric-capable) scale.  Localizes outliers so one
    large value cannot wipe out the resolution of the whole channel/tensor.
    """
    orig_dtype = x.dtype
    blocks, n = _blocked_view(x.astype(jnp.float32), spec.block_size)
    row_spec = QuantSpec(bits=spec.bits, granularity=Granularity.PER_TOKEN,
                         symmetric=spec.symmetric, round_mode=spec.round_mode)
    deq = _fake_quant_raw(blocks, row_spec, key)
    return deq.reshape(-1)[:n].reshape(x.shape).astype(orig_dtype)


def fake_quant_nograd(x: jnp.ndarray, spec: QuantSpec,
                      key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Fake quantization *without* gradient pass-through (used on values that
    are not differentiated through, e.g. optimizer states)."""
    if spec.sqrt_domain:
        # For strictly non-negative tensors (Adam m2).  sqrt expands small
        # magnitudes away from the zero bin (paper Fig. 12 failure mode).
        root = jnp.sqrt(jnp.maximum(x, 0.0))
        q = (_fake_quant_blockwise(root, spec, key) if spec.block_size
             else _fake_quant_raw(root, spec, key))
        return jnp.square(q).astype(x.dtype)
    if spec.block_size:
        return _fake_quant_blockwise(x, spec, key)
    return _fake_quant_raw(x, spec, key)


# ---------------------------------------------------------------------------
# STE-wrapped fake quantization (forward error injection, identity backward).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jnp.ndarray, spec: QuantSpec,
               key: Optional[jax.Array] = None) -> jnp.ndarray:
    return fake_quant_nograd(x, spec, key)


def _fq_fwd(x, spec, key=None):
    return fake_quant_nograd(x, spec, key), None


def _fq_bwd(spec, _res, g):
    # Straight-through estimator: gradient flows unchanged (key gets None).
    return (g, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def maybe_fake_quant(x: jnp.ndarray, spec: Optional[QuantSpec],
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """fp passthrough when the component is not quantized."""
    return x if spec is None else fake_quant(x, spec, key)


# ---------------------------------------------------------------------------
# Integer-storage codec (optimizer states, kernels, compressed collectives).
# ---------------------------------------------------------------------------

def storage_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int16


def quantize_int(x: jnp.ndarray, spec: QuantSpec,
                 key: Optional[jax.Array] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize to real integers.  Returns (q, scale, zero).

    q holds X_int of paper Eq. 1 in int8/int16 storage; sub-byte widths (4-bit)
    occupy the low bits of an int8 (packing is a storage-layer concern; the
    value range is what matters for fidelity).
    """
    if spec.block_size:
        blocks, _ = _blocked_view(x.astype(jnp.float32), spec.block_size)
        row_spec = QuantSpec(bits=spec.bits, granularity=Granularity.PER_TOKEN,
                             symmetric=spec.symmetric, round_mode=spec.round_mode)
        scale, zero = compute_scale_zero(blocks, row_spec)
        q = jnp.clip(_round(blocks / scale, spec.round_mode, key) - zero,
                     spec.qmin, spec.qmax)
        return q.astype(storage_dtype(spec.bits)), scale, zero
    scale, zero = compute_scale_zero(x, spec)
    xf = x.astype(jnp.float32)
    q = jnp.clip(_round(xf / scale, spec.round_mode, key) - zero,
                 spec.qmin, spec.qmax)
    return q.astype(storage_dtype(spec.bits)), scale, zero


def dequantize_int(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                   spec: QuantSpec, shape=None, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`quantize_int`.  ``shape`` is required for blockwise
    codecs (to strip tail padding)."""
    deq = scale * (q.astype(jnp.float32) + zero)
    if spec.block_size:
        if shape is None:
            raise ValueError("blockwise dequantize needs the original shape")
        n = 1
        for d in shape:
            n *= d
        deq = deq.reshape(-1)[:n].reshape(shape)
    return deq.astype(dtype)


def quant_error(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Elementwise |x - qdq(x)| -- used by diagnostics and property tests."""
    return jnp.abs(x.astype(jnp.float32) -
                   fake_quant_nograd(x, spec).astype(jnp.float32))
