"""Checkpointable, shard-aware batch loader over a corpus.

Supplies per-family batch dicts (tokens / patches / frames) matching
``repro.models.model_api`` input specs.  State = {"step": int} -- restoring a
checkpoint resumes the exact data stream (deterministic sharding, DESIGN.md
Section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.data.synthetic import SyntheticCorpus


@dataclasses.dataclass
class Loader:
    corpus: SyntheticCorpus
    cfg: ArchConfig
    batch_size: int                 # global batch
    seq_len: int
    dp_rank: int = 0
    dp_size: int = 1
    split: str = "train"
    step: int = 0

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])

    def peek(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Batch for an arbitrary step (pure; used for recovery/tests)."""
        step = self.step if step is None else step
        local = self.batch_size // self.dp_size
        cfg = self.cfg
        if cfg.family == "vlm":
            p = cfg.num_patches
            toks = self.corpus.batch(step, self.dp_rank, self.dp_size,
                                     batch_size=local,
                                     seq_len=self.seq_len - p,
                                     split=self.split)
            rng = np.random.RandomState((step * 31 + self.dp_rank) % 2**31)
            patches = rng.randn(local, p, cfg.d_model).astype(np.float32) * 0.1
            return {"patches": patches, "tokens": toks}
        if cfg.family == "encdec":
            enc_len = max(self.seq_len // max(cfg.frame_ratio, 1), 1)
            toks = self.corpus.batch(step, self.dp_rank, self.dp_size,
                                     batch_size=local, seq_len=self.seq_len,
                                     split=self.split)
            rng = np.random.RandomState((step * 37 + self.dp_rank) % 2**31)
            frames = rng.randn(local, enc_len, cfg.d_model).astype(
                np.float32) * 0.1
            return {"frames": frames, "tokens": toks}
        toks = self.corpus.batch(step, self.dp_rank, self.dp_size,
                                 batch_size=local, seq_len=self.seq_len,
                                 split=self.split)
        return {"tokens": toks}

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.peek()
        self.step += 1
        return batch

    def __iter__(self):
        return self
