"""Deterministic synthetic corpus (OpenWebText stand-in, DESIGN.md Section 7).

A mixture of order-2 Markov sources with shared sparse transition structure
plus periodic copy spans.  Properties that matter for the study:

* learnable: entropy well below ln(V), so validation-loss orderings between
  quantization schemes are meaningful;
* deterministic & shardable: ``batch(step, dp_rank, dp_size)`` is a pure
  function of (seed, step, rank) -- any pod can recompute any shard after a
  failure without coordination (fault-tolerance primitive);
* checkpoint-free: loader state is just the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 1234
    branching: int = 4          # candidate next-tokens per bigram state
    copy_period: int = 64       # every copy_period tokens, repeat a span
    copy_len: int = 16

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v, k = self.vocab_size, self.branching
        # sparse bigram transition table: (V, K) successors + logits
        self.succ = rng.randint(0, v, size=(v, k)).astype(np.int32)
        logits = rng.randn(v, k).astype(np.float64) * 1.5
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.probs = (p / p.sum(axis=1, keepdims=True)).astype(np.float64)
        self.cum = np.cumsum(self.probs, axis=1)

    def _gen(self, rng: np.random.RandomState, batch: int, length: int
             ) -> np.ndarray:
        out = np.empty((batch, length), np.int32)
        cur = rng.randint(0, self.vocab_size, size=batch).astype(np.int32)
        u = rng.random_sample((batch, length))
        for t in range(length):
            idx = (u[:, t, None] < self.cum[cur]).argmax(axis=1)
            cur = self.succ[cur, idx]
            out[:, t] = cur
        # copy spans: repeat the previous copy_len tokens periodically
        # (gives the model a long-range structure to learn)
        for start in range(self.copy_period, length - self.copy_len,
                           self.copy_period):
            out[:, start:start + self.copy_len] = \
                out[:, start - self.copy_len:start]
        return out

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1, *,
              batch_size: int, seq_len: int, split: str = "train"
              ) -> np.ndarray:
        """(batch_size, seq_len + 1) int32 tokens for this rank at this step.
        ``split='valid'`` draws from a disjoint seed stream."""
        tag = {"train": 0, "valid": 1 << 30}[split]
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + tag + step * 977 + dp_rank) % (2 ** 31))
        return self._gen(rng, batch_size, seq_len + 1)

    def entropy_floor(self, n: int = 8192) -> float:
        """Monte-Carlo estimate of the per-token entropy of the Markov part
        (the achievable CE floor, ignoring copy spans)."""
        ent = -np.sum(self.probs * np.log(self.probs), axis=1)
        rng = np.random.RandomState(0)
        seq = self._gen(rng, 1, n)[0]
        return float(ent[seq].mean())
