from repro.data.loader import Loader
from repro.data.synthetic import SyntheticCorpus

__all__ = ["Loader", "SyntheticCorpus"]
