"""Trace-level lint: quantization-scale placement in abstract jaxprs.

The HLO rules see the compiled artifact; this rule sees the *algebra*.  A
quantized matmul ``y = (xq @ wq) * (x_scale * w_scale)`` is only a valid
factorization when every scale is constant along its operand's contracted
axis -- a per-channel scale that varies along the contraction cannot be
pulled out of the dot, and multiplying it in beforehand silently changes
what the kernel computes (and forces an fp dequant XLA may then fuse out of
sight of the HLO counters).

``check_scale_contraction(fn, *args)`` traces ``fn`` abstractly with
:func:`jax.make_jaxpr`, marks every ``QState.scale`` leaf in ``args`` as a
taint source whose taint is *the set of axes the scale varies along* (size-1
and scalar scales carry no axes -- per-tensor scales commute with the dot
and legitimately pass), propagates axis-taints through elementwise ops,
broadcasts, transposes, reshapes, reductions and nested jaxprs, and reports
a :class:`~repro.lint.rules.Finding` for every ``dot_general`` whose
operand is scale-tainted along a contracted dimension.

Propagation is conservative: an unrecognized primitive taints all
non-singleton output axes, so a violation cannot be laundered through an
exotic op; false positives would show up as failures of the positive
contract tests on the real paths, which pin the rule's precision.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Sequence, Set, Tuple

import jax
from jax import core as jax_core

from repro.core.qadam import QState
from repro.lint.rules import Finding, Severity

AxisTaint = Set[int]  # axes of the value that vary because of a quant scale

RULE_ID = "scale-off-contracted-axis"


def _scale_mask(args) -> List[bool]:
    """Per-flattened-leaf mask: True where the leaf is a QState scale."""
    marked = jax.tree_util.tree_map(
        lambda x: QState(q=False, scale=True, zero=False)
        if isinstance(x, QState) else False,
        args, is_leaf=lambda x: isinstance(x, QState))
    return [bool(m) for m in jax.tree_util.tree_leaves(marked)]


def _aval_shape(v) -> Tuple[int, ...]:
    aval = getattr(v, "aval", None)
    return tuple(getattr(aval, "shape", ()) or ())


def _varying_axes(shape: Sequence[int]) -> AxisTaint:
    return {i for i, d in enumerate(shape) if d > 1}


def _get(taints: Dict[Any, AxisTaint], v) -> AxisTaint:
    if isinstance(v, jax_core.Literal):
        return set()
    return taints.get(v, set())


def _align_trailing(taint: AxisTaint, from_rank: int, to_rank: int) -> AxisTaint:
    """Map axis indices across a rank change under numpy trailing-axis
    broadcasting (rank-expand prepends axes)."""
    off = to_rank - from_rank
    return {a + off for a in taint if 0 <= a + off < to_rank}


def _elementwise(eqn, taints) -> AxisTaint:
    out_rank = len(_aval_shape(eqn.outvars[0]))
    merged: AxisTaint = set()
    for v in eqn.invars:
        merged |= _align_trailing(_get(taints, v), len(_aval_shape(v)), out_rank)
    return merged


def _sub_jaxprs(params) -> List[Tuple[Any, Any]]:
    """(jaxpr, consts) pairs found in an eqn's params, for call-like prims."""
    out = []
    for val in params.values():
        if isinstance(val, jax_core.ClosedJaxpr):
            out.append((val.jaxpr, val.consts))
        elif isinstance(val, jax_core.Jaxpr):
            out.append((val, []))
        elif isinstance(val, (tuple, list)):
            for item in val:
                if isinstance(item, jax_core.ClosedJaxpr):
                    out.append((item.jaxpr, item.consts))
    return out


def _dot_findings(eqn, taints, ctx_name: str, idx: int) -> List[Finding]:
    (lc, rc), _ = eqn.params["dimension_numbers"]
    out: List[Finding] = []
    for side, v, contracted in (("lhs", eqn.invars[0], lc),
                                ("rhs", eqn.invars[1], rc)):
        bad = _get(taints, v) & set(contracted)
        if bad:
            shape = _aval_shape(v)
            out.append(Finding(
                Severity.ERROR, RULE_ID, f"dot_general#{idx}", ctx_name,
                f"{side} operand {shape} is scale-tainted along contracted "
                f"axis/axes {sorted(bad)}: a per-channel quant scale varying "
                "on the contraction was multiplied in before the dot, so the "
                "int8 factorization is invalid"))
    return out


def _dot_out_taint(eqn, taints) -> AxisTaint:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0], eqn.invars[1]
    lshape, rshape = _aval_shape(lhs), _aval_shape(rhs)
    lfree = [a for a in range(len(lshape)) if a not in lc and a not in lb]
    rfree = [a for a in range(len(rshape)) if a not in rc and a not in rb]
    # output layout: batch dims, lhs free dims, rhs free dims
    out: AxisTaint = set()
    lt, rt = _get(taints, lhs), _get(taints, rhs)
    for o, (la, ra) in enumerate(zip(lb, rb)):
        if la in lt or ra in rt:
            out.add(o)
    for o, a in enumerate(lfree, start=len(lb)):
        if a in lt:
            out.add(o)
    for o, a in enumerate(rfree, start=len(lb) + len(lfree)):
        if a in rt:
            out.add(o)
    return out


def _propagate(jaxpr, in_taints: List[AxisTaint], ctx_name: str,
               counter=None) -> Tuple[List[AxisTaint], List[Finding]]:
    """Run axis-taint dataflow over one jaxpr; returns outvar taints plus
    all dot_general findings (including from nested jaxprs)."""
    counter = counter if counter is not None else itertools.count()
    taints: Dict[Any, AxisTaint] = {}
    for v, t in zip(jaxpr.invars, in_taints):
        if t:
            taints[v] = set(t)
    findings: List[Finding] = []

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        invars = eqn.invars
        any_taint = any(_get(taints, v) for v in invars)

        if name == "dot_general":
            idx = next(counter)
            findings.extend(_dot_findings(eqn, taints, ctx_name, idx))
            out = _dot_out_taint(eqn, taints)
            if out:
                taints[eqn.outvars[0]] = out
            continue

        subs = _sub_jaxprs(eqn.params)
        if subs:
            # call-like primitive (pjit / custom_vjp / scan / cond ...):
            # align our operand taints with the sub-jaxpr's trailing invars
            # (leading invars may be consts/carry not present here).
            for sub, _consts in subs:
                n = len(sub.invars)
                ops = list(invars)[-n:] if len(invars) >= n else list(invars)
                sub_in = [set()] * (n - len(ops)) + [_get(taints, v) for v in ops]
                sub_out, sub_f = _propagate(sub, sub_in, ctx_name, counter)
                findings.extend(sub_f)
                for ov, t in zip(eqn.outvars, sub_out):
                    if t:
                        taints[ov] = taints.get(ov, set()) | t
            continue

        if not any_taint:
            continue

        if name == "broadcast_in_dim":
            bdims = eqn.params["broadcast_dimensions"]
            t = _get(taints, invars[0])
            taints[eqn.outvars[0]] = {bdims[a] for a in t if a < len(bdims)}
        elif name == "transpose":
            perm = eqn.params["permutation"]
            t = _get(taints, invars[0])
            taints[eqn.outvars[0]] = {perm.index(a) for a in t}
        elif name == "squeeze":
            dims = set(eqn.params["dimensions"])
            t = _get(taints, invars[0])
            taints[eqn.outvars[0]] = {
                a - sum(1 for d in dims if d < a) for a in t if a not in dims}
        elif name == "reshape":
            in_shape = _aval_shape(invars[0])
            out_shape = _aval_shape(eqn.outvars[0])
            t = _get(taints, invars[0])
            in_sig = [a for a, d in enumerate(in_shape) if d > 1]
            out_sig = [a for a, d in enumerate(out_shape) if d > 1]
            if ([in_shape[a] for a in in_sig] == [out_shape[a] for a in out_sig]):
                # pure size-1 axis insertion/removal: map positionally
                remap = dict(zip(in_sig, out_sig))
                taints[eqn.outvars[0]] = {remap[a] for a in t if a in remap}
            else:
                taints[eqn.outvars[0]] = _varying_axes(out_shape)
        elif name.startswith("reduce_"):
            axes = set(eqn.params.get("axes", ()))
            t = _get(taints, invars[0])
            taints[eqn.outvars[0]] = {
                a - sum(1 for d in axes if d < a) for a in t if a not in axes}
        elif name in ("slice", "dynamic_slice", "pad", "rev",
                      "convert_element_type", "copy", "stop_gradient",
                      "reduce_precision", "round", "clamp", "sort", "gather",
                      "dynamic_update_slice", "concatenate", "select_n",
                      "optimization_barrier"):
            out_rank = len(_aval_shape(eqn.outvars[0]))
            merged: AxisTaint = set()
            for v in invars:
                merged |= {a for a in _get(taints, v) if a < out_rank}
            for ov in eqn.outvars:
                taints[ov] = set(merged)
        else:
            # elementwise default + conservative catch-all: a tainted input
            # taints every non-singleton output axis it can align with.
            known_ew = _elementwise(eqn, taints)
            for ov in eqn.outvars:
                shape = _aval_shape(ov)
                taints[ov] = (known_ew & _varying_axes(shape)) or (
                    _varying_axes(shape) if not known_ew and any_taint
                    and name not in ("iota",) else known_ew)

    return [_get(taints, v) for v in jaxpr.outvars], findings


def check_scale_contraction(fn, *args, name: str = "<fn>") -> List[Finding]:
    """Trace ``fn(*args)`` abstractly and report every ``dot_general``
    contracting over an axis along which a ``QState.scale`` input varies.
    Returns ``[]`` when every scale stays off every contracted axis."""
    mask = _scale_mask(args)
    closed = jax.make_jaxpr(fn)(*args)
    leaves = jax.tree_util.tree_leaves(args)
    in_taints: List[AxisTaint] = []
    for leaf, is_scale in zip(leaves, mask):
        shape = tuple(getattr(leaf, "shape", ()) or ())
        in_taints.append(_varying_axes(shape) if is_scale else set())
    # make_jaxpr flattens args in tree-leaf order, so invars align with mask
    if len(closed.jaxpr.invars) != len(in_taints):
        raise ValueError(
            f"invar/leaf mismatch tracing {name}: {len(closed.jaxpr.invars)} "
            f"invars vs {len(in_taints)} leaves")
    _, findings = _propagate(closed.jaxpr, in_taints, name)
    return findings
