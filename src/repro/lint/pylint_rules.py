"""Source-level AST lint: no env reads inside jit-traced function bodies.

The bug class: ``os.environ`` consulted inside a function that jax traces
(jit-decorated, jit-wrapped, a custom-vjp rule, or a Pallas kernel) is
evaluated ONCE at trace time and silently frozen into the compiled
artifact -- flipping the knob later changes the report but not the running
path.  PR 5's fix is the sanctioned pattern: snapshot the env at
construction and re-pin it around every (lazy) trace with a contextmanager,
so compiled path and reported path cannot diverge.

What counts as a *traced def* (lexically, within one file):

* a function decorated with ``jax.jit`` / ``jit`` / ``partial(jax.jit, ..)``
  / ``jax.custom_vjp`` / ``jax.custom_jvp`` / ``jax.checkpoint``;
* a function whose name is later passed as the first argument to
  ``jax.jit(...)`` / ``jit(...)`` / ``pl.pallas_call(...)`` / a
  ``defvjp(...)`` registration;
* every def nested inside one of those.

What counts as an *env read*: ``os.environ`` in any expression (attribute
access, subscript, ``.get``) and ``os.getenv(...)``.

Allowlisted:

* functions decorated with ``contextlib.contextmanager`` -- the pinning
  helper itself must touch ``os.environ``;
* any line carrying a ``# lint: env-ok`` comment -- the explicit escape
  hatch for a read that is genuinely trace-invariant.

This is a lexical single-file analysis on purpose: it cannot prove a
helper *called from* traced code is clean (that is what the HLO contracts
pin down), but it catches the direct form of the bug at review time for
free, with zero tracing.

A second rule (``swallowed-broad-except``) guards the fault-tolerance
surface: inside the recovery-path modules (``checkpoint/``, the guarded
train loop / sentinel / fault harness, the serving scheduler) a bare
``except:`` or ``except Exception/BaseException`` handler that does not
re-raise converts *detected* corruption into silent data loss -- exactly
the failure the hardened checkpoints exist to rule out.  Handlers that
re-raise (e.g. wrapping into ``CheckpointCorrupt``) pass; deliberate
park-the-error sites carry ``# lint: except-ok`` on the except line.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from repro.lint.rules import Finding, Severity

RULE_ID = "env-read-in-trace"

#: decorator / wrapper spellings that make a function traced
_TRACING_NAMES = {"jit", "custom_vjp", "custom_jvp", "checkpoint", "remat",
                  "pallas_call"}
_ALLOW_COMMENT = "# lint: env-ok"


def _tail_name(node: ast.AST) -> Optional[str]:
    """Rightmost identifier of a Name/Attribute chain (``jax.jit`` ->
    ``jit``); None for anything else."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_tracing_expr(node: ast.AST) -> bool:
    """Does this decorator / callee expression make its target traced?
    Handles bare names (``@jax.jit``) and configured forms
    (``@partial(jax.jit, donate_argnums=...)``, ``@jax.custom_vjp``...)."""
    if _tail_name(node) in _TRACING_NAMES:
        return True
    if isinstance(node, ast.Call):
        if _tail_name(node.func) in _TRACING_NAMES:
            return True
        if _tail_name(node.func) == "partial" and node.args:
            return _is_tracing_expr(node.args[0])
    return False


def _is_contextmanager(fn: ast.AST) -> bool:
    return any(_tail_name(d) == "contextmanager"
               for d in getattr(fn, "decorator_list", []))


def _jit_wrapped_names(tree: ast.Module) -> Set[str]:
    """Function names passed (anywhere in the module) to a tracing wrapper:
    ``jax.jit(_decode, ...)``, ``pl.pallas_call(kernel, ...)``, and
    ``x.defvjp(fwd, bwd)`` registrations."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _tail_name(node.func)
        if callee in _TRACING_NAMES:
            args = node.args[:1]
        elif callee == "defvjp":
            args = node.args
        else:
            continue
        for a in args:
            if isinstance(a, ast.Name):
                names.add(a.id)
    return names


class _EnvReads(ast.NodeVisitor):
    """Collect (lineno, spelling) of every os.environ / os.getenv use."""

    def __init__(self):
        self.hits: List[tuple] = []

    def visit_Attribute(self, node: ast.Attribute):
        if (node.attr == "environ" and isinstance(node.value, ast.Name)
                and node.value.id == "os"):
            self.hits.append((node.lineno, "os.environ"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if (_tail_name(node.func) == "getenv"
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "os"):
            self.hits.append((node.lineno, "os.getenv"))
        self.generic_visit(node)


def lint_source(source: str, filename: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns env-read findings."""
    tree = ast.parse(source, filename=filename)
    wrapped = _jit_wrapped_names(tree)
    lines = source.splitlines()

    def allowed(lineno: int) -> bool:
        return (0 < lineno <= len(lines)
                and _ALLOW_COMMENT in lines[lineno - 1])

    findings: List[Finding] = []
    seen_fns: Set[int] = set()

    def scan_traced(fn) -> None:
        """One traced def: every env read in its whole subtree (nested defs
        included) is trace-frozen."""
        if id(fn) in seen_fns:
            return
        seen_fns.add(id(fn))
        reads = _EnvReads()
        for stmt in fn.body:
            reads.visit(stmt)
        for lineno, spelling in reads.hits:
            if allowed(lineno):
                continue
            findings.append(Finding(
                Severity.ERROR, RULE_ID, f"line {lineno}", filename,
                f"{spelling} read inside traced function "
                f"{fn.name!r} (line {lineno}): the value is frozen at "
                "trace time -- snapshot it outside the trace and pin it "
                "with a contextmanager (see infer/engine._pinned_env), or "
                f"mark the line `{_ALLOW_COMMENT}` if it is trace-invariant"))

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_contextmanager(node):
            continue
        traced = (node.name in wrapped
                  or any(_is_tracing_expr(d) for d in node.decorator_list))
        if traced:
            scan_traced(node)
    return findings


EXCEPT_RULE_ID = "swallowed-broad-except"
_EXCEPT_ALLOW = "# lint: except-ok"
#: recovery-path modules where a swallowed broad except is a data-loss bug
#: (the serving engine joined the scope when its degradation ladder started
#: absorbing decode-step failures -- a silently swallowed one would skip
#: both the demotion and the re-raise on the bottom rung)
EXCEPT_SCOPE = ("checkpoint/", "train/loop.py", "train/sentinel.py",
                "train/faults.py", "infer/scheduler.py", "infer/engine.py")
_BROAD_EXC = {"Exception", "BaseException"}


def _is_broad_handler(node: ast.ExceptHandler) -> bool:
    """Bare ``except:``, ``except Exception/BaseException``, or a tuple
    containing one of them."""
    t = node.type
    if t is None:
        return True
    if isinstance(t, ast.Tuple):
        return any(_tail_name(e) in _BROAD_EXC for e in t.elts)
    return _tail_name(t) in _BROAD_EXC


def in_except_scope(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return any(seg in p for seg in EXCEPT_SCOPE)


def lint_excepts(source: str, filename: str = "<string>") -> List[Finding]:
    """The ``swallowed-broad-except`` rule for one recovery-path module:
    flag every broad handler that neither re-raises (a ``raise`` anywhere
    in the handler body, bare or wrapping) nor carries the
    ``# lint: except-ok`` marker on its except line."""
    tree = ast.parse(source, filename=filename)
    lines = source.splitlines()
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if not _is_broad_handler(node):
            continue
        if (0 < node.lineno <= len(lines)
                and _EXCEPT_ALLOW in lines[node.lineno - 1]):
            continue
        if any(isinstance(n, ast.Raise)
               for stmt in node.body for n in ast.walk(stmt)):
            continue
        spelled = "except:" if node.type is None else \
            f"except {_tail_name(node.type) or '...'}"
        findings.append(Finding(
            Severity.ERROR, EXCEPT_RULE_ID, f"line {node.lineno}", filename,
            f"broad handler `{spelled}` (line {node.lineno}) swallows "
            "errors on the recovery path: detected corruption or a dying "
            "writer/scheduler thread must propagate, not vanish.  Narrow "
            "the exception, re-raise (wrapping is fine), or mark the line "
            f"`{_EXCEPT_ALLOW}` with a justification"))
    return findings


def lint_path(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    findings = lint_source(source, filename=path)
    if in_except_scope(path):
        findings.extend(lint_excepts(source, filename=path))
    return findings


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (the CI entry point): the
    env-read rule everywhere, the broad-except rule inside
    :data:`EXCEPT_SCOPE`."""
    findings: List[Finding] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                findings.extend(lint_path(os.path.join(dirpath, fn)))
    return findings
