"""Analyzed view of one compiled HLO module for the rule engine.

:class:`HloModule` wraps ``parallel.hlo_count.parse_module`` output with the
graph facts every rule needs and no rule should re-derive:

* **reachability** -- the set of computations reachable from ENTRY.  Rules
  only fire on live code: compiled modules can retain dead computations
  (DCE'd branches, unused fusions) whose ops never execute;
* **donated parameters** -- ENTRY parameter numbers listed in the module
  header's ``input_output_alias`` map (``jax.jit(..., donate_argnums=...)``).
  The copy-free-aliasing rule checks no ``copy`` roots at one of these;
* **dataflow** -- per-computation def maps plus bounded backward walks over
  operand chains, with an op filter so rules can ask "does this value reach
  a quant round through elementwise ops only" without crossing a matmul.

Everything here is text-level static analysis: no jax tracing, no
compilation -- golden ``tests/fixtures/hlo`` modules exercise it directly.
"""
from __future__ import annotations

import math
import re
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from repro.parallel.hlo_count import (Instr, _DTYPE_BYTES, _OPERAND, _SHAPE,
                                      entry_name, parse_module,
                                      reachable_computations)

#: ``input_output_alias={ {0}: (0, {}, may-alias), {1,0}: (2, {}, ...) }`` --
#: one ``{output_index}: (param_number, {param_index}, kind)`` entry per
#: donated buffer; we need the param numbers.
_ALIAS_ENTRY = re.compile(r"\{[\d,\s]*\}\s*:\s*\((\d+),")


def _alias_blob(header: str) -> str:
    """The brace-balanced body of ``input_output_alias={...}`` (nested
    ``{output_index}`` / ``{param_index}`` braces defeat a regex)."""
    key = "input_output_alias={"
    start = header.find(key)
    if start < 0:
        return ""
    depth, i = 1, start + len(key)
    while i < len(header) and depth:
        depth += {"{": 1, "}": -1}.get(header[i], 0)
        i += 1
    return header[start + len(key):i - 1]

#: Ops that forward a buffer (or a view of one) without computing new values:
#: a copy whose operand chain crosses only these still copies the *donated*
#: bytes.  Anything else (fusion, dot, elementwise) produces a fresh buffer.
ALIASING_OPS = frozenset({
    "parameter", "copy", "copy-start", "copy-done", "bitcast", "tuple",
    "get-tuple-element", "optimization-barrier", "transpose", "reshape",
})

#: Elementwise / shape-preserving ops a quantize-round chain may cross; a
#: dot / reduce / scatter between two rounds means a genuinely new value was
#: computed, not the same tensor quantized twice.
QUANT_LOCAL_OPS = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "clamp",
    "select", "compare", "convert", "bitcast-convert", "broadcast",
    "reshape", "transpose", "bitcast", "copy", "negate", "abs", "sign",
    "floor", "ceil", "power", "exponential", "log", "tanh", "rsqrt", "sqrt",
})


def shape_of(type_str: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    """(dtype, dims) of the first array shape in an HLO type string, or
    (None, ()) for token/opaque types."""
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            return dtype, tuple(int(d) for d in dims.split(",") if d)
    return None, ()


def nelems(type_str: str) -> int:
    _, dims = shape_of(type_str)
    return int(math.prod(dims)) if dims else 1


def nbytes(type_str: str) -> int:
    dtype, dims = shape_of(type_str)
    if dtype is None:
        return 0
    return _DTYPE_BYTES[dtype] * (int(math.prod(dims)) if dims else 1)


def operand_head(ins: Instr) -> str:
    """The operand-list text of an instruction: ``rest`` up to the paren that
    closes the op's argument list.  Paren-balanced, not a naive split --
    tuple-typed operands (``get-tuple-element((f32[2], s8[4]) %t), index=0``)
    nest parens inside the list."""
    depth = 1
    for i, ch in enumerate(ins.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return ins.rest[:i]
    return ins.rest


def operand_names(ins: Instr) -> List[str]:
    """Instruction-operand names: ``%refs`` in the operand list only
    (computation references like ``to_apply=%region`` live after the operand
    list's closing paren and must not leak into dataflow walks)."""
    return _OPERAND.findall(operand_head(ins))


def operand_types(ins: Instr) -> List[Tuple[str, Tuple[int, ...]]]:
    """(dtype, dims) per operand, read from the inline-typed operand list."""
    return [(d, tuple(int(x) for x in dims.split(",") if x))
            for d, dims in _SHAPE.findall(operand_head(ins)) if d in _DTYPE_BYTES]


class HloModule:
    """Parsed + analyzed compiled module (see module docstring)."""

    def __init__(self, hlo: str):
        self.text = hlo
        self.comps: Dict[str, List[Instr]] = parse_module(hlo)
        self.entry: Optional[str] = entry_name(self.comps)
        self.reachable: List[str] = reachable_computations(self.comps)
        self._defs: Dict[str, Dict[str, Instr]] = {}

    # -- structure ---------------------------------------------------------

    def live_instrs(self) -> Iterable[Tuple[str, Instr]]:
        """(computation, instr) over reachable computations only."""
        for name in self.reachable:
            for ins in self.comps[name]:
                yield name, ins

    def defs(self, comp: str) -> Dict[str, Instr]:
        """name -> defining Instr within one computation."""
        if comp not in self._defs:
            self._defs[comp] = {i.name: i for i in self.comps.get(comp, [])}
        return self._defs[comp]

    def donated_params(self) -> Set[int]:
        """ENTRY parameter numbers donated via input_output_alias."""
        header = self.text.splitlines()[0] if self.text else ""
        return {int(p) for p in _ALIAS_ENTRY.findall(_alias_blob(header))}

    # -- dataflow ----------------------------------------------------------

    def walk_back(self, comp: str, ins: Instr,
                  through: FrozenSet[str]) -> List[Instr]:
        """Transitive operand producers of ``ins`` within ``comp``, walking
        only *through* instructions whose op is in ``through`` (the
        frontier instructions themselves -- where the walk stopped -- are
        included in the result, so callers can inspect what the chain hit)."""
        seen: Set[str] = set()
        out: List[Instr] = []
        frontier = list(operand_names(ins))
        defs = self.defs(comp)
        while frontier:
            name = frontier.pop()
            if name in seen:
                continue
            seen.add(name)
            producer = defs.get(name)
            if producer is None:
                continue
            out.append(producer)
            if producer.op in through:
                frontier.extend(operand_names(producer))
        return out

    def param_number(self, ins: Instr) -> Optional[int]:
        if ins.op != "parameter":
            return None
        m = re.match(r"(\d+)\)", ins.rest.strip())
        return int(m.group(1)) if m else None
