"""Path contracts: the declarative invariants of this repo's fast paths.

Each :class:`PathContract` names one compiled fast path, pins the env
snapshot that selects it, builds the *real* lowered module (via
``Engine.lowered_decode_hlo`` / ``train.step.lower_train_hlo`` /
``optim.adamw.lower_update_hlo`` -- the same jits production runs, not
reconstructions), and binds :mod:`repro.lint.rules` rule specs plus
jaxpr-level checks to it.  ``python -m repro.lint`` runs them; the tests'
former ad-hoc ``count_ops`` assertions live here as the single source of
truth.

Size thresholds are derived from the built path (e.g. the whole-cache
dequant floor is the actual per-layer cache buffer element count), so
contracts stay valid when the smoke config changes shape.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.lint.rules import Finding, RuleSpec, Severity, run_rules

#: Decode-state buffers below this many bytes are bookkeeping (positions,
#: rng keys, step counters) -- copies of those are not an aliasing failure.
_COPY_MIN_BYTES = 1024


@dataclasses.dataclass(frozen=True)
class PathContract:
    name: str
    path: str           # contract group: "decode" | "train" | "opt"
    description: str
    env: Dict[str, str]
    #: config name -> (compiled HLO text, HLO rule specs, extra findings
    #: from non-HLO checks such as jaxpr rules)
    build: Callable[[str], Tuple[str, List[RuleSpec], List[Finding]]]

    def check(self, config: str) -> List[Finding]:
        with _pinned(self.env):
            hlo, specs, extra = self.build(config)
        return run_rules(hlo, specs) + list(extra)


@contextlib.contextmanager
def _pinned(env: Dict[str, str]):
    old = {k: os.environ.get(k) for k in env}
    os.environ.update(env)
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _norm_config(config: str) -> str:
    """CLI spelling ``gpt2_small`` -> registry spelling ``gpt2-small``."""
    return config.replace("_", "-")


_MODEL_CACHE: Dict[str, tuple] = {}


def _gpt2(config: str):
    """(cfg, model, params) for one smoke config, cached per process --
    several contracts lower the same model."""
    config = _norm_config(config)
    if config not in _MODEL_CACHE:
        import dataclasses as _dc

        from repro.configs import get_smoke_config
        from repro.models import build_model
        # float32 everywhere: the contracts are structural, and fp32 keeps
        # the lowered modules identical across hosts with/without bf16
        cfg = _dc.replace(get_smoke_config(config), dtype="float32")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        _MODEL_CACHE[config] = (cfg, model, params)
    return _MODEL_CACHE[config]


def _prepared_linear_jaxpr_findings(policy_str: str) -> List[Finding]:
    """Jaxpr rule (scale-off-contracted-axis) on the prepared-weight linear
    closure the decode path dispatches to."""
    from repro.core.qpolicy import LinearCtx, as_policy
    from repro.infer.prepare import quantize_weight
    from repro.lint.jaxpr_rules import check_scale_contraction
    pol = as_policy(policy_str)
    ctx = LinearCtx("mlp_up")
    spec = pol.resolve(ctx).recipe.weights
    w = jnp.linspace(-1.0, 1.0, 64 * 48).reshape(64, 48)
    wq = quantize_weight(w, spec)
    x = jnp.zeros((4, 64), jnp.float32)
    return check_scale_contraction(
        lambda x_, wq_: pol.linear(ctx, x_, wq_), x, wq,
        name=f"policy.linear[prepared,{policy_str}]")


def _int8_bwd_jaxpr_findings(policy_str: str) -> List[Finding]:
    """Jaxpr rule on the int8 custom-vjp backward closure: residual QState
    scales must stay off both backward dots' contracted axes."""
    from repro.core.qadam import QState
    from repro.core.qlinear import _qlinear_int8_bwd
    from repro.core.qpolicy import LinearCtx, as_policy
    from repro.lint.jaxpr_rules import check_scale_contraction
    recipe = as_policy(policy_str).resolve(LinearCtx("mlp_up")).recipe
    M, K, N = 4, 64, 48
    zero = jnp.zeros((), jnp.float32)
    xs = QState(jnp.zeros((M, K), jnp.int8), jnp.ones((M, 1), jnp.float32),
                zero)
    ws = QState(jnp.zeros((K, N), jnp.int8), jnp.ones((1, N), jnp.float32),
                zero)
    g = jnp.zeros((M, N), jnp.float32)
    proto = jnp.zeros((0,), jnp.float32)

    def bwd(xs_, ws_, g_):
        return _qlinear_int8_bwd(recipe, (xs_, ws_, None, (M, K),
                                          proto, proto), g_)

    return check_scale_contraction(bwd, xs, ws, g,
                                   name=f"qlinear_int8_bwd[{policy_str}]")


# ---------------------------------------------------------------------------
# contract builders
# ---------------------------------------------------------------------------

def _build_decode_prepared(config: str):
    """Prepared-int8 weights, fp KV: a decode step must contain zero quant
    rounds (weights enter as stored payloads; nothing quantizes in-trace)."""
    cfg, model, params = _gpt2(config)
    from repro.core.qpolicy import as_policy
    from repro.infer.prepare import prepare_params
    policy = as_policy("*=w8c")
    prep = prepare_params(cfg, params, policy)
    state = model.init_decode_state(2, 16, 0, jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 4, jnp.int32)

    def dec(p, s, t, q):
        return model.decode(p, s, t, q, policy=policy)

    hlo = jax.jit(dec).lower(prep, state, tok, pos).compile().as_text()
    specs = [RuleSpec("no-weight-quant-rounds", {"max_rounds": 0}),
             RuleSpec("double-quantize")]
    return hlo, specs, _prepared_linear_jaxpr_findings("*=w8c")


def _build_decode_fused_kv(config: str):
    """Fused int8-KV decode attention via the Engine: no whole-cache
    dequantize, no quant rounds beyond the one new-row cache write per
    K/V stack, and the donated decode state stays copy-free (the ROADMAP
    donated-aliasing invariant)."""
    cfg, model, params = _gpt2(config)
    from repro.infer import Engine
    eng = Engine(model, params, "kv_cache=a8t,*=w8c",
                 max_slots=2, max_seq=32)
    hlo = eng.lowered_decode_hlo()
    caches = eng._state["caches"]
    _, b, s, kh, hd = caches["k"].shape
    cache_elems = b * s * kh * hd
    specs = [RuleSpec("no-whole-cache-dequant",
                      {"min_elems": cache_elems, "dims": (b, s, kh, hd)}),
             RuleSpec("copy-free-aliasing", {"min_bytes": _COPY_MIN_BYTES}),
             RuleSpec("double-quantize"),
             # the only legitimate in-trace rounds are the new K/V row
             # quantize on the cache write -- bounded, not zero
             RuleSpec("op-count",
                      {"op_prefix": "round-nearest",
                       "min_count": 0, "max_count": 2 * cfg.n_layers},
                      severity=Severity.ERROR)]
    return hlo, specs, _prepared_linear_jaxpr_findings("kv_cache=a8t,*=w8c")


def _build_decode_paged(config: str):
    """Paged int8-KV decode via the Engine: the page indirection must stay
    an indirection -- zero whole-cache dequant converts at any paged view
    shape, no gather materializing a full per-slot logical view, donated
    page pools copy-free, and only the per-stack new-row quantize rounds."""
    cfg, model, params = _gpt2(config)
    from repro.infer import Engine
    eng = Engine(model, params, "kv_cache=a8t,*=w8c",
                 max_slots=2, max_seq=32, paged=True, page_size=16)
    hlo = eng.lowered_decode_hlo()
    caches = eng._state["caches"]
    _, npages, page, kh, hd = caches["k"].shape
    b = eng.max_slots
    maxp = eng.pool.max_pages_per_slot
    view_elems = b * maxp * page * kh * hd      # one full per-slot KV view
    pool_elems = npages * page * kh * hd        # the whole physical pool
    specs = [
        # the pool itself, the gathered (B, maxp, page, ...) pages, and the
        # flattened (B, maxp*page, ...) view are all whole-cache dequants
        RuleSpec("no-whole-cache-dequant",
                 {"min_elems": pool_elems, "dims": (npages, page, kh, hd)}),
        RuleSpec("no-whole-cache-dequant",
                 {"min_elems": view_elems, "dims": (b, maxp, page, kh, hd)}),
        RuleSpec("no-whole-cache-dequant",
                 {"min_elems": view_elems, "dims": (b, maxp * page, kh, hd)}),
        RuleSpec("no-large-gather",
                 {"min_elems": view_elems,
                  "dims": (b, maxp, page, kh, hd)}),
        RuleSpec("no-large-gather",
                 {"min_elems": view_elems,
                  "dims": (b, maxp * page, kh, hd)}),
        RuleSpec("copy-free-aliasing", {"min_bytes": _COPY_MIN_BYTES}),
        RuleSpec("double-quantize"),
        RuleSpec("op-count",
                 {"op_prefix": "round-nearest",
                  "min_count": 0, "max_count": 2 * cfg.n_layers})]
    return hlo, specs, []


def _sharded_decode_hlo(config: str) -> Tuple[str, Tuple[int, ...], int]:
    """(compiled HLO text, per-shard dense cache shape, n_layers) for the
    fused int8-KV decode step lowered under a dp4 x tp2 mesh.

    The lint process usually sees one CPU device, so the mesh build runs in
    a child interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_
    count=8`` (the same forced host mesh the sharded-serve tests use); when
    the current process already has >= 8 devices the build stays in-process.
    """
    config = _norm_config(config)

    def build():
        import dataclasses as _dc

        import numpy as np
        from jax.sharding import Mesh

        from repro.configs import get_smoke_config
        from repro.infer import Engine
        from repro.models import build_model
        cfg = _dc.replace(get_smoke_config(config), dtype="float32")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(4, 2),
                    ("data", "model"))
        eng = Engine(model, params, "kv_cache=a8t,*=w8c",
                     max_slots=2, max_seq=32, mesh=mesh, aot=False)
        hlo = eng.lowered_decode_hlo()
        return hlo, tuple(eng._state["caches"]["k"].shape), cfg.n_layers

    if jax.device_count() >= 8:
        return build()

    import json
    import subprocess
    import sys

    import repro
    src_root = os.path.dirname(list(repro.__path__)[0])
    prog = (
        "import json, sys\n"
        "import jax\n"
        "from repro.lint.contracts import _sharded_decode_hlo\n"
        f"hlo, shape, nl = _sharded_decode_hlo({config!r})\n"
        "json.dump({'hlo': hlo, 'shape': shape, 'n_layers': nl},"
        " sys.stdout)\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               REPRO_FUSED_DECODE="1",
               PYTHONPATH=src_root + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError("sharded decode build subprocess failed:\n"
                           + out.stderr[-4000:])
    blob = json.loads(out.stdout)
    return blob["hlo"], tuple(blob["shape"]), blob["n_layers"]


def _build_decode_sharded(config: str):
    """Fused int8-KV decode under SPMD (dp4 x tp2 forced host mesh): the
    per-shard partitioned module must keep every single-device invariant --
    zero weight-quant rounds beyond the per-stack cache-row writes, no
    whole-cache dequantize at the *local* (kv-heads / tp) shard shape, and
    the donated per-shard decode state copy-free."""
    hlo, cache_shape, n_layers = _sharded_decode_hlo(config)
    # the compiled SPMD module is the per-partition program: cache dims are
    # already local (kv axis divided by tp), so thresholds derive from them
    _, b, s, kh_local, hd = cache_shape
    tp = 2
    kh_local //= tp
    cache_elems = b * s * kh_local * hd
    specs = [RuleSpec("no-whole-cache-dequant",
                      {"min_elems": cache_elems,
                       "dims": (b, s, kh_local, hd)}),
             RuleSpec("copy-free-aliasing", {"min_bytes": _COPY_MIN_BYTES}),
             RuleSpec("double-quantize"),
             # zero weight-quant rounds: the only rounds sharding may leave
             # in-trace are the per-stack new K/V row writes (2 per layer),
             # exactly the single-device fused-kv budget -- a partitioner
             # that re-quantized weights or re-encoded shards would exceed it
             RuleSpec("op-count",
                      {"op_prefix": "round-nearest",
                       "min_count": 0, "max_count": 2 * n_layers},
                      severity=Severity.ERROR)]
    return hlo, specs, []


def _build_train_int8(config: str):
    """Real-int8 train step (fwd + bwd + optimizer): integer MXU dots must
    be present -- 3 s32-result dots (fwd, dx, dw) per quantized linear
    role -- and nothing may quantize twice on one dataflow path."""
    cfg, model, params = _gpt2(config)
    from repro.optim.adamw import OptConfig
    from repro.train.step import lower_train_hlo
    policy = "*=w8c+a8t+g8t@int8_pallas"
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    hlo = lower_train_hlo(model, policy, opt)
    # 4 block-linear roles (attn qkv/out, mlp up/down) x 3 dots each; the
    # layer scan keeps one body instance, so the floor is per-body, not
    # per-layer
    specs = [RuleSpec("int8-compute-present", {"min_dots": 12}),
             RuleSpec("double-quantize")]
    return hlo, specs, _int8_bwd_jaxpr_findings(policy)


def _build_opt_fused_adam(config: str):
    """Fused 8-bit AdamW on the model's parameter tree: quantized moment
    encodes present in-trace (the int path actually runs), and the donated
    optimizer state stays copy-free across the fused bucket launches."""
    cfg, model, params = _gpt2(config)
    from repro.core.qconfig import parse_recipe
    from repro.optim.adamw import OptConfig, lower_update_hlo
    recipe = parse_recipe("m1:8c-b128,m2:8c-asym-b128-sqrt")
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                    state_storage="int")
    hlo = lower_update_hlo(params, recipe, opt)
    # XLA-CPU inserts small defensive copies of the fp-loop moment leaves
    # (biases / norm scales, a few KB); the buffers the fused path donates
    # are the quantized bucket payloads (hundreds of KB) -- gate on those
    specs = [RuleSpec("copy-free-aliasing", {"min_bytes": 1 << 14}),
             RuleSpec("double-quantize"),
             # both moments re-encode every step: rounds must be present
             # (their absence = silent fp/fake fallback)
             RuleSpec("op-count", {"op_prefix": "round-nearest",
                                   "min_count": 2})]
    return hlo, specs, []


CONTRACTS: List[PathContract] = [
    PathContract(
        name="decode-prepared",
        path="decode",
        description="prepared-int8 weight decode holds zero quant rounds",
        env={"REPRO_FUSED_DECODE": "0"},
        build=_build_decode_prepared),
    PathContract(
        name="decode-fused-kv",
        path="decode",
        description="fused int8-KV decode: no whole-cache dequant, "
                    "donated state copy-free",
        env={"REPRO_FUSED_DECODE": "1"},
        build=_build_decode_fused_kv),
    PathContract(
        name="decode-paged",
        path="decode",
        description="paged int8-KV decode: page indirection intact, no "
                    "whole-view gather/dequant, pools copy-free",
        env={"REPRO_FUSED_DECODE": "1"},
        build=_build_decode_paged),
    PathContract(
        name="decode-sharded",
        path="decode",
        description="SPMD fused int8-KV decode (dp4 x tp2 host mesh): "
                    "per-shard module keeps every single-device invariant",
        env={"REPRO_FUSED_DECODE": "1"},
        build=_build_decode_sharded),
    PathContract(
        name="train-int8",
        path="train",
        description="int8 fwd+bwd train step emits real s32-result dots",
        env={},
        build=_build_train_int8),
    PathContract(
        name="opt-fused-adam",
        path="opt",
        description="fused 8-bit AdamW: moments re-encode in-trace, "
                    "donated state copy-free",
        env={"REPRO_FUSED_ADAM": "1"},
        build=_build_opt_fused_adam),
]


def contracts_for(path: str) -> List[PathContract]:
    if path == "all":
        return list(CONTRACTS)
    sel = [c for c in CONTRACTS if c.path == path]
    if not sel:
        raise ValueError(f"unknown path {path!r}; "
                         f"choose from decode/train/opt/all")
    return sel


def run_path(path: str, config: str) -> Dict[str, List[Finding]]:
    """Check every contract in one path group; contract name -> findings."""
    return {c.name: c.check(config) for c in contracts_for(path)}
