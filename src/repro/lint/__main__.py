"""CLI: check the repo's compiled fast paths against their contracts.

Usage::

    python -m repro.lint --path {decode,train,opt,all} --config gpt2_small

Traces and compiles the real paths (Engine decode step, train step,
optimizer update), runs every bound rule, prints findings, and exits
nonzero if any ERROR-severity finding fires -- the CI gate.
"""
from __future__ import annotations

import argparse
import sys

from repro.lint.contracts import contracts_for
from repro.lint.rules import Severity


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="static path-contract checks over compiled HLO + jaxprs")
    ap.add_argument("--path", default="all",
                    choices=["decode", "train", "opt", "all"],
                    help="which fast-path contract group to check")
    ap.add_argument("--config", default="gpt2_small",
                    help="smoke config to lower the paths on "
                         "(gpt2_small / gpt2_small-moe / ...)")
    ap.add_argument("--min-severity", default="INFO",
                    choices=[s.name for s in Severity],
                    help="hide findings below this severity")
    ap.add_argument("--repo", metavar="DIR", nargs="?", const="src/repro",
                    default=None,
                    help="also run the source-level AST lint (env reads in "
                         "traced bodies) over DIR [default: src/repro]")
    args = ap.parse_args(argv)

    floor = Severity[args.min_severity]
    n_err = 0
    if args.repo is not None:
        from repro.lint.pylint_rules import lint_tree
        print(f"[repo] env-read-in-trace: AST lint over {args.repo}")
        findings = lint_tree(args.repo)
        n_err += sum(1 for f in findings if f.severity >= Severity.ERROR)
        if not findings:
            print("  OK")
        for f in findings:
            if f.severity >= floor:
                print(f"  {f.format()}")
    for contract in contracts_for(args.path):
        print(f"[{contract.path}] {contract.name}: {contract.description}")
        findings = contract.check(args.config)
        shown = [f for f in findings if f.severity >= floor]
        n_err += sum(1 for f in findings if f.severity >= Severity.ERROR)
        if not findings:
            print("  OK")
        for f in shown:
            print(f"  {f.format()}")
    if n_err:
        print(f"FAIL: {n_err} ERROR finding(s)", file=sys.stderr)
        return 1
    print("all contracts green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
