"""repro.lint -- quantization-invariant static analysis of compiled paths.

Three layers:

* :mod:`repro.lint.hlo_graph` / :mod:`repro.lint.rules` -- text-level rules
  over compiled HLO modules (reachability-aware, dataflow-walking);
* :mod:`repro.lint.jaxpr_rules` -- trace-level rules over abstract jaxprs
  (scale placement relative to contracted axes);
* :mod:`repro.lint.contracts` -- declarative contracts binding rules to the
  repo's real fast paths, run by ``python -m repro.lint``.

:mod:`repro.lint.pylint_rules` is a separate source-level AST lint (env
reads inside jit-traced bodies) also wired into CI.
"""
from repro.lint.hlo_graph import HloModule
from repro.lint.rules import (RULES, Finding, Rule, RuleSpec, Severity,
                              run_rules)

__all__ = [
    "HloModule", "RULES", "Finding", "Rule", "RuleSpec", "Severity",
    "run_rules",
]
