"""Rule registry for the quantization-invariant HLO analyzer.

Every fast path in this repo is only a win while its compiled module keeps a
structural shape: prepared-weights decode must hold zero in-trace weight
quant rounds, fused int8-KV decode must never dequantize the whole cache,
the int8 train step must actually emit integer MXU dots, donated buffers
must stay copy-free.  A silent fallback breaks none of the numeric tests --
the reference path computes the same values -- so these invariants are
checked *statically* here, over compiled HLO text.

A :class:`Rule` is a named, parameterized check ``(HloModule, **params) ->
[Finding]``; contracts (``lint/contracts.py``) bind rules to the real paths
with concrete parameters.  All rules scan only computations reachable from
ENTRY (``HloModule.reachable``): dead computations retained by the compiler
would otherwise mask zero-count assertions or inflate presence counts.

Adding a rule::

    @rule("my-rule", "one-line description")
    def _my_rule(mod: HloModule, *, threshold: int = 0) -> List[Finding]:
        ...yield findings...

and reference it from a contract via ``RuleSpec("my-rule", {...})``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Sequence

from repro.lint.hlo_graph import (ALIASING_OPS, QUANT_LOCAL_OPS, HloModule,
                                  nbytes, nelems, operand_names,
                                  operand_types, shape_of)


class Severity(enum.IntEnum):
    INFO = 0
    WARNING = 1
    ERROR = 2


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to an instruction when possible."""
    severity: Severity
    rule_id: str
    instr: Optional[str]            # instruction name, None for module-level
    computation: Optional[str]      # computation name, None for module-level
    message: str

    def format(self) -> str:
        where = ""
        if self.computation:
            where = f" [{self.computation}" + (
                f"::{self.instr}]" if self.instr else "]")
        return f"{self.severity.name:7s} {self.rule_id}{where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    description: str
    check: Callable[..., List[Finding]]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, description: str):
    def deco(fn):
        RULES[rule_id] = Rule(rule_id, description, fn)
        return fn
    return deco


def _finding(rule_id: str, msg: str, comp: Optional[str] = None,
             instr: Optional[str] = None,
             severity: Severity = Severity.ERROR) -> Finding:
    return Finding(severity, rule_id, instr, comp, msg)


# ---------------------------------------------------------------------------
# (1) no-weight-quant-rounds
# ---------------------------------------------------------------------------

@rule("no-weight-quant-rounds",
      "prepared-weights paths must contain zero in-trace quantize rounds")
def _no_weight_quant_rounds(mod: HloModule, *, max_rounds: int = 0,
                            prefix: str = "round-nearest") -> List[Finding]:
    """With weights stored as int8 payloads + scales, the compiled step must
    not re-quantize anything: every ``round-nearest*`` op on the live path
    is a weight (or activation) being quantized in-trace -- the exact cost
    preparation paid once to remove."""
    hits = [(comp, ins) for comp, ins in mod.live_instrs()
            if ins.op.startswith(prefix)]
    if len(hits) <= max_rounds:
        return []
    return [_finding("no-weight-quant-rounds",
                     f"in-trace quant round {ins.op} "
                     f"({len(hits)} total, contract allows {max_rounds})",
                     comp, ins.name)
            for comp, ins in hits]


# ---------------------------------------------------------------------------
# (2) no-whole-cache-dequant
# ---------------------------------------------------------------------------

@rule("no-whole-cache-dequant",
      "fused int8-KV decode must not convert large s8 buffers to fp")
def _no_whole_cache_dequant(mod: HloModule, *, min_elems: int = 4096,
                            from_dtype: str = "s8",
                            to_dtypes: Sequence[str] = ("f32", "bf16", "f16"),
                            dims: Optional[Sequence[int]] = None,
                            ) -> List[Finding]:
    """The fused decode kernels fold dequant scales in-register; a ``convert
    s8 -> fp`` at (or above) cache-buffer size means the whole quantized
    cache is being materialized in fp -- the dequant-on-read fallback.
    Size-thresholded: scalar / per-row converts (sampling temperature, the
    freshly decoded row) are part of the contract and pass.  ``dims`` pins
    the rule to one buffer shape (the (B, S, kv_heads, head_dim) cache):
    other large s8 converts -- e.g. the documented dequant-matmul fallback
    for stacked prepared-weight payloads -- are a different path's business.
    """
    out: List[Finding] = []
    for comp, ins in mod.live_instrs():
        if ins.op != "convert":
            continue
        res_dtype, res_dims = shape_of(ins.type_str)
        if res_dtype not in to_dtypes or nelems(ins.type_str) < min_elems:
            continue
        if dims is not None and res_dims != tuple(dims):
            continue
        opnds = operand_types(ins)
        if opnds and opnds[0][0] == from_dtype:
            out.append(_finding(
                "no-whole-cache-dequant",
                f"whole-buffer dequantize: convert {from_dtype}"
                f"{list(opnds[0][1])} -> {ins.type_str.strip()} "
                f"({nelems(ins.type_str)} elems >= {min_elems})",
                comp, ins.name))
    return out


# ---------------------------------------------------------------------------
# (3) int8-compute-present
# ---------------------------------------------------------------------------

@rule("int8-compute-present",
      "quantized train/backward HLO must hold real integer MXU dots")
def _int8_compute_present(mod: HloModule, *, min_dots: int = 1,
                          result_type: str = "s32") -> List[Finding]:
    """An int8 x int8 dot accumulates to s32.  Fewer s32-result dots than
    the contract's floor means some matmul silently fell back to an fp
    einsum over dequantized operands -- numerically near-identical, none of
    the efficiency."""
    n = sum(1 for _, ins in mod.live_instrs()
            if ins.op == "dot"
            and ins.type_str.strip().lstrip("(").startswith(result_type))
    if n >= min_dots:
        return []
    return [_finding("int8-compute-present",
                     f"only {n} {result_type}-result dot(s) on the live "
                     f"path, contract requires >= {min_dots} (a quantized "
                     "matmul fell back to fp)")]


# ---------------------------------------------------------------------------
# (4) copy-free-aliasing
# ---------------------------------------------------------------------------

@rule("copy-free-aliasing",
      "no copy of a donated input buffer (input_output_alias must hold)")
def _copy_free_aliasing(mod: HloModule, *, min_bytes: int = 1024
                        ) -> List[Finding]:
    """Donated buffers (decode state, fused-AdamW moment buckets) are
    updated in place; when XLA cannot prove the alias it inserts a
    defensive whole-buffer copy -- per step, erasing the one-read-one-write
    schedule.  Flags ``copy``/``copy-start`` in ENTRY whose operand chain
    roots at a donated parameter through aliasing ops only (tuple element
    extraction, bitcasts...).  ``min_bytes`` skips scalar bookkeeping copies
    (step counters, rng keys)."""
    if mod.entry is None:
        return []
    donated = mod.donated_params()
    if not donated:
        return []
    out: List[Finding] = []
    for ins in mod.comps[mod.entry]:
        if ins.op not in ("copy", "copy-start"):
            continue
        if nbytes(ins.type_str) < min_bytes:
            continue
        for producer in mod.walk_back(mod.entry, ins, through=ALIASING_OPS):
            pnum = mod.param_number(producer)
            if pnum in donated:
                out.append(_finding(
                    "copy-free-aliasing",
                    f"{ins.op} of {nbytes(ins.type_str)} bytes roots at "
                    f"donated parameter {pnum} ({producer.name}): the "
                    "input/output alias degraded to a defensive copy",
                    mod.entry, ins.name))
                break
    return out


# ---------------------------------------------------------------------------
# (5) double-quantize
# ---------------------------------------------------------------------------

@rule("double-quantize",
      "no value quantized twice on one elementwise dataflow path")
def _double_quantize(mod: HloModule, *, prefix: str = "round-nearest"
                     ) -> List[Finding]:
    """Two quant rounds with only elementwise/scaling ops between them mean
    the same tensor was quantized twice (qdq of an already-quantized value:
    double rounding error AND double cost).  A dot / reduce / scatter
    between the rounds computes a genuinely new value and legitimately
    re-quantizes, so the walk stops there."""
    out: List[Finding] = []
    for comp, ins in mod.live_instrs():
        if not ins.op.startswith(prefix):
            continue
        for producer in mod.walk_back(comp, ins, through=QUANT_LOCAL_OPS):
            if producer.op.startswith(prefix):
                out.append(_finding(
                    "double-quantize",
                    f"{ins.name} re-quantizes a value already rounded by "
                    f"{producer.name} (elementwise-only path between them)",
                    comp, ins.name))
                break
    return out


# ---------------------------------------------------------------------------
# (6) no-large-gather
# ---------------------------------------------------------------------------

@rule("no-large-gather",
      "paged decode must not gather more pages than a slot's live range")
def _no_large_gather(mod: HloModule, *, min_elems: int,
                     dtype: str = "s8",
                     dims: Optional[Sequence[int]] = None) -> List[Finding]:
    """Paged decode touches at most ``ceil(pos/page_size)`` physical pages
    per slot -- a gather / dynamic-slice whose *result* reaches the size of
    every slot's full logical KV view means the page indirection collapsed
    into a materialized whole-cache gather (paging's memory win gone, and a
    (B, maxp*page, ...) fp copy usually follows).  Size-thresholded on the
    result so the fused kernel's per-tile page DMAs (one page each) pass;
    ``dims`` pins the rule to the per-slot logical view shape
    (B, maxp, page, kv_heads, head_dim) so the layer scan's per-layer
    stacked-buffer slices (leading dim 1, a different axis entirely) are
    not mistaken for it."""
    out: List[Finding] = []
    for comp, ins in mod.live_instrs():
        if ins.op not in ("gather", "dynamic-slice"):
            continue
        res_dtype, res_dims = shape_of(ins.type_str)
        if res_dtype != dtype or nelems(ins.type_str) < min_elems:
            continue
        if dims is not None and res_dims != tuple(dims):
            continue
        out.append(_finding(
            "no-large-gather",
            f"{ins.op} materializes {ins.type_str.strip()} "
            f"({nelems(ins.type_str)} elems >= {min_elems}): whole-cache "
            "page gather on the paged decode path",
            comp, ins.name))
    return out


# ---------------------------------------------------------------------------
# op-count: the generic parameterized counter (replaces ad-hoc test asserts)
# ---------------------------------------------------------------------------

@rule("op-count",
      "bounded count of ops by prefix (and optional result-type prefix)")
def _op_count(mod: HloModule, *, op_prefix: str,
              result_type: Optional[str] = None,
              min_count: int = 0, max_count: Optional[int] = None
              ) -> List[Finding]:
    """Structured replacement for raw ``count_ops`` assertions: a contract
    states bounds, a violation reports the live count."""
    n = 0
    for _, ins in mod.live_instrs():
        if not ins.op.startswith(op_prefix):
            continue
        if (result_type is not None and not
                ins.type_str.strip().lstrip("(").startswith(result_type)):
            continue
        n += 1
    want = (f">= {min_count}" if max_count is None else
            f"in [{min_count}, {max_count}]")
    if n < min_count or (max_count is not None and n > max_count):
        tt = f" (result {result_type})" if result_type else ""
        return [_finding("op-count",
                         f"{n} live {op_prefix!r}{tt} op(s), contract "
                         f"requires {want}")]
    return []


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RuleSpec:
    """One rule binding inside a contract: rule id, parameters, and the
    severity its findings report at."""
    rule_id: str
    params: Dict = dataclasses.field(default_factory=dict)
    severity: Severity = Severity.ERROR

    def run(self, mod: HloModule) -> List[Finding]:
        found = RULES[self.rule_id].check(mod, **self.params)
        return [dataclasses.replace(f, severity=self.severity)
                for f in found]


def run_rules(hlo, specs: Sequence[RuleSpec]) -> List[Finding]:
    """Check one compiled module (text or :class:`HloModule`) against a list
    of rule bindings; returns all findings, most severe first."""
    mod = hlo if isinstance(hlo, HloModule) else HloModule(hlo)
    out: List[Finding] = []
    for spec in specs:
        out.extend(spec.run(mod))
    return sorted(out, key=lambda f: -int(f.severity))
