"""AdamW with optionally-quantized moments (paper Section 4.4).

The moments are stored between steps in the representation selected by the
recipe (fp / fake-quantized fp / real int8+scales) and decoded for the update
-- exactly the paper's methodology ("the quantized values of each state are
stored until the next training iteration, then dequantized and used for
Adam's update").

Built from scratch (optax is not available in this environment).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qadam
from repro.core.qconfig import QuantRecipe


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 6e-4                 # paper Appendix A
    b1: float = 0.9
    b2: float = 0.95                 # nanoGPT-style (paper follows nanoGPT)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 300_000       # paper: 300k steps
    min_lr_ratio: float = 0.0        # cosine decays to ~0 (paper: lr < 1e-6)
    state_storage: str = "fake"      # fake (paper) | int (production int8)


class AdamState(NamedTuple):
    step: jnp.ndarray                # int32 scalar
    m1: Any                          # pytree: fp arrays or qadam.QState
    m2: Any


def lr_schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup + half-cycle cosine (paper Appendix A)."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gn


def init_adam_state(params, recipe: Optional[QuantRecipe],
                    cfg: OptConfig) -> AdamState:
    recipe = recipe or QuantRecipe()
    m1 = jax.tree_util.tree_map(
        lambda p: qadam.init_state(p, recipe.adam_m1, cfg.state_storage),
        params)
    m2 = jax.tree_util.tree_map(
        lambda p: qadam.init_state(p, recipe.adam_m2, cfg.state_storage),
        params)
    return AdamState(step=jnp.zeros((), jnp.int32), m1=m1, m2=m2)


def _is_state_leaf(x):
    return isinstance(x, qadam.QState) or isinstance(x, jnp.ndarray) or \
        hasattr(x, "shape")


def adamw_update(params, grads, state: AdamState, cfg: OptConfig,
                 recipe: Optional[QuantRecipe] = None
                 ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  params fp32 master; grads any float dtype.
    Returns (new_params, new_state, stats)."""
    recipe = recipe or QuantRecipe()
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m1_leaves = treedef.flatten_up_to(state.m1)
    m2_leaves = treedef.flatten_up_to(state.m2)

    new_p, new_m1, new_m2 = [], [], []
    for p, g, m1s, m2s in zip(p_leaves, g_leaves, m1_leaves, m2_leaves):
        gf = g.astype(jnp.float32)
        m1 = qadam.decode(m1s, recipe.adam_m1, p.shape)
        m2 = qadam.decode(m2s, recipe.adam_m2, p.shape)
        m1 = b1 * m1 + (1.0 - b1) * gf
        m2 = b2 * m2 + (1.0 - b2) * jnp.square(gf)
        upd = (m1 / c1) / (jnp.sqrt(m2 / c2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay and p.ndim >= 2:
            upd = upd + cfg.weight_decay * pf
        new_p.append((pf - lr * upd).astype(p.dtype))
        new_m1.append(qadam.encode(m1, recipe.adam_m1, cfg.state_storage))
        new_m2.append(qadam.encode(m2, recipe.adam_m2, cfg.state_storage))

    stats = {"lr": lr, "grad_norm": gnorm,
             "update_norm": jnp.zeros((), jnp.float32)}
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            AdamState(step=step,
                      m1=jax.tree_util.tree_unflatten(treedef, new_m1),
                      m2=jax.tree_util.tree_unflatten(treedef, new_m2)),
            stats)
