"""AdamW with optionally-quantized moments (paper Section 4.4).

The moments are stored between steps in the representation selected by the
recipe (fp / fake-quantized fp / real int8+scales) and decoded for the update
-- exactly the paper's methodology ("the quantized values of each state are
stored until the next training iteration, then dequantized and used for
Adam's update").

Two update paths share those semantics:

* the reference **loop**: one Python iteration per leaf, decode -> update ->
  encode as unfused XLA ops (the bit-compared oracle, and the only path for
  fp/fake storage, non-blockwise moment codecs, and non-quantizable leaves);
* the fused **kernel** path (kernels/opt_update.py): quantizable leaves with
  blockwise int8-stored moments are flattened into padded (nblocks,
  block_size) buckets matching ``core.qadam``'s codec layout and the whole
  update runs as one Pallas launch per (dtype) bucket -- one HBM read and one
  write per buffer instead of ~6.  Default on TPU for ``state_storage="int"``;
  ``REPRO_FUSED_ADAM=1/0`` forces it either way (tests pin ``1`` to exercise
  the kernel in interpret mode on CPU).

Built from scratch (optax is not available in this environment).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qadam
from repro.core.qconfig import QuantRecipe


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 6e-4                 # paper Appendix A
    b1: float = 0.9
    b2: float = 0.95                 # nanoGPT-style (paper follows nanoGPT)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 300_000       # paper: 300k steps
    min_lr_ratio: float = 0.0        # cosine decays to ~0 (paper: lr < 1e-6)
    state_storage: str = "fake"      # fake (paper) | int (production int8)


class AdamState(NamedTuple):
    step: jnp.ndarray                # int32 scalar
    m1: Any                          # pytree: fp arrays or qadam.QState
    m2: Any


def lr_schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup + half-cycle cosine (paper Appendix A)."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _clip_scale(gnorm: jnp.ndarray, max_norm: float) -> jnp.ndarray:
    """Global-norm clip factor (shared by clip_by_global_norm and the
    streamed scalar of the fused/loop update paths)."""
    return jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    gn = global_norm(grads)
    scale = _clip_scale(gn, max_norm)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gn


def init_adam_state(params, recipe: Optional[QuantRecipe],
                    cfg: OptConfig) -> AdamState:
    recipe = recipe or QuantRecipe()
    m1 = jax.tree_util.tree_map(
        lambda p: qadam.init_state(p, recipe.adam_m1, cfg.state_storage),
        params)
    m2 = jax.tree_util.tree_map(
        lambda p: qadam.init_state(p, recipe.adam_m2, cfg.state_storage),
        params)
    return AdamState(step=jnp.zeros((), jnp.int32), m1=m1, m2=m2)


def fused_adam_enabled() -> bool:
    """Fused kernel path default: on where the kernel compiles (TPU);
    ``REPRO_FUSED_ADAM=1/0`` forces the choice either way (the loop stays the
    oracle; tests pin ``1`` to run the kernel in interpret mode on CPU)."""
    force = os.environ.get("REPRO_FUSED_ADAM", "")
    if force in ("0", "1"):
        return force == "1"
    return jax.default_backend() == "tpu"


def opt_path_desc(recipe, cfg: OptConfig) -> str:
    """One-word-ish description of the optimizer update path this (recipe,
    opt config, host) combination actually runs -- the ``opt=`` segment of
    ``train/step.train_path_summary``."""
    recipe = recipe or QuantRecipe()
    m1, m2 = recipe.adam_m1, recipe.adam_m2
    if m1 is None and m2 is None:
        return "fp-loop"
    if cfg.state_storage != "int":
        return "fake-loop"
    if qadam.fused_pair_eligible(m1, m2) and fused_adam_enabled():
        return f"int8-fused(b{m1.block_size})"
    return "int8-loop"


def _leaf_update(p, gf, m1, m2, lr, c1, c2, cfg: OptConfig):
    """Decoded-moment AdamW update for one leaf (shared math of both paths'
    reference semantics).  Returns (new_p, new_m1, new_m2, delta) with
    ``delta`` the applied fp32 parameter step (for the update_norm stat)."""
    m1 = cfg.b1 * m1 + (1.0 - cfg.b1) * gf
    m2 = cfg.b2 * m2 + (1.0 - cfg.b2) * jnp.square(gf)
    upd = (m1 / c1) / (jnp.sqrt(m2 / c2) + cfg.eps)
    pf = p.astype(jnp.float32)
    if cfg.weight_decay and p.ndim >= 2:
        upd = upd + cfg.weight_decay * pf
    delta = lr * upd
    return (pf - delta).astype(p.dtype), m1, m2, delta


def _fused_bucket(idxs: List[int], p_leaves, g_leaves, m1_leaves, m2_leaves,
                  clip_scale, lr, c1, c2, cfg: OptConfig, recipe):
    """Run one fused-kernel launch over the leaves in ``idxs`` (same param
    dtype, globally-shared moment specs) and scatter results back.  Returns
    (new_p, new_m1, new_m2) keyed by leaf index, plus the bucket's
    sum ||delta||^2."""
    from repro.kernels import opt_update as _ok      # lazy: pallas import

    m1_spec, m2_spec = recipe.adam_m1, recipe.adam_m2
    bs = m1_spec.block_size
    nblocks = []
    for i in idxs:
        (nb, _), _ = qadam.blockwise_state_shapes(p_leaves[i].shape, m1_spec)
        # codec invariant: stored states are already in blockwise layout
        assert m1_leaves[i].q.shape == (nb, bs), (m1_leaves[i].q.shape, nb, bs)
        assert m2_leaves[i].q.shape == (nb, bs), (m2_leaves[i].q.shape, nb, bs)
        nblocks.append(nb)

    g_cat = jnp.concatenate(
        [qadam.flatten_blocks(g_leaves[i].astype(jnp.float32), bs)
         for i in idxs])
    p_cat = jnp.concatenate(
        [qadam.flatten_blocks(p_leaves[i], bs) for i in idxs])
    cat = lambda part: jnp.concatenate([getattr(m, part)
                                        for m in (m1_leaves[i] for i in idxs)])
    cat2 = lambda part: jnp.concatenate([getattr(m, part)
                                         for m in (m2_leaves[i] for i in idxs)])
    q1, s1, z1 = cat("q"), cat("scale"), cat("zero")
    q2, s2, z2 = cat2("q"), cat2("scale"), cat2("zero")

    rows = g_cat.shape[0]
    br = _ok.tile_rows()
    pad = (-rows) % br
    if pad:
        # fully-padded rows: 0 payloads + 0 scales decode to 0, update to 0,
        # and the encode guard keeps their fresh scales finite (scale==0 is
        # only ever multiplied, never divided by).
        zpad = lambda a: jnp.pad(a, ((0, pad), (0, 0)))
        g_cat, p_cat = zpad(g_cat), zpad(p_cat)
        q1, s1, z1 = zpad(q1), zpad(s1), zpad(z1)
        q2, s2, z2 = zpad(q2), zpad(s2), zpad(z2)

    scalars = jnp.stack([
        clip_scale.astype(jnp.float32), lr.astype(jnp.float32),
        jnp.float32(cfg.b1), jnp.float32(cfg.b2), jnp.float32(cfg.eps),
        jnp.float32(cfg.weight_decay), c1.astype(jnp.float32),
        c2.astype(jnp.float32)])

    p_new, m1_new, m2_new, sumsq = _ok.fused_adamw_blocks(
        g_cat, p_cat, q1, s1, z1, q2, s2, z2, scalars,
        m1_codec=_ok.codec_of(m1_spec), m2_codec=_ok.codec_of(m2_spec),
        weight_decay=bool(cfg.weight_decay), block_rows=min(br, rows + pad),
        interpret=jax.default_backend() != "tpu")

    out_p, out_m1, out_m2 = {}, {}, {}
    off = 0
    for i, nb in zip(idxs, nblocks):
        sl = slice(off, off + nb)
        out_p[i] = qadam.unflatten_blocks(p_new[sl], p_leaves[i].shape)
        out_m1[i] = qadam.QState(m1_new[0][sl], m1_new[1][sl], m1_new[2][sl])
        out_m2[i] = qadam.QState(m2_new[0][sl], m2_new[1][sl], m2_new[2][sl])
        off += nb
    return out_p, out_m1, out_m2, sumsq


def adamw_update(params, grads, state: AdamState, cfg: OptConfig,
                 recipe: Optional[QuantRecipe] = None
                 ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  params fp32 master; grads any float dtype.
    Returns (new_params, new_state, stats)."""
    recipe = recipe or QuantRecipe()
    m1_spec, m2_spec = recipe.adam_m1, recipe.adam_m2
    gnorm = global_norm(grads)
    clip_scale = _clip_scale(gnorm, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m1_leaves = treedef.flatten_up_to(state.m1)
    m2_leaves = treedef.flatten_up_to(state.m2)
    n = len(p_leaves)

    fused_ok = (fused_adam_enabled() and cfg.state_storage == "int"
                and qadam.fused_pair_eligible(m1_spec, m2_spec))
    fused_idx = [i for i in range(n)
                 if fused_ok and qadam.quantizable(p_leaves[i])
                 and isinstance(m1_leaves[i], qadam.QState)
                 and isinstance(m2_leaves[i], qadam.QState)]

    new_p: List[Any] = [None] * n
    new_m1: List[Any] = [None] * n
    new_m2: List[Any] = [None] * n
    upd_sumsq = jnp.zeros((), jnp.float32)

    # --- fused path: one kernel launch per param dtype over all its leaves.
    buckets: Dict[str, List[int]] = {}
    for i in fused_idx:
        buckets.setdefault(str(p_leaves[i].dtype), []).append(i)
    for idxs in buckets.values():
        out_p, out_m1, out_m2, sumsq = _fused_bucket(
            idxs, p_leaves, g_leaves, m1_leaves, m2_leaves,
            clip_scale, lr, c1, c2, cfg, recipe)
        upd_sumsq = upd_sumsq + sumsq
        for i in idxs:
            new_p[i], new_m1[i], new_m2[i] = out_p[i], out_m1[i], out_m2[i]

    # --- reference loop: decode -> update -> encode, one leaf at a time.
    for i in range(n):
        if new_p[i] is not None:
            continue
        p, g = p_leaves[i], g_leaves[i]
        gf = g.astype(jnp.float32) * clip_scale
        m1 = qadam.decode(m1_leaves[i], m1_spec, p.shape)
        m2 = qadam.decode(m2_leaves[i], m2_spec, p.shape)
        new_p[i], m1, m2, delta = _leaf_update(p, gf, m1, m2, lr, c1, c2, cfg)
        upd_sumsq = upd_sumsq + jnp.sum(jnp.square(delta))
        new_m1[i] = qadam.encode(m1, m1_spec, cfg.state_storage)
        new_m2[i] = qadam.encode(m2, m2_spec, cfg.state_storage)

    stats = {"lr": lr, "grad_norm": gnorm,
             "update_norm": jnp.sqrt(upd_sumsq)}
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            AdamState(step=step,
                      m1=jax.tree_util.tree_unflatten(treedef, new_m1),
                      m2=jax.tree_util.tree_unflatten(treedef, new_m2)),
            stats)


def lower_update_hlo(params, recipe, cfg: OptConfig, *,
                     donate: bool = True) -> str:
    """Compiled HLO text of one ``adamw_update`` on abstract (params, grads,
    state), with the optimizer state donated -- the module ``repro.lint``
    optimizer contracts analyze.  ``params`` may be real arrays or
    ``ShapeDtypeStruct``s (nothing is materialized)."""
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    state = jax.eval_shape(lambda p: init_adam_state(p, recipe, cfg), shapes)
    grads = shapes

    def upd(p, g, st):
        return adamw_update(p, g, st, cfg, recipe)

    jitted = jax.jit(upd, donate_argnums=(2,) if donate else ())
    return jitted.lower(shapes, grads, state).compile().as_text()
