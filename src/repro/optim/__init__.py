from repro.optim.adamw import (AdamState, OptConfig, adamw_update,
                               clip_by_global_norm, fused_adam_enabled,
                               global_norm, init_adam_state, lr_schedule,
                               opt_path_desc)

__all__ = ["AdamState", "OptConfig", "adamw_update", "clip_by_global_norm",
           "fused_adam_enabled", "global_norm", "init_adam_state",
           "lr_schedule", "opt_path_desc"]
