from repro.optim.adamw import (AdamState, OptConfig, adamw_update,
                               clip_by_global_norm, global_norm,
                               init_adam_state, lr_schedule)

__all__ = ["AdamState", "OptConfig", "adamw_update", "clip_by_global_norm",
           "global_norm", "init_adam_state", "lr_schedule"]
