"""Policy-driven quantized inference engine with continuous batching.

``Engine(model, params, policy)`` owns a fixed pool of decode *slots* (rows
of one batched KV cache / SSM state).  Requests are admitted into free slots
as they open -- a finished sequence's slot is reused on the very next step
instead of waiting for the whole batch (continuous batching) -- and every
admitted request decodes in lock-step through one jitted per-token step.
The host side of the loop (submit queue, admission ticks, emit thread,
latency accounting) lives in :class:`repro.infer.scheduler.Scheduler`;
``submit``/``run`` delegate to it.

The quantization story mirrors training's :class:`QuantPolicy`, not a
parallel config surface:

* **prepared weights** -- at construction the policy is resolved per
  role/depth and every quantized weight is encoded ONCE into an int8 payload
  + scales (``repro.infer.prepare``); the jitted decode step consumes stored
  integers and contains zero weight-quantization ops;
* **int8 KV cache** -- a policy rule on the ``kv_cache`` role (e.g.
  ``"kv_cache=a8t,*=w8c"``) switches cache storage to int8 payloads with
  per-(position, head) scales.  Where the fused attention kernels support
  the spec (``policy.decode_attn_backend()``), decode attends *directly* on
  the quantized cache -- the per-slot ``(B,)`` position vectors feed the
  kernel grid as validity lengths and scatter rows, one int8 cache read and
  one int8 row write per step (kernels/decode_attn.py) -- and prefill runs
  the dequant-prologue flash kernel; otherwise the cache is dequantized on
  read (the bit-compared reference).  :meth:`Engine.path_summary` reports
  which path runs, :meth:`Engine.kv_decode_read_bytes` its analytic per-step
  KV traffic;
* **sampling** -- one :class:`SamplingParams` (greedy / temperature / top-k /
  top-p) is shared by all requests in the batch and baked into the step.

**Paged KV mode** (``paged=True``, attention-cache families): instead of one
``max_seq``-row cache strip per slot, K/V live in a pool of fixed-size int8
*pages* (``infer/pages.py``) indexed through per-slot page tables, so decode
KV memory scales with *live tokens* rather than ``slots x max_seq``:

* the fused kernel variant (``decode_attention_paged``) scalar-prefetches
  the page table and DMAs one physical page per logical KV tile -- same
  dequant-into-softmax body, same fused row quantize+scatter, now routed to
  ``page_table[pos // page_size]``;
* prefill packs short prompts into shared rows (segment-id masks keep them
  invisible to each other) and *pages in* each prompt's KV rows from the
  prefill buffer to freshly allocated pages;
* admission is by free-page count with a starvation bound (see ``_admit``);
  a request whose pool runs dry mid-decode preempts the youngest running
  request (its prompt+generated tokens re-enter the queue and its pages
  recycle instantly);
* shared prompt prefixes can be cached once (:meth:`cache_prefix`) and
  aliased into any number of page tables (refcounted -- copy-free sharing).

Per-slot positions: decode runs with a (B,) position vector, so each slot
writes its own cache row and masks its own history -- a request's tokens are
independent of which (or how many) neighbours share the batch (asserted by
``tests/test_infer.py::test_batch_invariance``).

Prompts are right-padded to bucketed lengths for prefill (bounded compile
count); causal masking makes the pad tail invisible and ``last_pos`` indexes
the real last-token logits.  Scope: decoder-only families (``dense``,
``moe``, ``ssm``, ``hybrid``; paged mode: ``dense``/``moe`` -- the families
with a pure attention cache); encoder-decoder and VLM serving stay on the
legacy ``greedy_generate`` loop.

**Multi-chip mode** (``mesh=``): weights FSDP-shard over the mesh's data
axis (int8 ``QState`` payloads with their fp32 scale sidecars co-sharded)
and the KV cache -- dense strips and paged pools alike -- tensor-parallels
over the kv-head axis, with the fused decode kernels dispatched per shard
through ``shard_map`` (kernels/decode_attn.py).  A mesh engine is AOT by
default: construction pre-lowers and compiles the donated decode executable
and one prefill executable per prompt bucket (``warmup``), so no trace or
compile is left for serve time -- the MaxText offline-inference shape.
Single-host engines keep the lazy jits unless ``aot=True``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.qconfig import Granularity
from repro.core.qpolicy import as_policy
from repro.infer.pages import (CapacityError, PagePool, init_paged_caches,
                               page_nbytes, pages_for, place_paged_caches)
from repro.infer.prepare import place_params, prepare_params
from repro.infer.resilience import EngineMonitor, MonitorConfig
from repro.infer.sampling import SamplingParams, sample
from repro.infer.scheduler import Scheduler

ENGINE_FAMILIES = ("dense", "moe", "ssm", "hybrid")
PAGED_FAMILIES = ("dense", "moe")

# the per-engine page-in jit cache is bounded (LRU): keys are page-count +
# pool-signature tuples, so a long-lived process cycling engine geometries
# cannot grow it without bound
_PAGEIN_CACHE_MAX = 8

# A queued request skipped this many admission passes (each time because its
# page need exceeded the free pool while smaller requests jumped ahead)
# becomes a barrier: nothing younger is admitted past it until it fits.
# Bounds head-of-line bypass so large prompts cannot starve.
STARVATION_LIMIT = 8


@contextlib.contextmanager
def _pinned_env(values: Dict[str, str]):
    """Pin env-read knobs around a trace.  jax.jit traces lazily (on first
    call, not at Engine construction), so the step closures re-apply the
    construction-time snapshot while tracing -- the compiled path is then
    guaranteed to match what ``path_summary`` reports, however the env
    changes in between."""
    old = {k: os.environ.get(k) for k in values}
    os.environ.update(values)
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _pad_rows(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    """Pad the leading (row/request) dim of a host-built prefill input up to
    ``n`` with ``fill`` -- AOT executables take max_slots-row launches."""
    if a.shape[0] >= n:
        return a
    out = np.full((n,) + a.shape[1:], fill, a.dtype)
    out[:a.shape[0]] = a
    return out


@dataclasses.dataclass
class Request:
    """One generation request.  ``eos_id`` stops the sequence when sampled
    (the eos token is not included in the response's tokens -- this applies
    to the very first sampled token too).  ``timeout_s`` bounds the wall
    clock from submit: a request still queued or decoding past its deadline
    is cancelled by the scheduler (finish reason ``"timeout"``, tokens
    generated so far included, slot and pages freed)."""
    tokens: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    timeout_s: Optional[float] = None
    request_id: Optional[int] = None         # assigned by submit()


@dataclasses.dataclass
class Response:
    """``finish_reason``: ``"eos"`` / ``"length"`` (served to completion),
    ``"timeout"`` (deadline sweep), ``"shed"`` (admission control rejected
    the request under overload -- ``retry_after_s`` estimates when resources
    should free up), ``"numerics"`` (the request's logits row went
    non-finite and it was quarantined -- tokens generated before the fault
    are kept, the poisoned token is not)."""
    request_id: int
    prompt: List[int]
    tokens: List[int]                        # generated, eos excluded
    finish_reason: str     # "eos" | "length" | "timeout" | "shed" | "numerics"
    text: Optional[str] = None               # set by the emit thread when the
    #                                          engine has a detokenizer
    retry_after_s: Optional[float] = None    # set on "shed" responses


@dataclasses.dataclass
class _Running:
    req: Request
    slot: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    order: int = 0                           # admission sequence number


class Engine:
    """See module docstring.  ``submit`` enqueues, ``run`` drains the queue
    and returns the finished :class:`Response` list; ``generate`` is the
    batch-array convenience used by the ``greedy_generate`` compatibility
    shim."""

    def __init__(self, model, params, policy=None, *,
                 max_slots: int = 8, max_seq: int = 256,
                 sampling: SamplingParams = SamplingParams(),
                 prepare_weights: bool = True, seed: int = 0,
                 prefill_bucket: int = 16,
                 paged: bool = False, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 mesh=None, aot: Optional[bool] = None,
                 detokenizer=None, max_queue: Optional[int] = None,
                 monitor: Optional[MonitorConfig] = None):
        cfg = model.cfg
        if cfg.family not in ENGINE_FAMILIES:
            raise ValueError(
                f"Engine serves decoder-only families {ENGINE_FAMILIES}; "
                f"{cfg.family!r} uses train.serve.greedy_generate")
        self.model = model
        self.cfg = cfg
        self.policy = as_policy(policy)
        self.sampling = sampling
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.prefill_bucket = int(prefill_bucket)
        self.detokenizer = detokenizer
        # multi-chip serving: FSDP weights over "data", tensor-parallel KV
        # heads over "model" (parallel/sharding.py serve_fsdp mode); AOT
        # defaults on with a mesh -- sharded serving compiles every
        # executable at construction instead of tracing lazily mid-serve
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.sharding import make_rules
            self.rules = make_rules(mesh, "serve_fsdp", cfg=cfg)
        else:
            self.rules = None
        self._aot = bool(aot) if aot is not None else mesh is not None
        self.params = (prepare_params(cfg, params, self.policy)
                       if prepare_weights else params)
        if self.rules is not None:
            # int8 QState payloads FSDP-shard by the raw weight's logical
            # axes; fp32 scale/zero sidecars co-shard with their payloads
            self.params = place_params(self.rules, self.params, model.axes)
        self._dtype = jnp.dtype(cfg.dtype)
        from repro.kernels.decode_attn import (default_block_k,
                                               effective_block_k,
                                               fused_decode_enabled,
                                               spmd_head_shardable)
        self._kv_fused = (self.policy.decode_attn_backend()[0]
                          == "int8_pallas" and fused_decode_enabled()
                          and (self.rules is None or
                               spmd_head_shardable(cfg.n_kv_heads,
                                                   self.rules)))
        kv_spec = self.policy.kv_spec()

        self.paged = bool(paged)
        if self.paged:
            if cfg.family not in PAGED_FAMILIES:
                raise ValueError(
                    f"paged KV serving needs a pure attention cache "
                    f"({PAGED_FAMILIES}); {cfg.family!r} carries SSM state")
            # the page is the kernel's KV tile: clamp/shrink exactly like
            # the dense kernel sizes its tile for a max_seq-row cache
            self.page_size = effective_block_k(self.max_seq, page_size)
            if self.max_seq % self.page_size:
                raise ValueError(
                    f"page_size {self.page_size} must divide "
                    f"max_seq {self.max_seq}")
            maxp = self.max_seq // self.page_size
            self.n_pages = int(n_pages) if n_pages is not None \
                else 1 + self.max_slots * maxp
            self.pool = PagePool(n_pages=self.n_pages,
                                 page_size=self.page_size,
                                 max_slots=self.max_slots,
                                 max_pages_per_slot=maxp)
            self._state = {
                "caches": init_paged_caches(cfg, self.n_pages,
                                            self.page_size, self._dtype,
                                            kv_spec=kv_spec),
                "ssm": None}
            # packing prompts into shared prefill rows requires the KV codec
            # to be row-local (fp or one scale per position x head); a
            # per-write-block scale would couple packed neighbours
            self._packable = (kv_spec is None or
                              kv_spec.granularity is Granularity.PER_TOKEN)
            # segment masks are materialized arrays, which the q8 flash
            # prefill kernel does not take -- packing would silently swap
            # the attend path (flash -> XLA) and upper-layer KV rows would
            # no longer be bit-identical to a dense engine's.  When the
            # fused path is on, prompts prefill one per row instead.
            self._pack_ok = self._packable and not self._kv_fused
            self._kv_block = self.page_size
        else:
            self.page_size = None
            self.n_pages = None
            self.pool = None
            self._packable = False
            self._state = model.init_decode_state(
                self.max_slots, self.max_seq, 0, self._dtype,
                policy=self.policy)
            # report the tile the kernel will actually compile for
            # max_seq-row caches, not the requested/env tile
            self._kv_block = effective_block_k(self.max_seq)
        self._kv_env = {"REPRO_FUSED_DECODE": "1" if self._kv_fused else "0",
                        "REPRO_DECODE_BLOCK": str(default_block_k())}
        # the compiled-path degradation ladder (mirrors the training
        # sentinel's skip -> rollback -> fallback ladder): rung 0 is the
        # configured fast path; a kernel failure or repeated numeric fault
        # steps down toward the bit-compared references, a healthy streak
        # re-probes back up (see _step / _demote / _try_promote)
        caches0 = self._state.get("caches")
        if caches0 is None:
            self._rungs = ["none"]
        elif "k_scale" not in caches0:
            self._rungs = ["fp"]
        elif self._kv_fused:
            self._rungs = ["fused", "dequant", "fp"]
        else:
            self._rungs = ["dequant", "fp"]
        self._rung = 0
        self.monitor = EngineMonitor(monitor)
        #: set by the resilience harness (FaultPlan.engine_hooks()) to
        #: inject serving faults at the decode-step hook points
        self.fault_hooks = None
        self._decode_steps = 0
        self.preemptions = 0
        if self.rules is not None:
            # decode state onto the mesh: payload AND sidecar cache buffers
            # tensor-parallel over the kv-head axis, everything else (slot
            # bookkeeping, SSM states) replicated
            if self.paged:
                self._state["caches"] = place_paged_caches(
                    self.rules, self._state["caches"])
            else:
                self._state = jax.device_put(self._state,
                                             self._state_shardings())

        self._queue: deque = deque()
        # incremented inside the traced step closures: each jax trace of
        # prefill/decode bumps its counter, so tests can assert AOT warmup
        # leaves nothing to retrace at serve time
        self._trace_counts: Dict[str, int] = {"prefill": 0, "decode": 0}
        self._free: List[int] = list(range(self.max_slots))
        self._running: Dict[int, _Running] = {}
        self._done: List[Response] = []
        self._pos = np.zeros((self.max_slots,), np.int32)
        self._last_tok = np.zeros((self.max_slots,), np.int32)
        self._next_id = 0
        self._order = 0
        self._key = jax.random.PRNGKey(seed)
        self._skips: Dict[int, int] = {}          # request_id -> passes skipped
        self._carry: Dict[int, Tuple[List[int], List[int]]] = {}
        #   preempted request_id -> (original prompt, tokens generated so far)
        self._prefixes: Dict[tuple, List[int]] = {}   # cached prefix -> pids
        self._pagein_jits: "OrderedDict[tuple, jax.stages.Wrapped]" = \
            OrderedDict()
        self.scheduler = Scheduler(self, max_queue=max_queue)

        if self.paged:
            def _prefill(params, toks, last, segs):
                # max_seq (not the row width) sizes the prefill KV buffers so
                # the attention reduction length matches the dense engine's
                # bit for bit; pages are sliced out of the buffer afterwards
                self._trace_counts["prefill"] += 1
                with _pinned_env(self._kv_env):
                    return self.model.prefill(params, {"tokens": toks},
                                              policy=self.policy,
                                              rules=self.rules,
                                              max_seq=self.max_seq,
                                              last_pos=last, segments=segs)

            def _make_decode(env):
                def _decode(params, state, tok, pos, pt, key):
                    self._trace_counts["decode"] += 1
                    with _pinned_env(env):
                        logits, state = self.model.decode(
                            params, state, tok, pos, policy=self.policy,
                            rules=self.rules, page_table=pt)
                    return (sample(logits, self.sampling, key),
                            jnp.all(jnp.isfinite(logits), axis=-1), state)
                return _decode
        else:
            def _prefill(params, toks, last_pos):
                self._trace_counts["prefill"] += 1
                with _pinned_env(self._kv_env):
                    return self.model.prefill(params, {"tokens": toks},
                                              policy=self.policy,
                                              rules=self.rules,
                                              max_seq=self.max_seq,
                                              last_pos=last_pos)

            def _make_decode(env):
                def _decode(params, state, tok, pos, key):
                    self._trace_counts["decode"] += 1
                    with _pinned_env(env):
                        logits, state = self.model.decode(
                            params, state, tok, pos, policy=self.policy,
                            rules=self.rules)
                    return (sample(logits, self.sampling, key),
                            jnp.all(jnp.isfinite(logits), axis=-1), state)
                return _decode

        # donate the decode state: it is replaced by the return value every
        # step, and without donation XLA must defensively copy the buffers
        # the fused kernel aliases in place (input_output_aliases on the
        # int8 KV caches) -- a whole-cache copy per step that would erase
        # the one-read-one-row-write schedule.  Under sharding rules the
        # output shardings are pinned to the construction-time placement so
        # the AOT decode executable's input layouts hold step to step.
        # The decode step additionally returns a (B,) per-slot logit
        # finiteness flag (reduced on device -- the full logits never come
        # to host): the quarantine signal.  Token values are untouched, so
        # healthy-path greedy output is bit-identical to an engine without
        # the ladder.
        dec_kw, pre_kw = {}, {}
        if self.rules is not None:
            repl = self.rules.replicated()
            st_sh = self._state_shardings()
            dec_kw["out_shardings"] = (repl, repl, st_sh)
            # prefill state buffers are dense (B, max_seq) strips in both
            # modes (pages are sliced out afterwards): kv-head sharded
            # caches, replicated logits/ssm -- a pytree prefix
            pre_kw["out_shardings"] = (repl, {"caches": self._kv_sharding(),
                                              "ssm": repl})
        self._make_decode = _make_decode
        self._prefill_jit = jax.jit(_prefill, **pre_kw)
        # rung 0's jit is built eagerly (it is the one warmup AOT-compiles
        # and lowered_decode_hlo lints); degraded rungs trace lazily at
        # first demotion -- the emergency path pays its own compile
        self._decode_jit = jax.jit(_make_decode(dict(self._kv_env)),
                                   donate_argnums=(1,), **dec_kw)
        self._decode_jits: Dict[str, object] = {self._rungs[0]:
                                                self._decode_jit}
        self._scatter_jit = self._make_scatter_jit()

        # AOT executables (warmup() fills these): decode + one prefill per
        # (bucket, packed) shape
        self._decode_exec = None
        self._prefill_exec: Dict[Tuple[int, bool], object] = {}
        self._compiles: List[Dict[str, object]] = []
        self._warmed = False
        if self._aot:
            self.warmup()

    # -- public API --------------------------------------------------------

    def submit(self, req: Request) -> int:
        toks = [int(t) for t in req.tokens]
        if not toks:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.paged:
            page = self.page_size
            maxp = self.pool.max_pages_per_slot
            alloc = self.pool.n_pages - 1          # page 0 is the trash page
            acct = dict(max_seq=self.max_seq, page_size=page,
                        pages_total=alloc, pages_free=self.pool.free_pages,
                        slots_total=self.max_slots,
                        slots_free=len(self._free))
            if len(toks) > self.max_seq - 1:
                raise CapacityError(
                    f"prompt length {len(toks)} needs at least one decode "
                    f"row in max_seq={self.max_seq} ({maxp} pages x {page} "
                    f"rows/page per slot)",
                    tokens=len(toks),
                    pages_needed=pages_for(len(toks) + 1, page), **acct)
            peak = pages_for(min(len(toks) + req.max_new_tokens,
                                 self.max_seq), page)
            if peak > alloc:
                raise CapacityError(
                    f"request peaks at {peak} pages "
                    f"({min(len(toks) + req.max_new_tokens, self.max_seq)} "
                    f"live tokens / {page} rows per page) but the pool holds "
                    f"only {alloc} allocatable pages -- even alone it would "
                    f"exhaust the pool mid-decode",
                    tokens=len(toks), pages_needed=peak, **acct)
        elif len(toks) > self.max_seq - 1:
            raise CapacityError(
                f"prompt length {len(toks)} needs at least one "
                f"decode row in max_seq={self.max_seq}",
                tokens=len(toks), max_seq=self.max_seq,
                slots_total=self.max_slots, slots_free=len(self._free))
        req = dataclasses.replace(req, tokens=toks,
                                  request_id=self._next_id)
        self._next_id += 1
        self.scheduler.enqueue(req)
        return req.request_id

    def run(self) -> List[Response]:
        """Drain the queue: admit-on-free until every submitted request has a
        response.  Returns responses in request_id order."""
        return self.scheduler.run()

    def generate(self, prompts, max_new_tokens: int,
                 eos_id: Optional[int] = None) -> jnp.ndarray:
        """Uniform-batch convenience matching the ``greedy_generate``
        contract: (B, max_new_tokens) int32, eos-padded after the stop."""
        prompts = np.asarray(prompts)
        ids = [self.submit(Request(tokens=row.tolist(),
                                   max_new_tokens=max_new_tokens,
                                   eos_id=eos_id))
               for row in prompts]
        by_id = {r.request_id: r for r in self.run()}
        pad = eos_id if eos_id is not None else 0
        out = np.full((len(ids), max_new_tokens), pad, np.int32)
        for i, rid in enumerate(ids):
            t = by_id[rid].tokens
            if eos_id is None and len(t) < max_new_tokens:
                lim = (f"max_seq={self.max_seq} = "
                       f"{self.pool.max_pages_per_slot} pages x "
                       f"{self.page_size} rows/page per slot"
                       if self.paged else f"max_seq={self.max_seq}")
                raise ValueError(
                    f"request {rid} truncated at {len(t)}/{max_new_tokens} "
                    f"tokens (cache rows exhausted: {lim}); "
                    "grow max_seq"
                    + (" or n_pages" if self.paged else "")
                    + " or pass eos_id")
            out[i, :len(t)] = t
        return jnp.asarray(out)

    def cancel(self, request_id: int, reason: str = "timeout",
               retry_after_s: Optional[float] = None) -> bool:
        """Cancel a queued or running request (scheduler-thread only -- the
        same thread that runs ``_admit``/``_step``).  Running: finished via
        the normal path (slot and pages freed, tokens generated so far kept).
        Queued: removed before admission (a preempted continuation keeps its
        carry split, so the response still reports the original prompt);
        ``retry_after_s`` is attached to the response (the scheduler's shed
        path sets it as the client's back-off hint).
        Returns False when the request is unknown or already finished."""
        for req in self._queue:
            if req.request_id == request_id:
                self._queue.remove(req)
                self._skips.pop(request_id, None)
                orig, prior = self._carry.pop(
                    request_id, (list(req.tokens), []))
                self._done.append(Response(request_id=request_id, prompt=orig,
                                           tokens=prior,
                                           finish_reason=reason,
                                           retry_after_s=retry_after_s))
                return True
        for st in self._running.values():
            if st.req.request_id == request_id:
                self._finish(st, reason)
                return True
        return False

    def cache_prefix(self, tokens: Sequence[int]) -> int:
        """Prefill ``tokens`` once and pin its whole-page KV as a shared
        prefix: any later request whose prompt starts with it aliases the
        pinned pages into its own page table (refcounted, copy-free) and
        prefills only the tail pages.  Only full pages are cached (the
        trailing partial page is recomputed per request -- a page is the
        aliasing unit).  Returns the number of pages cached; paged mode
        only."""
        if not self.paged:
            raise ValueError("cache_prefix requires paged=True")
        toks = [int(t) for t in tokens]
        page = self.page_size
        n_pg = len(toks) // page
        if n_pg == 0:
            raise ValueError(
                f"prefix shorter than one page ({page} tokens); nothing "
                "to share")
        plen = n_pg * page
        if plen > self.max_seq - 1:
            raise ValueError(
                f"prefix of {plen} tokens leaves no decode row in "
                f"max_seq={self.max_seq}")
        key = tuple(toks[:plen])
        if key in self._prefixes:
            return n_pg
        if n_pg > self.pool.free_pages:
            raise CapacityError(
                f"caching a {n_pg}-page prefix needs {n_pg} free pages",
                tokens=plen, page_size=page, pages_needed=n_pg,
                pages_total=self.pool.n_pages - 1,
                pages_free=self.pool.free_pages,
                slots_total=self.max_slots, slots_free=len(self._free))
        lb = self._row_len(plen)
        toksa = np.zeros((1, lb), np.int32)
        toksa[0, :plen] = key
        # segments=None: a prefix is one segment, and the plain causal-mask
        # trace keeps its rows bit-identical to the rows a request prefilling
        # this prompt itself would write (same attend path, fused or not)
        last = np.asarray([[0, plen - 1]], np.int32)
        _, new_state = self._prefill_call(toksa, last)
        new_state = self._match_prefill_state(new_state)
        pids = self.pool.alloc(n_pg)
        self.pool.pin(pids)
        self._page_in(new_state["caches"], 0, 0, pids)
        self._prefixes[key] = pids
        return n_pg

    def kv_cache_nbytes(self) -> int:
        """Resident bytes of the decode state (KV caches + SSM states).
        With int8 KV this is the payload+sidecar footprint the fused decode
        path reads per step -- see :meth:`kv_decode_read_bytes`."""
        return sum(int(x.size) * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(self._state))

    def live_kv_bytes(self) -> int:
        """KV bytes actually referenced by live sequences.  Paged: live
        (refcounted) pages x per-page bytes across all layers -- this is the
        number that scales with live tokens instead of slots x max_seq.
        Dense: the whole resident cache (every slot's strip is committed
        whether or not the slot is live)."""
        if not self.paged:
            return self.kv_cache_nbytes()
        return self.pool.live_pages * page_nbytes(self._state["caches"])

    def _kv_mode(self) -> str:
        """Which KV consumption path decode runs: ``fused`` (int8 kernels),
        ``dequant`` (int8 storage, dequantize-on-read), ``fp``, or ``none``
        (no KV cache -- pure SSM).  Snapshotted at construction and pinned
        around the step traces (``_pinned_env``), so the report always
        matches the compiled path -- flipping ``REPRO_FUSED_DECODE`` /
        ``REPRO_DECODE_BLOCK`` after construction affects neither.  A
        ladder-degraded engine reports the rung it currently runs (the
        state structure and the rung's pinned env move together)."""
        caches = self._state.get("caches")
        if caches is None:
            return "none"
        if "k_scale" not in caches:
            return "fp"
        return "fused" if self._rungs[self._rung] == "fused" else "dequant"

    def kv_decode_read_bytes(self) -> int:
        """Analytic KV bytes moved per decode step across the stack (the
        roofline term the fused path shrinks; 0 without a KV cache).  Paged
        mode reads only live pages -- the figure tracks live tokens.  See
        ``kernels.decode_attn.decode_kv_read_bytes`` for the per-mode
        accounting."""
        caches = self._state.get("caches")
        if caches is None:
            return 0
        from repro.kernels.decode_attn import decode_kv_read_bytes
        if self.paged:
            stacks = caches["k"].shape[0]
            kh, hd = caches["k"].shape[-2:]
            rows = int(self.pool.live_pages) * self.page_size
            return decode_kv_read_bytes(self._kv_mode(), 1, rows, kh, hd,
                                        n_layers=stacks,
                                        fp_bytes=self._dtype.itemsize)
        stacks, b, s, kh, hd = caches["k"].shape
        return decode_kv_read_bytes(self._kv_mode(), b, s, kh, hd,
                                    n_layers=stacks,
                                    fp_bytes=self._dtype.itemsize)

    def path_summary(self) -> str:
        """``train_path_summary``-style one-liner for the serving path:
        whether weights are prepared int8 payloads, and which KV consumption
        path decode runs (``kv=`` segment)."""
        from repro.core.qadam import QState
        prepared = any(isinstance(leaf, QState) for leaf in
                       jax.tree_util.tree_leaves(
                           self.params,
                           is_leaf=lambda x: isinstance(x, QState)))
        mode = self._kv_mode()
        if self.paged:
            kv = {"fused": f"int8-paged-fused(p{self.page_size})",
                  "dequant": f"int8-paged-gather(p{self.page_size})",
                  "fp": f"fp-paged(p{self.page_size})",
                  "none": "none"}[mode]
        elif mode == "fused":
            kv = f"int8-fused(b{self._kv_block})"
        else:
            kv = {"dequant": "int8-dequant", "fp": "fp", "none": "none"}[mode]
        s = f"weights={'prepared-int8' if prepared else 'raw'} kv={kv}"
        if self._rung > 0:
            s += (f" degraded={self._rungs[self._rung]}"
                  f"(rung {self._rung}/{len(self._rungs) - 1})")
        if self.rules is not None:
            s += f" mesh=dp{self.rules.dp_size}xtp{self.rules.tp_size}"
        if self._warmed:
            rep = self.warmup_report()
            s += (f" aot={rep['n_executables']}exec"
                  f"/{rep['total_compile_s']:.1f}s")
            # generated_code_size is 0 on the CPU backend (the plugin does
            # not report it): omit the segment rather than print a bogus
            # 0KiB -- on a real TPU the per-executable bytes are nonzero
            if int(rep["total_code_bytes"]):
                s += f"/{int(rep['total_code_bytes']) // 1024}KiB"
        return s

    def lowered_decode_hlo(self) -> str:
        """Compiled HLO text of the donated decode step -- the exact module
        ``_step`` executes (same jit, same donation, same pinned env
        snapshot), so ``repro.lint`` decode contracts analyze what serving
        runs, not a reconstruction.  A warmed engine returns its AOT
        executable's text (the partitioned SPMD module under a mesh)."""
        if self._decode_exec is not None:
            return self._decode_exec.as_text()
        tok = self._dev(jnp.zeros((self.max_slots, 1), jnp.int32))
        pos = self._dev(jnp.zeros((self.max_slots,), jnp.int32))
        key = self._dev(jax.random.PRNGKey(0))
        if self.paged:
            pt = self._dev(jnp.zeros(
                (self.max_slots, self.pool.max_pages_per_slot), jnp.int32))
            return (self._decode_jit.lower(self.params, self._state, tok,
                                           pos, pt, key)
                    .compile().as_text())
        return (self._decode_jit.lower(self.params, self._state, tok, pos,
                                       key).compile().as_text())

    # -- sharding / AOT machinery ------------------------------------------

    def _kv_sharding(self) -> NamedSharding:
        """One NamedSharding for any rank-5 cache leaf: dense strips
        ``(L, B, S, K, hd)``, their ``(.., K, 1)`` scale sidecars, and paged
        pools ``(L, P, page, K, hd)`` all carry the kv-head axis at dim 3 --
        the only sharded cache dim at serve time (``make_rules(cfg=...)``
        drops the mapping when the head count does not divide the mesh, so
        ``part`` degrades to fully replicated exactly when the kernels fall
        back to the gather path)."""
        ax = self.rules.axis_map.get("kv") or ()
        part = ax[0] if len(ax) == 1 else None
        return NamedSharding(self.rules.mesh, P(None, None, None, part, None))

    def _state_shardings(self):
        """Sharding tree matching ``self._state``: kv-head-sharded cache
        buffers (payloads and sidecars co-sharded), replicated SSM state."""
        repl = self.rules.replicated()
        kv = self._kv_sharding()
        out = {}
        for k, v in self._state.items():
            sh = kv if k == "caches" else repl
            out[k] = jax.tree_util.tree_map(lambda x, _sh=sh: _sh, v)
        return out

    def _make_scatter_jit(self):
        """Build the admission scatter jit against the *current* decode
        state structure (a ladder transition to/from the fp rung changes the
        cache leaves, and under a mesh the pinned out_shardings with them)."""
        def _scatter(state, new, src, written):
            # fixed-shape slot scatter: ``src[slot]`` is the prefill row to
            # copy into ``slot`` and ``written`` masks the slots admitted
            # this pass.  One executable regardless of group size (the old
            # ``buf.at[:, slots].set`` retraced per admission-group size).
            def upd(buf, n):
                rows = jnp.take(n, src, axis=1).astype(buf.dtype)
                m = written.reshape((1, -1) + (1,) * (buf.ndim - 2))
                return jnp.where(m, rows, buf)
            return jax.tree_util.tree_map(upd, state, new)
        kw = {}
        if self.rules is not None:
            kw["out_shardings"] = self._state_shardings()
        return jax.jit(_scatter, donate_argnums=(0,), **kw)

    def _dev(self, x):
        """Pin small host-built step inputs (tokens, positions, rng keys,
        page tables) to a replicated mesh placement so the AOT executables
        see identical input shardings call after call; identity without a
        mesh."""
        if self.rules is None or x is None:
            return x
        return jax.device_put(x, self.rules.replicated())

    def _prefill_buckets(self) -> List[Tuple[int, bool]]:
        """Every (row_width, packed) prefill shape admission can launch:
        the doubling prompt buckets clamped to ``max_seq`` (page-rounded in
        paged mode), with a packed (segment-masked) variant when row packing
        is enabled."""
        lbs: List[int] = []
        b = self.prefill_bucket
        while True:
            lb = min(b, self.max_seq)
            if lb not in lbs:
                lbs.append(lb)
            if lb >= self.max_seq:
                break
            b *= 2
        if not self.paged:
            return [(lb, False) for lb in lbs]
        out: List[Tuple[int, bool]] = []
        for lb in lbs:
            rl = self._row_len(lb)
            for packed in ((False, True) if self._pack_ok else (False,)):
                if (rl, packed) not in out:
                    out.append((rl, packed))
        return out

    def _aot_compile(self, name: str, jitfn, *args):
        """Lower + compile one executable, recording compile seconds and
        generated code bytes for :meth:`warmup_report`."""
        t0 = time.perf_counter()
        comp = jitfn.lower(*args).compile()
        dt = time.perf_counter() - t0
        size = 0
        try:
            mem = comp.memory_analysis()
            size = int(getattr(mem, "generated_code_size_in_bytes", 0) or 0)
        except Exception:  # lint: except-ok -- optional metric probe: some
            pass           # backends have no memory_analysis(); size stays 0
        self._compiles.append(
            {"name": name, "compile_s": dt, "code_bytes": size})
        return comp

    def _compile_prefill(self, lb: int, packed: bool):
        n = self.max_slots
        toks = self._dev(jnp.zeros((n, lb), jnp.int32))
        if self.paged:
            segs = self._dev(jnp.full((n, lb), -1, jnp.int32)) \
                if packed else None
            args = (toks, self._dev(jnp.zeros((n, 2), jnp.int32)), segs)
        else:
            args = (toks, self._dev(jnp.zeros((n,), jnp.int32)))
        ex = self._aot_compile(
            f"prefill[{lb}{',packed' if packed else ''}]",
            self._prefill_jit, self.params, *args)
        self._prefill_exec[(lb, packed)] = ex
        return ex

    def warmup(self) -> Dict[str, object]:
        """Pre-lower and AOT-compile every serving executable up front: the
        donated decode step plus one prefill per (bucket, packed) shape --
        no trace or compile is left for serve time.  Idempotent; runs at
        construction when ``aot`` is on (default with a mesh).  Returns
        :meth:`warmup_report`."""
        if self._warmed:
            return self.warmup_report()
        tok = self._dev(jnp.zeros((self.max_slots, 1), jnp.int32))
        pos = self._dev(jnp.zeros((self.max_slots,), jnp.int32))
        key = self._dev(jax.random.PRNGKey(0))
        if self.paged:
            pt = self._dev(jnp.zeros(
                (self.max_slots, self.pool.max_pages_per_slot), jnp.int32))
            self._decode_exec = self._aot_compile(
                "decode", self._decode_jit, self.params, self._state,
                tok, pos, pt, key)
        else:
            self._decode_exec = self._aot_compile(
                "decode", self._decode_jit, self.params, self._state,
                tok, pos, key)
        for lb, packed in self._prefill_buckets():
            self._compile_prefill(lb, packed)
        self._warmed = True
        return self.warmup_report()

    def warmup_report(self) -> Dict[str, object]:
        """Compile-cost report over every AOT executable built so far:
        count, total compile seconds, total generated-code bytes, and the
        per-executable breakdown."""
        return {"n_executables": len(self._compiles),
                "total_compile_s": sum(c["compile_s"]
                                       for c in self._compiles),
                "total_code_bytes": sum(c["code_bytes"]
                                        for c in self._compiles),
                "executables": [dict(c) for c in self._compiles]}

    def _prefill_call(self, toks: np.ndarray, last: np.ndarray, segs=None):
        """Route one prefill launch through its bucket's AOT executable
        (compiling on demand if warmup missed the shape) or the lazy jit.
        Under AOT the row/request dims are padded to ``max_slots`` so each
        bucket is exactly one executable -- pad rows are causally inert and
        their logits / state rows are never consumed (every op in the
        forward is row-independent, so real rows are bit-identical to an
        unpadded launch)."""
        lb = toks.shape[1]
        packed = segs is not None
        if self._aot:
            toks = _pad_rows(toks, self.max_slots)
            last = _pad_rows(last, self.max_slots)
            if segs is not None:
                segs = _pad_rows(segs, self.max_slots, fill=-1)
        args = [self._dev(jnp.asarray(toks)), self._dev(jnp.asarray(last))]
        if self.paged:
            args.append(self._dev(jnp.asarray(segs))
                        if segs is not None else None)
        if not self._aot:
            return self._prefill_jit(self.params, *args)
        ex = self._prefill_exec.get((lb, packed))
        if ex is None:
            ex = self._compile_prefill(lb, packed)
        return ex(self.params, *args)

    # -- scheduler internals -----------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _drain_done(self) -> List[Response]:
        done, self._done = self._done, []
        return done

    def _bucket_len(self, n: int) -> int:
        b = self.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _row_len(self, n: int) -> int:
        """Paged prefill row width: the dense bucket, rounded up to whole
        pages (page-in slices whole pages out of the row)."""
        return min(pages_for(self._bucket_len(n), self.page_size)
                   * self.page_size, self.max_seq)

    def _shared_prefix(self, toks: List[int]):
        """Longest cached prefix of ``toks`` -> (prefix_tokens, pids)."""
        best = None
        for pref, pids in self._prefixes.items():
            if len(pref) <= len(toks) and list(pref) == toks[:len(pref)]:
                if best is None or len(pref) > best[0]:
                    best = (len(pref), pids)
        return best

    def _admit(self) -> None:
        """Admit queued requests into free slots.

        Head-of-line fairness: the queue is scanned in FIFO order and any
        request whose resources fit is admitted -- in paged mode a large
        request that does not fit the free-page budget no longer blocks
        smaller requests behind it.  FIFO still holds among requests of the
        same size: the budget only shrinks during the scan, so a request can
        only overtake a *larger* one.  Starvation is bounded: a request
        skipped ``STARVATION_LIMIT`` admission passes becomes a barrier (no
        younger request passes it) until it is admitted."""
        if not self._queue or not self._free:
            return
        free_pages = self.pool.free_pages if self.paged else 0
        free_slots = len(self._free)
        selected: List[Request] = []
        shares: Dict[int, tuple] = {}
        kept: List[Request] = []
        blocked = False
        for req in self._queue:
            if blocked or free_slots == 0:
                kept.append(req)
                continue
            if self.paged:
                share = self._shared_prefix(req.tokens)
                npg = pages_for(len(req.tokens), self.page_size)
                # +1: headroom so the first decode write cannot immediately
                # force a preemption
                need = max(npg - (len(share[1]) if share else 0) + 1, 1)
                if need > free_pages:
                    n = self._skips[req.request_id] = \
                        self._skips.get(req.request_id, 0) + 1
                    if n >= STARVATION_LIMIT:
                        blocked = True
                    kept.append(req)
                    continue
                free_pages -= need
                if share:
                    shares[req.request_id] = share
            selected.append(req)
            free_slots -= 1
        self._queue = deque(kept)
        for r in selected:
            self._skips.pop(r.request_id, None)
        if not selected:
            return
        if self.paged:
            self._admit_paged(selected, shares)
        else:
            groups: Dict[int, List[Request]] = {}
            for r in selected:
                groups.setdefault(self._bucket_len(len(r.tokens)),
                                  []).append(r)
            for lb, group in groups.items():
                self._admit_group(lb, group)

    def _admit_group(self, lb: int, group: List[Request]) -> None:
        n = len(group)
        slots = [self._free.pop(0) for _ in range(n)]
        toks = np.zeros((n, lb), np.int32)
        last = np.zeros((n,), np.int32)
        for i, r in enumerate(group):
            toks[i, :len(r.tokens)] = r.tokens
            last[i] = len(r.tokens) - 1
        logits, new_state = self._prefill_call(toks, last)
        new_state = self._match_prefill_state(new_state)
        src = np.zeros((self.max_slots,), np.int32)
        written = np.zeros((self.max_slots,), np.bool_)
        for i, s in enumerate(slots):
            src[s] = i
            written[s] = True
        self._state = self._scatter_jit(self._state, new_state,
                                        self._dev(jnp.asarray(src)),
                                        self._dev(jnp.asarray(written)))
        first = np.asarray(sample(logits, self.sampling, self._next_key()))
        for i, r in enumerate(group):
            st = _Running(req=r, slot=slots[i], order=self._order)
            self._order += 1
            self._running[slots[i]] = st
            self._pos[slots[i]] = len(r.tokens)
            self._last_tok[slots[i]] = int(first[i])
            # the FIRST sampled token goes through the same eos/length
            # bookkeeping as every later one
            self._record(st, int(first[i]))

    def _admit_paged(self, selected: List[Request],
                     shares: Dict[int, tuple]) -> None:
        """One bucketed prefill launch for all admitted requests: short
        prompts pack into shared rows at page-aligned offsets (segment-id
        masks isolate them), then each prompt's fresh pages are paged in
        from the prefill buffer."""
        page = self.page_size
        spans = [pages_for(len(r.tokens), page) * page for r in selected]
        lb = self._row_len(max(len(r.tokens) for r in selected))
        packed = self._pack_ok and len(selected) > 1
        if packed:
            rows: List[List[Tuple[int, int]]] = []   # per row: (req idx, off)
            used: List[int] = []
            for i, w in enumerate(spans):            # greedy first-fit
                for ri, u in enumerate(used):
                    if u + w <= lb:
                        rows[ri].append((i, u))
                        used[ri] += w
                        break
                else:
                    rows.append([(i, 0)])
                    used.append(w)
        else:
            rows = [[(i, 0)] for i in range(len(selected))]
        n = len(rows)
        toks = np.zeros((n, lb), np.int32)
        segs = np.full((n, lb), -1, np.int32)
        last = np.zeros((len(selected), 2), np.int32)
        placement: Dict[int, Tuple[int, int]] = {}
        for ri, row in enumerate(rows):
            for (i, off) in row:
                r = selected[i]
                L = len(r.tokens)
                toks[ri, off:off + L] = r.tokens
                # the whole page-rounded span carries the segment id: pad
                # rows sit causally after the prompt (invisible to it) and
                # their cache rows are overwritten by decode before any mask
                # admits them
                segs[ri, off:off + spans[i]] = i
                last[i] = (ri, off + L - 1)
                placement[i] = (ri, off)
        logits, new_state = self._prefill_call(
            toks, last, segs if packed else None)
        new_state = self._match_prefill_state(new_state)
        first = np.asarray(sample(logits, self.sampling, self._next_key()))
        for i, r in enumerate(selected):
            ri, off = placement[i]
            L = len(r.tokens)
            npg = pages_for(L, page)
            share = shares.get(r.request_id)
            if share is not None:
                plen, spids = share
                shared = self.pool.share(spids)
            else:
                shared = []
            fresh = self.pool.alloc(npg - len(shared))
            slot = self._free.pop(0)
            self.pool.assign(slot, shared + fresh)
            if fresh:
                # shared pages hold bit-identical rows (the prefix attends
                # only to itself), so only the tail is paged in
                self._page_in(new_state["caches"], ri,
                              off + len(shared) * page, fresh)
            st = _Running(req=r, slot=slot, order=self._order)
            self._order += 1
            self._running[slot] = st
            self._pos[slot] = L
            self._last_tok[slot] = int(first[i])
            self._record(st, int(first[i]))

    def _page_in(self, prefill_caches, row: int, col0: int,
                 pids: List[int]) -> None:
        """Copy whole pages [col0, col0 + len(pids)*page) of prefill row
        ``row`` into physical pages ``pids`` of the pool (all layers, all
        cache buffers).  The row, start column and page ids are all traced
        (``col0`` via ``dynamic_slice_in_dim`` -- the old static-slice
        version retraced per distinct packing offset), so the jit cache is
        keyed on the full jaxpr-relevant signature: page count and size plus
        the pool buffers' dtypes/shapes.  Bounded LRU
        (``_PAGEIN_CACHE_MAX``); pool buffers are donated so the copy is
        in-place."""
        npg = len(pids)
        page = self.page_size
        jkey = (npg, page,
                tuple(sorted((k, str(v.dtype), v.shape)
                             for k, v in self._state["caches"].items())))
        fn = self._pagein_jits.get(jkey)
        if fn is None:
            def f(pools, g, row_, c0_, pids_, _n=npg, _p=page):
                def upd(pool, buf):
                    seg = jnp.take(buf, row_, axis=1)          # (L, lb, ...)
                    seg = jax.lax.dynamic_slice_in_dim(seg, c0_, _n * _p,
                                                       axis=1)
                    seg = seg.reshape(seg.shape[0], _n, _p, *seg.shape[2:])
                    return pool.at[:, pids_].set(seg.astype(pool.dtype))
                return jax.tree_util.tree_map(upd, pools, g)
            kw = {}
            if self.rules is not None:
                # keep the pools' construction-time placement so the AOT
                # decode executable's input shardings hold
                kw["out_shardings"] = jax.tree_util.tree_map(
                    lambda x, _sh=self._kv_sharding(): _sh,
                    self._state["caches"])
            fn = jax.jit(f, donate_argnums=(0,), **kw)
            self._pagein_jits[jkey] = fn
            while len(self._pagein_jits) > _PAGEIN_CACHE_MAX:
                self._pagein_jits.popitem(last=False)
        else:
            self._pagein_jits.move_to_end(jkey)
        self._state["caches"] = fn(
            self._state["caches"], prefill_caches,
            self._dev(jnp.asarray(row, jnp.int32)),
            self._dev(jnp.asarray(col0, jnp.int32)),
            self._dev(jnp.asarray(pids, jnp.int32)))

    def _ensure_write_pages(self) -> None:
        """Before a decode step, make sure every running slot owns the page
        its next row lands in; when the pool is dry, preempt the youngest
        other request (instant page recycle) and retry.  With nothing else
        to evict the needy request preempts *itself* -- it re-enters the
        queue with its tokens carried and resumes once pages free up --
        instead of raising a ``CapacityError`` out of the scheduling loop
        (overload is an outcome here, not an exception; if the pool stays
        dry the scheduler eventually sheds it)."""
        for slot in sorted(self._running):
            st = self._running.get(slot)
            if st is None:                 # preempted by an earlier iteration
                continue
            while slot in self._running \
                    and int(self._pos[slot]) // self.page_size \
                    >= int(self.pool.used[slot]):
                if self.pool.free_pages == 0:
                    if not self._preempt_for(slot):
                        self._preempt(st)
                        break
                    continue
                self.pool.append(slot, self.pool.alloc(1)[0])

    def _preempt_for(self, needy_slot: int) -> bool:
        victims = [st for s, st in self._running.items() if s != needy_slot]
        if not victims:
            return False
        self._preempt(max(victims, key=lambda s: s.order))
        return True

    def _preempt(self, st: _Running) -> None:
        """Evict a running request: free its slot and pages now, re-enter the
        queue at the front with prompt = original prompt + tokens generated
        so far (the carry map keeps the original prompt/generation split for
        the final Response)."""
        self.preemptions += 1
        rid = st.req.request_id
        orig, prior = self._carry.get(rid, (list(st.req.tokens), []))
        gen = prior + st.tokens
        del self._running[st.slot]
        self.pool.release_slot(st.slot)
        self._free.append(st.slot)
        self._pos[st.slot] = 0
        self._last_tok[st.slot] = 0
        self._carry[rid] = (orig, gen)
        remaining = st.req.max_new_tokens - len(st.tokens)
        if remaining < 1 or len(orig) + len(gen) > self.max_seq - 1:
            # no decode row left for a continuation: the request would have
            # hit the max_seq wall on its next step anyway
            self._done.append(Response(request_id=rid, prompt=orig,
                                       tokens=gen, finish_reason="length"))
            self._carry.pop(rid, None)
            return
        cont = dataclasses.replace(st.req, tokens=orig + gen,
                                   max_new_tokens=remaining)
        self._queue.appendleft(cont)

    # -- degradation ladder ------------------------------------------------

    def _rung_env(self, rung: str) -> Dict[str, str]:
        env = dict(self._kv_env)
        env["REPRO_FUSED_DECODE"] = "1" if rung == "fused" else "0"
        return env

    def _decode_fn(self, rung: str):
        """The decode jit for one ladder rung (lazily built and cached --
        ``fused`` and ``dequant`` share the int8 state structure and differ
        only in the env pinned at trace time; ``fp`` traces against the
        dequantized structure)."""
        fn = self._decode_jits.get(rung)
        if fn is None:
            kw = {}
            if self.rules is not None:
                repl = self.rules.replicated()
                kw["out_shardings"] = (repl, repl, self._state_shardings())
            fn = jax.jit(self._make_decode(self._rung_env(rung)),
                         donate_argnums=(1,), **kw)
            self._decode_jits[rung] = fn
        return fn

    def _decode_call(self, args):
        if self._rung == 0 and self._decode_exec is not None:
            return self._decode_exec(self.params, self._state, *args)
        return self._decode_fn(self._rungs[self._rung])(
            self.params, self._state, *args)

    def _dequant_caches(self, caches):
        """int8 cache strips/pools -> the fp reference structure (payload x
        guarded scale; scale-0 padding rows dequantize to exactly 0,
        matching attention's ``_kv_guard`` convention).  Pinned prefix
        pages convert in place, so aliased tables stay valid."""
        dt = self._dtype

        def conv(c):
            out = {}
            for name in ("k", "v"):
                s = c[name + "_scale"]
                g = jnp.where(s == 0.0, 1.0, s)
                out[name] = (c[name].astype(jnp.float32) * g).astype(dt)
            return out
        kw = {}
        if self.rules is not None:
            kw["out_shardings"] = {"k": self._kv_sharding(),
                                   "v": self._kv_sharding()}
        return jax.jit(conv, **kw)(caches)

    def _requant_caches(self, caches):
        """Re-engage path: fp caches back to int8 payloads + per-(position,
        head) fp32 scale sidecars.  All-zero (never-written) rows keep
        scale 0, the padding convention.  Requantization is near-exact, not
        bit-exact -- live rows re-enter the int8 codec with fresh scales,
        the same precision a freshly-written row gets."""
        spec = self.policy.kv_spec()
        from repro.core.quantizer import storage_dtype
        sdt = storage_dtype(spec.bits)

        def conv(c):
            out = {}
            for name in ("k", "v"):
                xf = c[name].astype(jnp.float32)
                absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
                scale = absmax / spec.qmax
                q = jnp.round(xf / jnp.where(scale == 0.0, 1.0, scale))
                out[name] = jnp.clip(q, spec.qmin, spec.qmax).astype(sdt)
                out[name + "_scale"] = scale.astype(jnp.float32)
            return out
        kw = {}
        if self.rules is not None:
            kw["out_shardings"] = {
                k: self._kv_sharding()
                for k in ("k", "v", "k_scale", "v_scale")}
        return jax.jit(conv, **kw)(caches)

    def _match_prefill_state(self, new_state):
        """On the fp rung, prefill still produces int8-structured caches
        (the policy drives its trace); dequantize them before the scatter /
        page-in so they match the engine's current cache structure."""
        caches = self._state.get("caches")
        nc = new_state.get("caches")
        if (caches is not None and "k_scale" not in caches
                and nc is not None and "k_scale" in nc):
            new_state = dict(new_state,
                             caches=self._dequant_caches(nc))
        return new_state

    def _demote(self, why: str, step: int) -> bool:
        """One rung down the ladder; False when already at the bottom.
        Stepping onto the fp rung dequantizes the live decode state (pages
        and dense strips alike), so running requests continue with their
        history intact on the bit-compared reference path."""
        if self._rung + 1 >= len(self._rungs):
            return False
        frm = self._rungs[self._rung]
        to = self._rungs[self._rung + 1]
        if to == "fp":
            caches = self._state.get("caches")
            if caches is not None and "k_scale" in caches:
                self._state = dict(self._state,
                                   caches=self._dequant_caches(caches))
                self._scatter_jit = self._make_scatter_jit()
        self._rung += 1
        self.monitor.record_demotion(step, frm, to, why)
        return True

    def _try_promote(self, step: int) -> bool:
        """Re-probe one rung up after a healthy streak; False at the top.
        Leaving the fp rung requantizes the live state (near-exact);
        dequant -> fused is free (same buffers, different compiled path)."""
        if self._rung == 0:
            return False
        frm = self._rungs[self._rung]
        to = self._rungs[self._rung - 1]
        if frm == "fp":
            caches = self._state.get("caches")
            if caches is not None and "k_scale" not in caches:
                self._state = dict(self._state,
                                   caches=self._requant_caches(caches))
                self._scatter_jit = self._make_scatter_jit()
        self._rung -= 1
        self.monitor.record_promotion(step, frm, to)
        return True

    def _absorb_step_failure(self, e: BaseException, step: int) -> bool:
        """Decide whether a decode-step exception is survivable: True means
        the engine demoted a rung and the caller should retry the step.
        False (no lower rung, or the donated state buffers were consumed
        before the failure surfaced -- nothing valid to retry against)
        re-raises."""
        self.monitor.record_kernel_error(step)
        leaves = jax.tree_util.tree_leaves(self._state)
        if any(getattr(x, "is_deleted", lambda: False)() for x in leaves):
            return False
        return self._demote(
            f"decode step failed: {type(e).__name__}: {e}", step=step)

    def resilience_summary(self) -> Dict[str, object]:
        """Serving-side mirror of the trainer's ``resilience_summary``:
        current ladder rung, every demotion/promotion (with steps and
        reasons), quarantine / kernel-error / preemption counters, and
        rolling decode-step latency percentiles."""
        s = self.monitor.summary()
        s.update({"rung": self._rungs[self._rung],
                  "rung_index": self._rung,
                  "rungs": list(self._rungs),
                  "preemptions": self.preemptions,
                  "decode_steps": self._decode_steps})
        return s

    def _step(self) -> None:
        hooks = self.fault_hooks
        n = self._decode_steps
        if hooks is not None:
            hooks.pre_step(self, n)
        if self.paged:
            self._ensure_write_pages()
            if not self._running:
                return
        args = [self._dev(jnp.asarray(self._last_tok[:, None])),
                self._dev(jnp.asarray(self._pos))]
        if self.paged:
            args.append(self._dev(self.pool.table_array()))
        args.append(self._dev(self._next_key()))
        t0 = time.perf_counter()
        try:
            if hooks is not None:
                hooks.kernel(n)
            nxt, finite, self._state = self._decode_call(args)
        except Exception as e:
            # the ladder's guarded dispatch: a failing compiled step demotes
            # one rung and retries; anything unabsorbable re-raises into the
            # scheduler's dead-loop watchdog
            if not self._absorb_step_failure(e, n):
                raise
            nxt, finite, self._state = self._decode_call(args)
        self.monitor.record_step((time.perf_counter() - t0) * 1e3)
        self._decode_steps = n + 1
        nxt = np.asarray(nxt)
        finite = np.asarray(finite)
        if hooks is not None:
            finite = hooks.mangle_finite(n, finite)
            hooks.post_step(self, n)
        for slot in list(self._running):
            st = self._running[slot]
            self._pos[slot] += 1
            if not bool(finite[slot]):
                # non-finite logits row: quarantine THIS request (the
                # sampled token is garbage and is not recorded), free its
                # slot and pages, leave the rest of the batch untouched
                self.monitor.record_quarantine(n)
                self._finish(st, "numerics")
                continue
            self._last_tok[slot] = int(nxt[slot])
            self._record(st, int(nxt[slot]))
            if slot in self._running and self._pos[slot] >= self.max_seq:
                self._finish(st, "length")       # cache rows exhausted
        if self.monitor.should_demote(n):
            self._demote(
                f"{self.monitor.cfg.numeric_limit}+ numeric quarantines "
                f"within {self.monitor.cfg.numeric_window} steps", step=n)
        elif self._rung > 0 and self.monitor.should_reprobe():
            self._try_promote(step=n)

    def _record(self, st: _Running, tok: int) -> None:
        if st.req.eos_id is not None and tok == st.req.eos_id:
            self._finish(st, "eos")
            return
        st.tokens.append(tok)
        if len(st.tokens) >= st.req.max_new_tokens:
            self._finish(st, "length")

    def _finish(self, st: _Running, reason: str) -> None:
        del self._running[st.slot]
        self._free.append(st.slot)
        if self.paged:
            # pages recycle instantly (refcounted -- shared prefix pages
            # survive under their pin / other tables)
            self.pool.release_slot(st.slot)
            self._pos[st.slot] = 0
            self._last_tok[st.slot] = 0
        rid = st.req.request_id
        orig, prior = self._carry.pop(rid, (list(st.req.tokens), []))
        self._done.append(Response(request_id=rid, prompt=orig,
                                   tokens=prior + st.tokens,
                                   finish_reason=reason))
