"""Policy-driven quantized inference engine with continuous batching.

``Engine(model, params, policy)`` owns a fixed pool of decode *slots* (rows
of one batched KV cache / SSM state).  Requests are admitted into free slots
as they open -- a finished sequence's slot is reused on the very next step
instead of waiting for the whole batch (continuous batching) -- and every
admitted request decodes in lock-step through one jitted per-token step.

The quantization story mirrors training's :class:`QuantPolicy`, not a
parallel config surface:

* **prepared weights** -- at construction the policy is resolved per
  role/depth and every quantized weight is encoded ONCE into an int8 payload
  + scales (``repro.infer.prepare``); the jitted decode step consumes stored
  integers and contains zero weight-quantization ops;
* **int8 KV cache** -- a policy rule on the ``kv_cache`` role (e.g.
  ``"kv_cache=a8t,*=w8c"``) switches cache storage to int8 payloads with
  per-(position, head) scales.  Where the fused attention kernels support
  the spec (``policy.decode_attn_backend()``), decode attends *directly* on
  the quantized cache -- the per-slot ``(B,)`` position vectors feed the
  kernel grid as validity lengths and scatter rows, one int8 cache read and
  one int8 row write per step (kernels/decode_attn.py) -- and prefill runs
  the dequant-prologue flash kernel; otherwise the cache is dequantized on
  read (the bit-compared reference).  :meth:`Engine.path_summary` reports
  which path runs, :meth:`Engine.kv_decode_read_bytes` its analytic per-step
  KV traffic;
* **sampling** -- one :class:`SamplingParams` (greedy / temperature / top-k /
  top-p) is shared by all requests in the batch and baked into the step.

Per-slot positions: decode runs with a (B,) position vector, so each slot
writes its own cache row and masks its own history -- a request's tokens are
independent of which (or how many) neighbours share the batch (asserted by
``tests/test_infer.py::test_batch_invariance``).

Prompts are right-padded to bucketed lengths for prefill (bounded compile
count); causal masking makes the pad tail invisible and ``last_pos`` indexes
the real last-token logits.  Scope: decoder-only families (``dense``,
``moe``, ``ssm``, ``hybrid``) on a single host; encoder-decoder and VLM
serving stay on the legacy ``greedy_generate`` loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qpolicy import as_policy
from repro.infer.prepare import prepare_params
from repro.infer.sampling import SamplingParams, sample

ENGINE_FAMILIES = ("dense", "moe", "ssm", "hybrid")


@contextlib.contextmanager
def _pinned_env(values: Dict[str, str]):
    """Pin env-read knobs around a trace.  jax.jit traces lazily (on first
    call, not at Engine construction), so the step closures re-apply the
    construction-time snapshot while tracing -- the compiled path is then
    guaranteed to match what ``path_summary`` reports, however the env
    changes in between."""
    old = {k: os.environ.get(k) for k in values}
    os.environ.update(values)
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


@dataclasses.dataclass
class Request:
    """One generation request.  ``eos_id`` stops the sequence when sampled
    (the eos token is not included in the response's tokens -- this applies
    to the very first sampled token too)."""
    tokens: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    request_id: Optional[int] = None         # assigned by submit()


@dataclasses.dataclass
class Response:
    request_id: int
    prompt: List[int]
    tokens: List[int]                        # generated, eos excluded
    finish_reason: str                       # "eos" | "length"


@dataclasses.dataclass
class _Running:
    req: Request
    slot: int
    tokens: List[int] = dataclasses.field(default_factory=list)


class Engine:
    """See module docstring.  ``submit`` enqueues, ``run`` drains the queue
    and returns the finished :class:`Response` list; ``generate`` is the
    batch-array convenience used by the ``greedy_generate`` compatibility
    shim."""

    def __init__(self, model, params, policy=None, *,
                 max_slots: int = 8, max_seq: int = 256,
                 sampling: SamplingParams = SamplingParams(),
                 prepare_weights: bool = True, seed: int = 0,
                 prefill_bucket: int = 16):
        cfg = model.cfg
        if cfg.family not in ENGINE_FAMILIES:
            raise ValueError(
                f"Engine serves decoder-only families {ENGINE_FAMILIES}; "
                f"{cfg.family!r} uses train.serve.greedy_generate")
        self.model = model
        self.cfg = cfg
        self.policy = as_policy(policy)
        self.sampling = sampling
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.prefill_bucket = int(prefill_bucket)
        self.params = (prepare_params(cfg, params, self.policy)
                       if prepare_weights else params)
        self._dtype = jnp.dtype(cfg.dtype)
        self._state = model.init_decode_state(
            self.max_slots, self.max_seq, 0, self._dtype, policy=self.policy)
        from repro.kernels.decode_attn import (default_block_k,
                                               effective_block_k,
                                               fused_decode_enabled)
        self._kv_fused = (self.policy.decode_attn_backend()[0]
                          == "int8_pallas" and fused_decode_enabled())
        # report the tile the kernel will actually compile for max_seq-row
        # caches, not the requested/env tile
        self._kv_block = effective_block_k(self.max_seq)
        self._kv_env = {"REPRO_FUSED_DECODE": "1" if self._kv_fused else "0",
                        "REPRO_DECODE_BLOCK": str(default_block_k())}

        self._queue: deque = deque()
        self._free: List[int] = list(range(self.max_slots))
        self._running: Dict[int, _Running] = {}
        self._done: List[Response] = []
        self._pos = np.zeros((self.max_slots,), np.int32)
        self._last_tok = np.zeros((self.max_slots,), np.int32)
        self._next_id = 0
        self._key = jax.random.PRNGKey(seed)

        def _prefill(params, toks, last_pos):
            with _pinned_env(self._kv_env):
                return self.model.prefill(params, {"tokens": toks},
                                          policy=self.policy,
                                          max_seq=self.max_seq,
                                          last_pos=last_pos)

        def _decode(params, state, tok, pos, key):
            with _pinned_env(self._kv_env):
                logits, state = self.model.decode(params, state, tok, pos,
                                                  policy=self.policy)
            return sample(logits, self.sampling, key), state

        def _scatter(state, new, slots):
            return jax.tree_util.tree_map(
                lambda buf, n: buf.at[:, slots].set(n.astype(buf.dtype)),
                state, new)

        # donate the decode state: it is replaced by the return value every
        # step, and without donation XLA must defensively copy the buffers
        # the fused kernel aliases in place (input_output_aliases on the
        # int8 KV caches) -- a whole-cache copy per step that would erase
        # the one-read-one-row-write schedule
        self._prefill_jit = jax.jit(_prefill)
        self._decode_jit = jax.jit(_decode, donate_argnums=(1,))
        self._scatter_jit = jax.jit(_scatter, donate_argnums=(0,))

    # -- public API --------------------------------------------------------

    def submit(self, req: Request) -> int:
        toks = [int(t) for t in req.tokens]
        if not toks:
            raise ValueError("empty prompt")
        if len(toks) > self.max_seq - 1:
            raise ValueError(f"prompt length {len(toks)} needs at least one "
                             f"decode row in max_seq={self.max_seq}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = dataclasses.replace(req, tokens=toks,
                                  request_id=self._next_id)
        self._next_id += 1
        self._queue.append(req)
        return req.request_id

    def run(self) -> List[Response]:
        """Drain the queue: admit-on-free until every submitted request has a
        response.  Returns responses in request_id order."""
        self._admit()
        while self._running:
            self._step()
            self._admit()
        done, self._done = self._done, []
        return sorted(done, key=lambda r: r.request_id)

    def generate(self, prompts, max_new_tokens: int,
                 eos_id: Optional[int] = None) -> jnp.ndarray:
        """Uniform-batch convenience matching the ``greedy_generate``
        contract: (B, max_new_tokens) int32, eos-padded after the stop."""
        prompts = np.asarray(prompts)
        ids = [self.submit(Request(tokens=row.tolist(),
                                   max_new_tokens=max_new_tokens,
                                   eos_id=eos_id))
               for row in prompts]
        by_id = {r.request_id: r for r in self.run()}
        pad = eos_id if eos_id is not None else 0
        out = np.full((len(ids), max_new_tokens), pad, np.int32)
        for i, rid in enumerate(ids):
            t = by_id[rid].tokens
            if eos_id is None and len(t) < max_new_tokens:
                raise ValueError(
                    f"request {rid} truncated at {len(t)}/{max_new_tokens} "
                    f"tokens (cache rows exhausted: max_seq={self.max_seq}); "
                    "grow max_seq or pass eos_id")
            out[i, :len(t)] = t
        return jnp.asarray(out)

    def kv_cache_nbytes(self) -> int:
        """Resident bytes of the decode state (KV caches + SSM states).
        With int8 KV this is the payload+sidecar footprint the fused decode
        path reads per step -- see :meth:`kv_decode_read_bytes`."""
        return sum(int(x.size) * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(self._state))

    def _kv_mode(self) -> str:
        """Which KV consumption path decode runs: ``fused`` (int8 kernels),
        ``dequant`` (int8 storage, dequantize-on-read), ``fp``, or ``none``
        (no KV cache -- pure SSM).  Snapshotted at construction and pinned
        around the step traces (``_pinned_env``), so the report always
        matches the compiled path -- flipping ``REPRO_FUSED_DECODE`` /
        ``REPRO_DECODE_BLOCK`` after construction affects neither."""
        caches = self._state.get("caches")
        if caches is None:
            return "none"
        if "k_scale" not in caches:
            return "fp"
        return "fused" if self._kv_fused else "dequant"

    def kv_decode_read_bytes(self) -> int:
        """Analytic KV bytes moved per decode step across the stack (the
        roofline term the fused path shrinks; 0 without a KV cache).  See
        ``kernels.decode_attn.decode_kv_read_bytes`` for the per-mode
        accounting."""
        caches = self._state.get("caches")
        if caches is None:
            return 0
        from repro.kernels.decode_attn import decode_kv_read_bytes
        stacks, b, s, kh, hd = caches["k"].shape
        return decode_kv_read_bytes(self._kv_mode(), b, s, kh, hd,
                                    n_layers=stacks,
                                    fp_bytes=self._dtype.itemsize)

    def path_summary(self) -> str:
        """``train_path_summary``-style one-liner for the serving path:
        whether weights are prepared int8 payloads, and which KV consumption
        path decode runs (``kv=`` segment)."""
        from repro.core.qadam import QState
        prepared = any(isinstance(leaf, QState) for leaf in
                       jax.tree_util.tree_leaves(
                           self.params,
                           is_leaf=lambda x: isinstance(x, QState)))
        mode = self._kv_mode()
        if mode == "fused":
            kv = f"int8-fused(b{self._kv_block})"
        else:
            kv = {"dequant": "int8-dequant", "fp": "fp", "none": "none"}[mode]
        return (f"weights={'prepared-int8' if prepared else 'raw'} kv={kv}")

    def lowered_decode_hlo(self) -> str:
        """Compiled HLO text of the donated decode step -- the exact module
        ``_step`` executes (same jit, same donation, same pinned env
        snapshot), so ``repro.lint`` decode contracts analyze what serving
        runs, not a reconstruction."""
        tok = jnp.zeros((self.max_slots, 1), jnp.int32)
        pos = jnp.zeros((self.max_slots,), jnp.int32)
        key = jax.random.PRNGKey(0)
        return (self._decode_jit.lower(self.params, self._state, tok, pos,
                                       key).compile().as_text())

    # -- scheduler internals -----------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _bucket_len(self, n: int) -> int:
        b = self.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _admit(self) -> None:
        while self._queue and self._free:
            reqs: List[Request] = []
            while self._queue and len(reqs) < len(self._free):
                reqs.append(self._queue.popleft())
            groups: Dict[int, List[Request]] = {}
            for r in reqs:
                groups.setdefault(self._bucket_len(len(r.tokens)),
                                  []).append(r)
            for lb, group in groups.items():
                self._admit_group(lb, group)

    def _admit_group(self, lb: int, group: List[Request]) -> None:
        n = len(group)
        slots = [self._free.pop(0) for _ in range(n)]
        toks = np.zeros((n, lb), np.int32)
        last = np.zeros((n,), np.int32)
        for i, r in enumerate(group):
            toks[i, :len(r.tokens)] = r.tokens
            last[i] = len(r.tokens) - 1
        logits, new_state = self._prefill_jit(
            self.params, jnp.asarray(toks), jnp.asarray(last))
        self._state = self._scatter_jit(self._state, new_state,
                                        jnp.asarray(slots, jnp.int32))
        first = np.asarray(sample(logits, self.sampling, self._next_key()))
        for i, r in enumerate(group):
            st = _Running(req=r, slot=slots[i])
            self._running[slots[i]] = st
            self._pos[slots[i]] = len(r.tokens)
            self._last_tok[slots[i]] = int(first[i])
            # the FIRST sampled token goes through the same eos/length
            # bookkeeping as every later one
            self._record(st, int(first[i]))

    def _step(self) -> None:
        tok = jnp.asarray(self._last_tok[:, None])
        pos = jnp.asarray(self._pos)
        nxt, self._state = self._decode_jit(self.params, self._state, tok,
                                            pos, self._next_key())
        nxt = np.asarray(nxt)
        for slot in list(self._running):
            self._pos[slot] += 1
            self._last_tok[slot] = int(nxt[slot])
            st = self._running[slot]
            self._record(st, int(nxt[slot]))
            if slot in self._running and self._pos[slot] >= self.max_seq:
                self._finish(st, "length")       # cache rows exhausted

    def _record(self, st: _Running, tok: int) -> None:
        if st.req.eos_id is not None and tok == st.req.eos_id:
            self._finish(st, "eos")
            return
        st.tokens.append(tok)
        if len(st.tokens) >= st.req.max_new_tokens:
            self._finish(st, "length")

    def _finish(self, st: _Running, reason: str) -> None:
        del self._running[st.slot]
        self._free.append(st.slot)
        self._done.append(Response(request_id=st.req.request_id,
                                   prompt=list(st.req.tokens),
                                   tokens=st.tokens, finish_reason=reason))
