"""Paged KV cache: fixed-size int8 pages, free-list allocation, refcounts.

The dense engine allocates KV as ``(slots, max_seq)`` rows -- decode memory
scales with the worst-case length and a short request holds a full row
hostage.  Paged KV (the vLLM / Jorgensen 2025 block-table idiom) splits the
cache into fixed-size *pages*:

* ``init_paged_caches`` builds per-buffer pools shaped
  ``(n_layers, n_pages, page_size, kv_heads, head_dim)`` -- int8 payloads
  plus ``(.., page_size, kv_heads, 1)`` fp32 scale sidecars under an int8
  ``kv_spec`` (the per-(position, head) codec of ``models.attention``), fp
  pools otherwise.  One *logical* page id addresses the same physical page
  row across all layers, so the page table is per-slot only.
* :class:`PagePool` is the host-side allocator: a LIFO free list (freed
  pages recycle on the very next allocation), a per-slot page table of
  static width ``max_seq // page_size``, and per-page refcounts --
  ``share`` aliases full prefix pages into another slot's table, which is
  what makes common-system-prompt prefix sharing nearly free.
* **Page 0 is the trash page.**  It is never on the free list; empty table
  entries point at it, so inactive decode slots scatter their (discarded)
  rows harmlessly and gathers of unwritten table entries read bounded
  garbage that the validity mask excludes.

Device buffers live in ``Engine._state`` and are mutated only through the
jitted decode / page-in steps; the pool here tracks *which* physical pages
are live, never their contents.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


class CapacityError(ValueError):
    """A request cannot be held by the configured cache geometry.

    Subclasses :class:`ValueError` (the engine's historical rejection type)
    and carries the paged accounting so callers can size pools / shed load
    instead of parsing messages."""

    def __init__(self, message: str, *,
                 tokens: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 page_size: Optional[int] = None,
                 pages_needed: Optional[int] = None,
                 pages_total: Optional[int] = None,
                 pages_free: Optional[int] = None,
                 slots_total: Optional[int] = None,
                 slots_free: Optional[int] = None):
        super().__init__(message)
        self.tokens = tokens
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_needed = pages_needed
        self.pages_total = pages_total
        self.pages_free = pages_free
        self.slots_total = slots_total
        self.slots_free = slots_free


@dataclasses.dataclass
class PagePool:
    """Host-side page allocator + per-slot page tables.  See module doc."""
    n_pages: int
    page_size: int
    max_slots: int
    max_pages_per_slot: int

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError("n_pages must be >= 2 (page 0 is the trash page)")
        # LIFO free list: the page freed last is reallocated first, so the
        # freed-page hygiene property (recycled garbage masked by validity
        # lengths) is exercised constantly, not only under pressure
        self._free: List[int] = list(range(1, self.n_pages))
        self.refcount = np.zeros((self.n_pages,), np.int32)
        self.refcount[TRASH_PAGE] = 1          # pinned forever
        self.table = np.zeros((self.max_slots, self.max_pages_per_slot),
                              np.int32)
        self.used = np.zeros((self.max_slots,), np.int32)  # pages per slot

    # -- allocation --------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Physical pages currently referenced (trash page excluded)."""
        return int(np.sum(self.refcount[1:] > 0))

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise CapacityError(
                f"page pool exhausted: need {n} pages, {len(self._free)} free "
                f"of {self.n_pages - 1} allocatable",
                pages_needed=n, pages_total=self.n_pages - 1,
                pages_free=len(self._free), page_size=self.page_size)
        pids = [self._free.pop() for _ in range(n)]
        self.refcount[pids] += 1
        return pids

    def share(self, pids: List[int]) -> List[int]:
        """Alias already-live pages into another table (prefix sharing):
        one more reference each, no copy, no new pages."""
        assert all(self.refcount[p] > 0 for p in pids)
        self.refcount[list(pids)] += 1
        return list(pids)

    def pin(self, pids: List[int]) -> None:
        """Extra permanent reference (cached prefixes survive every release)."""
        self.refcount[list(pids)] += 1

    def release(self, pids: List[int]) -> None:
        for p in pids:
            if p == TRASH_PAGE:
                continue
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)           # instant recycle
            assert self.refcount[p] >= 0

    # -- per-slot tables ---------------------------------------------------

    def assign(self, slot: int, pids: List[int]) -> None:
        """Install a slot's page list (already ref'd via alloc/share)."""
        assert len(pids) <= self.max_pages_per_slot
        self.table[slot] = TRASH_PAGE
        self.table[slot, :len(pids)] = pids
        self.used[slot] = len(pids)

    def append(self, slot: int, pid: int) -> None:
        """Map one more (alloc'd) page at the end of a slot's table."""
        u = int(self.used[slot])
        assert u < self.max_pages_per_slot
        self.table[slot, u] = pid
        self.used[slot] = u + 1

    def slot_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self.table[slot, :int(self.used[slot])]]

    def release_slot(self, slot: int) -> List[int]:
        """Free a finished slot: decref its pages (recycling any that drop
        to zero), point its table back at the trash page.  Returns the page
        ids that were mapped."""
        pids = self.slot_pages(slot)
        self.release(pids)
        self.table[slot] = TRASH_PAGE
        self.used[slot] = 0
        return pids

    def table_array(self) -> jnp.ndarray:
        """Device copy of the full (max_slots, max_pages_per_slot) table --
        the scalar-prefetch operand of the paged decode kernel."""
        return jnp.asarray(self.table)


def pages_for(n_tokens: int, page_size: int) -> int:
    """ceil(n_tokens / page_size) -- pages needed to hold n_tokens rows."""
    return -(-int(n_tokens) // int(page_size))


def init_paged_caches(cfg, n_pages: int, page_size: int, dtype,
                      kv_spec=None) -> Dict[str, jnp.ndarray]:
    """Stacked page pools for the whole layer stack.  Same dict structure as
    the dense decode caches (``k``/``v`` [+ ``k_scale``/``v_scale``]), so the
    engine's state tree, ``_kv_mode`` probing and the layer scan's stacked-xs
    convention all apply unchanged -- only the row axes differ:
    ``(L, n_pages, page_size, K, hd)`` instead of ``(L, B, max_seq, K, hd)``.
    """
    from repro.core.quantizer import storage_dtype
    k, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    if kv_spec is not None:
        qdt = storage_dtype(kv_spec.bits)
        return {
            "k": jnp.zeros((L, n_pages, page_size, k, hd), qdt),
            "v": jnp.zeros((L, n_pages, page_size, k, hd), qdt),
            "k_scale": jnp.zeros((L, n_pages, page_size, k, 1), jnp.float32),
            "v_scale": jnp.zeros((L, n_pages, page_size, k, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((L, n_pages, page_size, k, hd), dtype),
        "v": jnp.zeros((L, n_pages, page_size, k, hd), dtype),
    }


#: logical axes of one paged pool buffer ``(L, n_pages, page, K, hd)`` --
#: pages and rows are never sharded (a page is the DMA unit of exactly one
#: shard's kernel launch); the kv-head axis carries the tensor parallelism.
PAGED_POOL_AXES = ("layers", None, None, "kv", None)


def paged_cache_shardings(rules, caches: Dict[str, jnp.ndarray]
                          ) -> Dict[str, jnp.ndarray]:
    """NamedSharding per pool buffer: int8 payload pools tensor-parallel over
    the kv-head axis, fp32 scale sidecars co-sharded with their payloads
    (same axes tuple; the sidecar's trailing size-1 dim is replicated) --
    each shard's paged decode kernel DMAs pages of its local head slice
    only."""
    return {k: rules.sharding_for(v.shape, PAGED_POOL_AXES)
            for k, v in caches.items()}


def place_paged_caches(rules, caches: Dict[str, jnp.ndarray]
                       ) -> Dict[str, jnp.ndarray]:
    """Put the page pools onto ``rules.mesh`` per
    :func:`paged_cache_shardings`."""
    return jax.device_put(caches, paged_cache_shardings(rules, caches))


def page_nbytes(caches: Dict[str, jnp.ndarray]) -> int:
    """Bytes one *logical* page occupies across every buffer and layer --
    the unit ``Engine.live_kv_bytes`` scales by."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(caches):
        L = leaf.shape[0]
        per_page = int(np.prod(leaf.shape[2:]))
        total += L * per_page * leaf.dtype.itemsize
    return total
