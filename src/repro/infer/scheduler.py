"""Async continuous-batching host loop for the serving engine.

The :class:`~repro.infer.engine.Engine` owns the device side (jitted
prefill / decode / page-in steps, the page pool, slot bookkeeping); the
:class:`Scheduler` owns the host side around it:

* a thread-safe **submit queue** (``enqueue`` may be called from any thread
  -- the Poisson-trace benchmark submits from a generator thread while the
  loop decodes);
* the **scheduling loop** (:meth:`step`): drain submissions, admit by free
  pages (the engine's HOL-fair ``_admit``), run one decode step, hand
  finished sequences to the emit thread;
* a background **detokenize/emit thread**: finished responses are finalized
  (optional ``Engine.detokenizer`` producing ``Response.text``) and their
  completion events set *off* the scheduling loop, so token emission
  overlaps prefill/decode instead of serializing with them;
* wall-clock **latency accounting** per request (submit -> finish),
  summarized by :meth:`latency_stats` (p50/p99 -- the serving numbers the
  ROADMAP's "millions of users" item asks for);
* **per-request deadlines**: a ``Request.timeout_s`` is armed at submit;
  the scheduling loop sweeps expired requests every tick and cancels them
  through ``Engine.cancel`` (finish reason ``"timeout"``, slot and pages
  freed) -- one stuck or oversized request cannot hold resources forever;
* **admission control + load shedding**: overload rejection is a
  first-class *outcome* (finish reason ``"shed"`` with a
  ``Response.retry_after_s`` back-off hint), never an exception escaping
  the loop.  Three shed points: a bounded submit queue (``max_queue``
  waiting requests -- the (queued + running) depth gate at :meth:`enqueue`);
  deadline-aware shedding (a queued request that cannot finish before its
  deadline by the rolling decode-step estimate is rejected immediately
  instead of burning pages until the timeout sweep kills it); and the
  idle-inadmissible head (a request the (prefix-pinned) pool can never fit
  -- previously a ``CapacityError`` straight out of the loop, killing
  serving for everyone).  Precedence: the timeout sweep runs first, so an
  already-expired deadline is always a ``"timeout"``;
* a **dead-loop watchdog**: if the background scheduling thread dies, every
  pending completion event is set so blocked ``wait()`` callers wake up and
  re-raise the loop's exception instead of hanging until their own timeout
  (``stop()`` re-raises it too, and raises ``RuntimeError`` when the loop
  thread fails to join -- a hung decode step must not masquerade as a clean
  shutdown).  ``fault_hook`` (set by the resilience harness from
  ``train.faults.FaultPlan.scheduler_hook``) is called with the tick number
  at the top of every :meth:`step` to inject exactly this failure
  deterministically.

Two driving modes share every code path:

* ``run()`` -- synchronous drain, what ``Engine.run`` delegates to: loop
  until every submitted request has a response, then return them in
  request-id order (the engine's historical contract).
* ``start()`` / ``stop()`` -- the loop runs in a background thread;
  ``wait(ids)`` blocks on completion events.  Used by
  ``benchmarks/serve_throughput.py --trace`` to overlap timed arrivals
  with decode.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency on the hot path)."""
    if not xs:
        return float("nan")
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


class Scheduler:
    def __init__(self, engine, max_queue: Optional[int] = None):
        self.engine = engine
        #: bounded submit queue: enqueue sheds when (queued + running)
        #: already holds this many requests; None = unbounded (the
        #: pre-admission-control behaviour)
        self.max_queue = max_queue
        self._inbox: "queue.Queue" = queue.Queue()
        self._emit_q: "queue.Queue" = queue.Queue()
        self._results: Dict[int, object] = {}
        self._events: Dict[int, threading.Event] = {}
        self._times: Dict[int, Dict[str, float]] = {}
        self._lock = threading.Lock()
        self._emit_thread: Optional[threading.Thread] = None
        self._loop_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._loop_error: Optional[BaseException] = None
        self._deadlines: Dict[int, float] = {}        # rid -> monotonic bound
        #: test/resilience hook called with the tick number at the top of
        #: every step() -- raising here simulates a dying loop thread
        self.fault_hook: Optional[Callable[[int], None]] = None
        self.peak_live_bytes = 0
        self.steps = 0
        self.timeouts = 0
        self.peak_queue_depth = 0
        self._reasons: Dict[str, int] = {}     # finish_reason -> count
        self._good_tokens = 0                  # tokens of completed requests

    # -- submission (any thread) ------------------------------------------

    def queue_depth(self) -> int:
        """Requests waiting or running: submitted-not-yet-drained (inbox) +
        the engine's queue + running slots.  Reads of the engine deques from
        the submitting thread are len()-only (atomic under the GIL)."""
        return (self._inbox.qsize() + len(self.engine._queue)
                + len(self.engine._running))

    def _retry_after(self, req) -> float:
        """Back-off hint attached to shed responses: a rough drain estimate
        from the rolling decode-step time and the work ahead; a 50ms floor
        covers the cold engine (no step history yet)."""
        step_s = self.engine.monitor.mean_step_s() or 0.05
        depth = max(1, len(self.engine._queue) + len(self.engine._running))
        budget = max(1, int(getattr(req, "max_new_tokens", 1)))
        return round(max(0.05, step_s * min(depth * budget, 10_000)), 3)

    def _shed_at_submit(self, req, now: float) -> None:
        """Bounded-queue rejection on the submitting thread: the request
        never reaches the scheduling loop; its ``"shed"`` response flows
        through the normal emit thread so ``wait``/``run`` see it like any
        other finish."""
        from repro.infer.engine import Response
        resp = Response(request_id=req.request_id, prompt=list(req.tokens),
                        tokens=[], finish_reason="shed",
                        retry_after_s=self._retry_after(req))
        with self._lock:
            self._events[req.request_id] = threading.Event()
            self._times[req.request_id] = {"submit": now}
        self._ensure_emit_thread()
        self._emit_q.put(resp)

    def enqueue(self, req) -> None:
        """Called by ``Engine.submit`` after validation: records the arrival
        time and hands the request to the scheduling loop -- or sheds it on
        the spot when the bounded submit queue is full."""
        now = time.monotonic()
        if self.max_queue is not None \
                and self.queue_depth() >= self.max_queue:
            self._shed_at_submit(req, now)
            return
        with self._lock:
            self._events[req.request_id] = threading.Event()
            self._times[req.request_id] = {"submit": now}
            if getattr(req, "timeout_s", None) is not None:
                self._deadlines[req.request_id] = now + req.timeout_s
        self._inbox.put(req)

    # -- emit thread -------------------------------------------------------

    def _ensure_emit_thread(self) -> None:
        if self._emit_thread is None or not self._emit_thread.is_alive():
            self._emit_thread = threading.Thread(
                target=self._emit_loop, name="repro-emit", daemon=True)
            self._emit_thread.start()

    def _emit_loop(self) -> None:
        detok = getattr(self.engine, "detokenizer", None)
        while True:
            resp = self._emit_q.get()
            try:
                if detok is not None:
                    resp.text = detok(resp.tokens)
                now = time.monotonic()
                with self._lock:
                    t = self._times.setdefault(resp.request_id, {})
                    t["finish"] = now
                    reason = resp.finish_reason
                    if reason == "shed":
                        t["shed"] = True
                    self._reasons[reason] = self._reasons.get(reason, 0) + 1
                    if reason in ("eos", "length"):
                        self._good_tokens += len(resp.tokens)
                    self._results[resp.request_id] = resp
                    ev = self._events.get(resp.request_id)
                if ev is not None:
                    ev.set()
            finally:
                self._emit_q.task_done()

    # -- the loop ----------------------------------------------------------

    def _drain_inbox(self) -> int:
        n = 0
        while True:
            try:
                self.engine._queue.append(self._inbox.get_nowait())
                n += 1
            except queue.Empty:
                return n

    def _sweep_timeouts(self) -> None:
        """Cancel every request past its deadline (queued or running); runs
        on the scheduling thread, before admission, so an expired queued
        request is never admitted."""
        with self._lock:
            if not self._deadlines:
                return
            now = time.monotonic()
            expired = [rid for rid, dl in self._deadlines.items()
                       if now >= dl]
            for rid in expired:
                del self._deadlines[rid]
        for rid in expired:
            if self.engine.cancel(rid, reason="timeout"):
                self.timeouts += 1

    def _sweep_sheds(self) -> None:
        """Deadline-aware shedding: reject queued requests that cannot finish
        before their deadline by the rolling decode-step estimate.  Runs
        after the timeout sweep (an expired deadline is always a
        ``"timeout"``); refuses to guess on a cold engine (no step history
        -> no estimate -> no shed)."""
        step_s = self.engine.monitor.mean_step_s()
        if step_s is None:
            return
        queued = {r.request_id: r for r in self.engine._queue}
        if not queued:
            return
        now = time.monotonic()
        with self._lock:
            doomed = []
            for rid, dl in self._deadlines.items():
                req = queued.get(rid)
                if req is None:
                    continue
                # prefill step + one decode step per budgeted token
                est = (1 + int(req.max_new_tokens)) * step_s
                if now + est > dl:
                    doomed.append((rid, req))
            for rid, _ in doomed:
                del self._deadlines[rid]
        for rid, req in doomed:
            self.engine.cancel(rid, reason="shed",
                               retry_after_s=self._retry_after(req))

    def step(self) -> bool:
        """One scheduling tick: drain submissions, sweep deadlines, admit,
        decode one step, emit finishes.  Returns False when fully idle."""
        if self.fault_hook is not None:
            self.fault_hook(self.steps)
        eng = self.engine
        self._drain_inbox()
        self._sweep_timeouts()
        self._sweep_sheds()
        self.peak_queue_depth = max(self.peak_queue_depth,
                                    len(eng._queue) + len(eng._running))
        eng._admit()
        if eng._running:
            eng._step()
            eng._admit()          # freed slots/pages readmit immediately
        self.steps += 1
        self.peak_live_bytes = max(self.peak_live_bytes,
                                   eng.live_kv_bytes())
        for resp in eng._drain_done():
            with self._lock:
                self._deadlines.pop(resp.request_id, None)
            self._ensure_emit_thread()
            self._emit_q.put(resp)
        if eng._queue and not eng._running:
            # nothing running and nothing admissible: the queued request can
            # never fit (pinned prefixes shrank the pool below its need).
            # With a deadline armed we idle until the sweep cancels it
            # (finish reason "timeout") instead of killing the loop.  An
            # undeadlined head gets patience first -- a transiently dry pool
            # (fault hold, preempted pages mid-recycle) must not shed a
            # request that would fit next tick -- then is shed (finish
            # reason "shed", retry-after hint); pre-admission-control this
            # raised CapacityError out of the loop, killing serving for
            # every in-flight request.
            from repro.infer.engine import STARVATION_LIMIT
            req = eng._queue[0]
            rid = req.request_id
            with self._lock:
                deadlined = rid in self._deadlines
            if deadlined:
                return True
            if eng.paged and eng._skips.get(rid, 0) < STARVATION_LIMIT:
                eng._skips[rid] = eng._skips.get(rid, 0) + 1
                return True
            eng.cancel(rid, reason="shed",
                       retry_after_s=self._retry_after(req))
            for resp in eng._drain_done():
                with self._lock:
                    self._deadlines.pop(resp.request_id, None)
                self._ensure_emit_thread()
                self._emit_q.put(resp)
            return True
        return bool(eng._running or eng._queue or not self._inbox.empty())

    def run(self) -> List[object]:
        """Synchronous drain (the ``Engine.run`` contract): process until
        idle, wait for the emit thread, return every unclaimed response in
        request-id order."""
        if self._loop_thread is not None and self._loop_thread.is_alive():
            raise RuntimeError("scheduler loop already running; use wait()")
        while self.step():
            pass
        self._emit_q.join()
        with self._lock:
            out = [self._results.pop(rid)
                   for rid in sorted(self._results)]
            for r in out:
                self._events.pop(r.request_id, None)
        return out

    # -- async serve mode --------------------------------------------------

    def start(self) -> None:
        """Run the scheduling loop in a background thread (serve mode)."""
        if self._loop_thread is not None and self._loop_thread.is_alive():
            return
        self._stop.clear()
        self._loop_error = None
        if getattr(self.engine, "_aot", False):
            # AOT engines compile at construction; warmup() is idempotent,
            # so this only pays if construction was asked to defer -- either
            # way no trace/compile can land inside the timed serve loop
            self.engine.warmup()
        self._ensure_emit_thread()

        def loop():
            try:
                while not self._stop.is_set():
                    if not self.step():
                        time.sleep(1e-3)
            except BaseException as e:   # lint: except-ok -- the watchdog:
                # park the error for wait()/stop() and wake every blocked
                # waiter; swallowing it here would hang them forever
                self._loop_error = e
                self._wake_all()

        self._loop_thread = threading.Thread(target=loop, name="repro-sched",
                                             daemon=True)
        self._loop_thread.start()

    def _wake_all(self) -> None:
        """Dead-loop watchdog: set every pending completion event so blocked
        ``wait()`` callers re-check ``_loop_error`` instead of hanging."""
        with self._lock:
            evs = [ev for rid, ev in self._events.items()
                   if rid not in self._results]
        for ev in evs:
            ev.set()

    def stop(self, join_timeout_s: float = 60.0) -> None:
        """Stop the background loop.  Raises ``RuntimeError`` if the loop
        thread fails to join within ``join_timeout_s`` (a decode step wedged
        in the runtime must not masquerade as a clean shutdown -- previously
        this returned silently and the next ``start()`` raced the zombie),
        and re-raises the loop's own error if it died."""
        self._stop.set()
        if self._loop_thread is not None:
            t = self._loop_thread
            t.join(timeout=join_timeout_s)
            if t.is_alive():
                raise RuntimeError(
                    f"scheduler loop thread failed to join within "
                    f"{join_timeout_s:g}s; a decode step is likely wedged "
                    "in the runtime (the thread is a daemon and will not "
                    "block interpreter exit)")
            self._loop_thread = None
        if self._loop_error is not None:
            raise self._loop_error

    def wait(self, rids: List[int], timeout: Optional[float] = None) -> None:
        """Block until every listed request has a response.  Raises the
        scheduling loop's exception if the loop thread died (before, during,
        or after the wait -- the watchdog wakes blocked waiters) and
        ``TimeoutError`` when the wall-clock ``timeout`` expires first."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for rid in rids:
            if self._loop_error is not None:
                raise self._loop_error
            ev = self._events.get(rid)
            if ev is None:
                continue
            left = None if deadline is None else deadline - time.monotonic()
            if not ev.wait(left):
                if self._loop_error is not None:
                    raise self._loop_error
                raise TimeoutError(f"request {rid} not finished in time")
            if self._loop_error is not None:
                with self._lock:
                    has_result = rid in self._results
                if not has_result:
                    raise self._loop_error

    def result(self, rid: int):
        with self._lock:
            self._events.pop(rid, None)
            return self._results.pop(rid)

    # -- metrics -----------------------------------------------------------

    def latency_stats(self) -> Dict[str, float]:
        """End-to-end (submit -> finish) latency over finished requests,
        plus overload accounting: ``completed``/``shed``/``timeout``/
        ``numerics`` outcome counts, ``goodput_tok_s`` (tokens of
        *completed* requests over the serving span), and queue-depth
        telemetry.  Latency percentiles exclude shed requests -- a
        rejection in microseconds would make p50 meaningless; ``n`` stays
        "requests that actually ran"."""
        with self._lock:
            lats = [t["finish"] - t["submit"] for t in self._times.values()
                    if "finish" in t and not t.get("shed")]
            finishes = [t["finish"] for t in self._times.values()
                        if "finish" in t]
            submits = [t["submit"] for t in self._times.values()]
            reasons = dict(self._reasons)
            good_tokens = self._good_tokens
        span = (max(finishes) - min(submits)) if finishes else 0.0
        return {"n": len(lats),
                "p50_s": _percentile(lats, 50),
                "p99_s": _percentile(lats, 99),
                "mean_s": (sum(lats) / len(lats)) if lats else float("nan"),
                "completed": (reasons.get("eos", 0)
                              + reasons.get("length", 0)),
                "shed": reasons.get("shed", 0),
                "timeout": reasons.get("timeout", 0),
                "numerics": reasons.get("numerics", 0),
                "goodput_tok_s": good_tokens / max(span, 1e-9),
                "queue_depth": self.queue_depth(),
                "peak_queue_depth": self.peak_queue_depth}
