"""Token sampling surface shared by every request in an engine batch.

One :class:`SamplingParams` (a frozen dataclass, so it hashes into jit
static args) configures the whole decode batch: greedy when ``temperature ==
0``, otherwise temperature-scaled categorical with optional top-k and
nucleus (top-p) truncation.  ``sample`` runs inside the jitted decode step;
rows of a batch draw independent tokens from one per-step key.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy (argmax; top_k / top_p ignored).
    top_k == 0 and top_p == 1.0 disable their truncations."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def _top_k_mask(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits >= kth, logits, _NEG)


def _top_p_mask(logits: jnp.ndarray, p: float) -> jnp.ndarray:
    """Nucleus truncation: keep the smallest prefix of descending-probability
    tokens whose cumulative mass reaches ``p`` (the top-1 token always
    survives)."""
    desc = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p                 # mass *before* this token < p
    kth = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(logits >= kth, logits, _NEG)


def sample(logits: jnp.ndarray, sp: SamplingParams,
           key: jax.Array) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32 next tokens."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k:
        l = _top_k_mask(l, min(sp.top_k, l.shape[-1]))
    if sp.top_p < 1.0:
        l = _top_p_mask(l, sp.top_p)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
