"""Prepared weights: resolve the QuantPolicy once and quantize each weight
into a stored integer payload + scales at engine construction time.

Training-style quantized serving re-runs fake quantize->dequantize on every
weight at every decode step -- an absmax reduce, a round, a clip, and two
multiplies per matmul per token.  At inference the weights never change, so
the engine quantizes them ONCE here, into :class:`~repro.core.qadam.QState`
containers (the same payload+scale+zero triple the quantized optimizer
states use).  ``QuantPolicy.linear`` recognizes a ``QState`` weight and runs
the dequant-read matmul (or the real-int8 Pallas kernel when the policy's
backend is ``int8_pallas`` and the recipe fits the W8A8 contract) -- the
jitted decode step contains no weight quantization ops at all, which
``tests/test_infer.py`` asserts by counting ``round-nearest`` HLO ops.

Scale layout: quantization reduces over the *input* axis (axis -2) for
per-channel specs, so a stacked block weight (L, d_in, d_out) gets scales
(L, 1, d_out) and the layer scan / MoE expert vmap slice payload and scales
together.  This matches the in-trace ``fake_quant`` grid on each 2-D slice
exactly, so prepared decode is bit-equivalent to fake-quant decode.

Weights stay raw (fp) when:

* the role resolves to fp (embed / lm-head / router stay fp by default);
* the policy is depth-banded such that layers of one stacked tensor resolve
  to different specs (a scanned weight must be uniformly typed);
* the spec uses a blockwise / sqrt-domain codec (no flat payload layout).

Stochastic-rounding weight specs are prepared with nearest rounding:
"quantize once" has no noise stream to resample.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.qadam import QState, state_nbytes
from repro.core.qconfig import Granularity, QuantSpec
from repro.core.qpolicy import QuantPolicy, Resolved, as_policy
from repro.core.quantizer import compute_scale_zero, storage_dtype

# weight-leaf name -> role, per enclosing module key
_ATTN_ROLES = {"wq": "attn_qkv", "wk": "attn_qkv", "wv": "attn_qkv",
               "wo": "attn_out"}
_MLP_ROLES = {"w_gate": "mlp_up", "w_up": "mlp_up", "w_fc1": "mlp_up",
              "w_down": "mlp_down", "w_fc2": "mlp_down"}
# the router is skipped: its call site casts the weight (fp by default)
_MOE_ROLES = {"w_gate": "mlp_up", "w_up": "mlp_up", "w_down": "mlp_down"}
_SSM_ROLES = {"in_z": "ssm_in", "in_x": "ssm_in", "in_bc": "ssm_in",
              "in_dt": "ssm_in", "out_proj": "ssm_out"}
_MODULE_TABLES = {"attn": _ATTN_ROLES, "cross_attn": _ATTN_ROLES,
                  "mlp": _MLP_ROLES, "moe": _MOE_ROLES, "ssm": _SSM_ROLES}


def quantize_weight(w: jnp.ndarray, spec: QuantSpec) -> QState:
    """Quantize one weight (possibly stacked: (L, ...) scan dim and/or (E,
    ...) expert dim ahead of the (d_in, d_out) core) into payload + scales.
    Reduction runs over the trailing matmul axes only, so every leading index
    gets its own scale grid -- identical to in-trace fake_quant per slice
    (same ``compute_scale_zero`` formula, explicit axes)."""
    xf = w.astype(jnp.float32)
    if spec.granularity is Granularity.PER_CHANNEL:
        axes = (-2,)
    elif spec.granularity is Granularity.PER_TENSOR:
        axes = (-2, -1)
    else:                                    # PER_TOKEN: one scale per in-row
        axes = (-1,)
    scale, zero = compute_scale_zero(xf, spec, axes=axes)
    q = jnp.clip(jnp.round(xf / scale) - zero, spec.qmin, spec.qmax)
    return QState(q.astype(storage_dtype(spec.bits)), scale, zero)


def _preparable_spec(res: Optional[Resolved]) -> Optional[QuantSpec]:
    if res is None or res.recipe is None:
        return None
    spec = res.recipe.weights
    if spec is None or spec.block_size or spec.sqrt_domain:
        return None
    return spec


def prepare_params(cfg, params: Dict[str, Any], policy) -> Dict[str, Any]:
    """Return a copy of ``params`` with every weight the policy quantizes
    replaced by its stored-integer :class:`QState`.  The result is consumed
    by the unchanged model code: ``policy.linear`` dispatches on the leaf
    type, ``cast_params`` passes QState through."""
    policy = as_policy(policy)
    n_layers = cfg.n_layers
    # quantize what the model would have quantized: the carrier-precision
    # (bf16 AMP) view of the weight, so the grid matches in-trace fake_quant
    # bit-exactly (scales come from the cast values)
    carrier = jnp.dtype(cfg.dtype)

    def resolve_uniform(role: str, depthful: bool) -> Optional[Resolved]:
        if not depthful:
            return policy.resolve(role)
        rs = [policy.resolve(role, i, n_layers) for i in range(n_layers)]
        return rs[0] if all(r == rs[0] for r in rs) else None

    def prep(w, role: str, depthful: bool):
        spec = _preparable_spec(resolve_uniform(role, depthful))
        return w if spec is None else quantize_weight(w.astype(carrier), spec)

    def prep_module(key: str, sub: Dict[str, Any], depthful: bool):
        table = _MODULE_TABLES.get(key)
        if table is None:
            return sub
        return {k: (prep(v, table[k], depthful) if k in table else v)
                for k, v in sub.items()}

    out = dict(params)
    if "blocks" in out:
        out["blocks"] = {k: prep_module(k, v, True)
                         for k, v in out["blocks"].items()}
    if "shared" in out:                      # zamba2: depth-less shared block
        shared = {k: prep_module(k, v, False)
                  for k, v in out["shared"].items()}
        shared["proj"] = prep(out["shared"]["proj"], "shared_proj", False)
        out["shared"] = shared
    if "patch_proj" in out:
        out["patch_proj"] = prep(out["patch_proj"], "patch_proj", False)
    return out


def prepared_param_shardings(rules, params: Dict[str, Any],
                             axes_tree) -> Dict[str, Any]:
    """NamedSharding tree for a (possibly prepared) parameter tree on
    ``rules.mesh``.  A :class:`QState` leaf gets its payload sharded by the
    raw weight's logical axes and the fp32 scale / zero sidecars *co-sharded*
    with it: the sidecar keeps the payload's trailing (output-channel) dims,
    and its size-1 reduced dims fail the divisibility check and drop to
    replicated -- so every shard's payload slice arrives with exactly the
    scale rows it dequantizes, no cross-chip sidecar traffic."""
    from repro.parallel.sharding import Rules  # noqa: F401  (doc anchor)

    def side(qshape, s, ax):
        # sidecars from compute_scale_zero keep the payload's rank (keepdims
        # reductions); anything else (scalar zero points) is replicated
        if getattr(s, "ndim", -1) == len(qshape):
            return rules.sharding_for(s.shape, ax)
        return rules.replicated()

    def one(leaf, ax):
        if isinstance(leaf, QState):
            return QState(rules.sharding_for(leaf.q.shape, ax),
                          side(leaf.q.shape, leaf.scale, ax),
                          side(leaf.q.shape, leaf.zero, ax))
        return rules.sharding_for(leaf.shape, ax)

    return jax.tree_util.tree_map(
        one, params, axes_tree, is_leaf=lambda x: isinstance(x, QState))


def place_params(rules, params: Dict[str, Any], axes_tree) -> Dict[str, Any]:
    """Put a (possibly prepared) parameter tree onto ``rules.mesh`` with
    :func:`prepared_param_shardings` -- FSDP/TP placement of payloads with
    co-sharded sidecars."""
    return jax.device_put(params,
                          prepared_param_shardings(rules, params, axes_tree))


def params_nbytes(params: Dict[str, Any]) -> int:
    """Resident bytes of a (possibly prepared) parameter tree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QState)):
        total += state_nbytes(leaf) if isinstance(leaf, QState) else \
            int(leaf.size) * leaf.dtype.itemsize
    return total
