"""Policy-driven quantized inference: prepared weights, int8 KV cache,
paged KV + continuous batching.  See ``repro.infer.engine`` for the
architecture, ``repro.infer.pages`` for the page pool, and
``repro.infer.scheduler`` for the async host loop."""
from repro.infer.engine import (ENGINE_FAMILIES, PAGED_FAMILIES, Engine,
                                Request, Response)
from repro.infer.pages import (CapacityError, PagePool, init_paged_caches,
                               page_nbytes, pages_for)
from repro.infer.prepare import params_nbytes, prepare_params, quantize_weight
from repro.infer.resilience import EngineMonitor, MonitorConfig
from repro.infer.sampling import SamplingParams, sample
from repro.infer.scheduler import Scheduler

__all__ = ["ENGINE_FAMILIES", "PAGED_FAMILIES", "Engine", "Request",
           "Response", "CapacityError", "PagePool", "init_paged_caches",
           "page_nbytes", "pages_for", "params_nbytes", "prepare_params",
           "quantize_weight", "EngineMonitor", "MonitorConfig",
           "SamplingParams", "sample", "Scheduler"]
