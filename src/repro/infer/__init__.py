"""Policy-driven quantized inference: prepared weights, int8 KV cache,
continuous batching.  See ``repro.infer.engine`` for the architecture."""
from repro.infer.engine import ENGINE_FAMILIES, Engine, Request, Response
from repro.infer.prepare import params_nbytes, prepare_params, quantize_weight
from repro.infer.sampling import SamplingParams, sample

__all__ = ["ENGINE_FAMILIES", "Engine", "Request", "Response",
           "params_nbytes", "prepare_params", "quantize_weight",
           "SamplingParams", "sample"]
