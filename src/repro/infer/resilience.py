"""Serving-side health monitor: the engine's view of its own step stream.

The training loop judges steps with a :class:`~repro.train.sentinel.
StabilitySentinel`; the serving engine gets the same supervision plane in
miniature.  An :class:`EngineMonitor` is attached to every
:class:`~repro.infer.engine.Engine` and records, per decode step:

* **step latency** (a rolling window -- feeds the scheduler's retry-after
  hints, the deadline-aware shed estimate, and the ``slow_step`` counter);
* **numeric quarantines** -- a running request whose logits row went
  non-finite was evicted (finish reason ``"numerics"``); repeated
  quarantines inside ``numeric_window`` steps demote the engine one rung
  down its compiled-path ladder (fused -> dequant-on-read -> fp reference);
* **kernel errors** -- a decode-step exception absorbed by the ladder;
* **demotions / promotions** -- every ladder transition, with the step it
  happened on and why, so the resilience gate can assert the scripted walk
  was followed *exactly*;
* a **healthy streak** -- consecutive clean steps; once it reaches
  ``reprobe_after`` the engine re-probes one rung up (re-engaging the fast
  path after a transient fault, mirroring the training sentinel's fallback
  window).

The monitor is pure host-side bookkeeping: nothing here is traced, and the
healthy path's compiled artifacts are byte-identical with or without it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (no numpy on the hot path)."""
    if not xs:
        return float("nan")
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Knobs for the serving degradation ladder.

    ``numeric_limit`` quarantines inside any ``numeric_window``-step span
    (since the last transition) demote the engine one rung; after
    ``reprobe_after`` consecutive healthy steps a degraded engine re-probes
    one rung up.  ``slow_step_ms`` (optional) only *counts* outliers -- a
    slow step is an observability signal, not a demotion trigger (CPU CI
    jitter would flap the ladder)."""
    latency_window: int = 256
    numeric_window: int = 8
    numeric_limit: int = 2
    reprobe_after: int = 12
    slow_step_ms: Optional[float] = None


class EngineMonitor:
    def __init__(self, cfg: Optional[MonitorConfig] = None):
        self.cfg = cfg or MonitorConfig()
        self._lat_ms: Deque[float] = deque(maxlen=self.cfg.latency_window)
        self._quarantine_steps: List[int] = []
        self.demotions: List[Dict[str, object]] = []
        self.promotions: List[Dict[str, object]] = []
        self.quarantined = 0
        self.kernel_errors = 0
        self.slow_steps = 0
        self.healthy_streak = 0
        self._last_transition_step = -1

    # -- recording (engine internals, scheduler thread) --------------------

    def record_step(self, ms: float) -> None:
        self._lat_ms.append(float(ms))
        self.healthy_streak += 1
        if self.cfg.slow_step_ms is not None and ms > self.cfg.slow_step_ms:
            self.slow_steps += 1

    def record_quarantine(self, step: int) -> None:
        self.quarantined += 1
        self.healthy_streak = 0
        self._quarantine_steps.append(int(step))

    def record_kernel_error(self, step: int) -> None:
        self.kernel_errors += 1
        self.healthy_streak = 0

    def record_demotion(self, step: int, frm: str, to: str,
                        why: str) -> None:
        self.demotions.append({"step": int(step), "from": frm, "to": to,
                               "why": why})
        self.healthy_streak = 0
        self._last_transition_step = int(step)

    def record_promotion(self, step: int, frm: str, to: str) -> None:
        self.promotions.append({"step": int(step), "from": frm, "to": to})
        # the re-engaged rung must re-earn its streak before probing higher
        self.healthy_streak = 0
        self._last_transition_step = int(step)

    # -- judgments ---------------------------------------------------------

    def should_demote(self, step: int) -> bool:
        """``numeric_limit`` quarantines within the trailing
        ``numeric_window`` steps, all after the last ladder transition."""
        lo = max(int(step) - self.cfg.numeric_window,
                 self._last_transition_step)
        recent = [s for s in self._quarantine_steps if s > lo or s == step]
        return len(recent) >= self.cfg.numeric_limit

    def should_reprobe(self) -> bool:
        return self.healthy_streak >= self.cfg.reprobe_after

    # -- metrics -----------------------------------------------------------

    def mean_step_s(self) -> Optional[float]:
        """Rolling mean decode-step seconds; None before any step ran (the
        scheduler's shed estimate refuses to guess without history)."""
        if not self._lat_ms:
            return None
        return sum(self._lat_ms) / len(self._lat_ms) / 1e3

    def step_ms(self) -> Dict[str, float]:
        xs = list(self._lat_ms)
        return {"n": len(xs),
                "p50": _percentile(xs, 50),
                "p99": _percentile(xs, 99),
                "mean": (sum(xs) / len(xs)) if xs else float("nan")}

    def summary(self) -> Dict[str, object]:
        return {"quarantined": self.quarantined,
                "kernel_errors": self.kernel_errors,
                "slow_steps": self.slow_steps,
                "healthy_streak": self.healthy_streak,
                "demotions": [dict(d) for d in self.demotions],
                "promotions": [dict(p) for p in self.promotions],
                "step_ms": self.step_ms()}
