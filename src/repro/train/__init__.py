from repro.train.faults import FaultInjected, FaultPlan
from repro.train.loop import LoopConfig, Trainer
from repro.train.sentinel import SentinelConfig, StabilitySentinel, Verdict
from repro.train.serve import greedy_generate, greedy_generate_reference
from repro.train.step import (TrainState, batch_shardings, init_train_state,
                              make_eval_step, make_train_step,
                              state_shardings)

__all__ = ["FaultInjected", "FaultPlan", "LoopConfig", "SentinelConfig",
           "StabilitySentinel", "Trainer", "Verdict", "greedy_generate",
           "greedy_generate_reference", "TrainState", "batch_shardings",
           "init_train_state", "make_eval_step", "make_train_step",
           "state_shardings"]
