from repro.train.loop import LoopConfig, Trainer
from repro.train.serve import greedy_generate, greedy_generate_reference
from repro.train.step import (TrainState, batch_shardings, init_train_state,
                              make_eval_step, make_train_step,
                              state_shardings)

__all__ = ["LoopConfig", "Trainer", "greedy_generate",
           "greedy_generate_reference", "TrainState", "batch_shardings",
           "init_train_state", "make_eval_step", "make_train_step",
           "state_shardings"]
