"""Batched serving: ``greedy_generate`` is now a thin compatibility shim
over :class:`repro.infer.Engine` (prepared weights, per-slot positions,
admit-on-free scheduling).  Families the engine does not serve yet
(encoder-decoder, VLM) and sharded serving (``rules``) fall back to the
legacy jitted fori loop, kept here as :func:`greedy_generate_reference` --
it is also the parity oracle for the engine tests.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model_api import Model


def greedy_generate(model: Model, params, batch: Dict, max_new_tokens: int,
                    *, recipe=None, rules=None, eos_id: Optional[int] = None,
                    max_seq: Optional[int] = None) -> jnp.ndarray:
    """Returns (B, max_new_tokens) int32 generations.

    ``recipe`` accepts the full policy surface (None / QuantRecipe /
    QuantPolicy / policy string) -- e.g. a per-layer int8 policy for
    quantized serving.  Decoder-only unsharded calls route through the
    inference engine, so quantized weights are prepared once (stored int8
    payloads) instead of fake-quantized at every decode step."""
    from repro.infer import ENGINE_FAMILIES, Engine

    prompt = batch["tokens"]
    b, s = prompt.shape
    total = (max_seq or (s + max_new_tokens))

    if (rules is None and model.cfg.family in ENGINE_FAMILIES
            and set(batch) == {"tokens"}):
        eng = Engine(model, params, recipe, max_slots=b, max_seq=total)
        return eng.generate(prompt, max_new_tokens, eos_id=eos_id)
    return greedy_generate_reference(model, params, batch, max_new_tokens,
                                     recipe=recipe, rules=rules,
                                     eos_id=eos_id, max_seq=max_seq)


def greedy_generate_reference(model: Model, params, batch: Dict,
                              max_new_tokens: int, *, recipe=None, rules=None,
                              eos_id: Optional[int] = None,
                              max_seq: Optional[int] = None) -> jnp.ndarray:
    """Legacy fixed-budget fori loop (scheduler-free).  Every emitted token
    -- including the first, sampled from the prefill logits -- passes the
    eos done-mask before emission: once a sequence emits ``eos_id``, every
    later position is ``eos_id``."""
    prompt = batch["tokens"]
    b, s = prompt.shape
    total = (max_seq or (s + max_new_tokens))

    logits, state = model.prefill(params, batch, recipe=recipe, rules=rules,
                                  max_seq=total)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    # vlm prompts occupy num_patches extra cache rows
    base_pos = s + (model.cfg.num_patches if model.cfg.family == "vlm" else 0)

    def step(carry, i):
        state, tok, done = carry
        # consult the done mask BEFORE emitting (covers the first token)
        if eos_id is not None:
            tok = jnp.where(done[:, None], jnp.full_like(tok, eos_id), tok)
            done = done | (tok[:, 0] == eos_id)
        logits, state = model.decode(params, state, tok, base_pos + i,
                                     recipe=recipe, rules=rules)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (state, nxt, done), tok[:, 0]

    done0 = jnp.zeros((b,), bool)
    (_, _, _), toks = jax.lax.scan(
        step, (state, first, done0), jnp.arange(max_new_tokens))
    return jnp.moveaxis(toks, 0, 1)
