"""Batched serving: prefill + greedy decode with per-sequence stopping.

The decode loop is a jitted ``lax.while_loop``-free simple fori over steps
(fixed budget) -- production serving would wrap this in a scheduler; here it
backs the examples, serving tests, and serve-shape dry-runs.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_api import Model


def greedy_generate(model: Model, params, batch: Dict, max_new_tokens: int,
                    *, recipe=None, rules=None, eos_id: Optional[int] = None,
                    max_seq: Optional[int] = None) -> jnp.ndarray:
    """Returns (B, max_new_tokens) int32 generations.

    ``recipe`` accepts the full policy surface (None / QuantRecipe /
    QuantPolicy / policy string) -- e.g. a per-layer int8 policy for
    quantized serving."""
    prompt = batch["tokens"]
    b, s = prompt.shape
    total = (max_seq or (s + max_new_tokens))

    logits, state = model.prefill(params, batch, recipe=recipe, rules=rules,
                                  max_seq=total)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    # vlm prompts occupy num_patches extra cache rows
    base_pos = s + (model.cfg.num_patches if model.cfg.family == "vlm" else 0)

    def step(carry, i):
        state, tok, done = carry
        logits, state = model.decode(params, state, tok, base_pos + i,
                                     recipe=recipe, rules=rules)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
            nxt = jnp.where(done[:, None], jnp.full_like(nxt, eos_id), nxt)
        return (state, nxt, done), tok[:, 0]

    done0 = jnp.zeros((b,), bool)
    (_, _, _), toks = jax.lax.scan(
        step, (state, first, done0), jnp.arange(max_new_tokens))
    return jnp.moveaxis(toks, 0, 1)
