"""Training stability sentinel: anomaly detection + the recovery ladder.

The paper's central finding is that quantized pre-training fails as a
*stability* problem -- the loss diverges when gradients / optimizer states
stop being representable, not gradually but in spikes (Fig. 10/12).  The
:class:`StabilitySentinel` watches every step's host-side metrics and turns
"the run is dying" into a deterministic recovery action:

detection (cheap, rolling-window, all host-side):

* non-finite loss or global grad norm (NaN/inf guards);
* loss spike: ``loss > mean + spike_sigma * std`` over the last ``window``
  *healthy* steps (a floor keeps flat curves from hair-triggering);
* grad-norm spike: ``grad_norm > grad_factor * rolling median``;
* int8 overflow pressure: the train step's ``grad_sat`` counter (candidate
  first-moment mass outgrowing the stored Adam-moment scales,
  ``core.diagnostics.moment_saturation_rate``) above ``sat_threshold`` AND
  ``sat_factor``x its own rolling median -- the rate has a benign ambient
  level while the moment EMA warms up, so only a *step change* on top of
  the absolute floor is a spike (sustained pressure self-baselines here
  but keeps showing in the loss / grad-norm rules);
* quant-error drift: ``grad_qerr`` (relative quantization error of the
  gradient) jumping ``qerr_factor``x over its rolling median.

recovery ladder (escalating, driven by the Trainer):

1. **skip-batch** -- the poisoned update is discarded (the trainer keeps the
   pre-step state; the batch is consumed).  First line of defense: a single
   bad batch or a transient overflow costs one step of data.
2. **rollback** -- more than ``skip_limit`` spikes inside one window means
   the *state* is bad, not the batch: the trainer restores the newest intact
   checkpoint (``CheckpointManager.restore_latest`` falls back through the
   rotation past corrupt ones) and rewinds the loop.
3. **fp-fallback window** -- a rollback arms a step-indexed policy override:
   for the next ``fallback_steps`` steps the trainer runs the step compiled
   from ``core.qpolicy.fallback_policy`` (same optimization problem, real-
   int8 kernels off -- or fully fp), then re-engages the quantized path.
   This is the continual-QAT transition of Nielsen et al. used as a
   recovery action.  While the window is open further spikes only skip
   (rollback thrash is worse than losing a few batches).

The ladder is bounded: after ``max_rollbacks`` rollbacks the sentinel stops
escalating (skips only) and flags ``exhausted`` in :meth:`summary` -- a run
that cannot be saved should surface in monitoring, not loop forever.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from collections import deque
from typing import Deque, Dict, List, Optional


class Verdict(enum.Enum):
    OK = "ok"
    SKIP = "skip"
    ROLLBACK = "rollback"


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    window: int = 32            # rolling-stat window (healthy steps)
    min_history: int = 8        # observations before spike detection arms
    spike_sigma: float = 6.0    # loss deviation threshold (in rolling stds)
    spike_floor: float = 0.5    # absolute loss-jump floor (std can be ~0)
    grad_factor: float = 10.0   # grad_norm vs rolling median
    sat_threshold: float = 0.25  # int8 moment-saturation-rate floor
    sat_factor: float = 2.0     # grad_sat vs its rolling median
    qerr_factor: float = 4.0    # grad_qerr vs rolling median
    skip_limit: int = 2         # spikes skipped per window before rollback
    fallback_steps: int = 16    # fp/fake window length after a rollback
    max_rollbacks: int = 3      # escalation budget for the whole run


def _finite(x: Optional[float]) -> bool:
    return x is not None and math.isfinite(x)


def _median(xs) -> float:
    ys = sorted(xs)
    return ys[len(ys) // 2]


class StabilitySentinel:
    """See module docstring.  One instance per training run; not thread-safe
    (the train loop is single-threaded)."""

    #: metric keys consulted, in order of preference, for the loss signal
    LOSS_KEYS = ("loss", "ce")

    def __init__(self, cfg: Optional[SentinelConfig] = None):
        self.cfg = cfg or SentinelConfig()
        self._loss: Deque[float] = deque(maxlen=self.cfg.window)
        self._gnorm: Deque[float] = deque(maxlen=self.cfg.window)
        self._qerr: Deque[float] = deque(maxlen=self.cfg.window)
        # sat is ambient pressure, recorded on EVERY finite observation
        # (healthy or not) so its median baselines warm-up levels and a
        # flagged-but-persistent plateau cannot starve its own window
        self._sat: Deque[float] = deque(maxlen=self.cfg.window)
        self._spike_steps: List[int] = []       # recent spikes (pruned)
        self.fallback_until = -1                # exclusive step bound
        self.rollbacks = 0
        self.exhausted = False
        self.last_reasons: List[str] = []
        self.counts: Dict[str, int] = {
            "observed": 0, "spikes": 0, "skips": 0, "rollbacks": 0,
            "fallback_windows": 0, "fallback_steps_run": 0}
        self.spike_reasons: Dict[str, int] = {}

    # -- detection ---------------------------------------------------------

    def _spike_reasons(self, metrics: Dict[str, float]) -> List[str]:
        cfg = self.cfg
        loss = next((metrics[k] for k in self.LOSS_KEYS if k in metrics),
                    None)
        gnorm = metrics.get("grad_norm")
        reasons = []
        if loss is not None and not _finite(loss):
            reasons.append("nonfinite-loss")
        if gnorm is not None and not _finite(gnorm):
            reasons.append("nonfinite-grad")
        if reasons:
            return reasons                       # NaN outranks everything
        if _finite(loss) and len(self._loss) >= cfg.min_history:
            mean = sum(self._loss) / len(self._loss)
            var = sum((x - mean) ** 2 for x in self._loss) / len(self._loss)
            band = max(cfg.spike_sigma * math.sqrt(var), cfg.spike_floor)
            if loss > mean + band:
                reasons.append("loss-spike")
        if _finite(gnorm) and len(self._gnorm) >= cfg.min_history:
            if gnorm > cfg.grad_factor * max(_median(self._gnorm), 1e-12):
                reasons.append("grad-norm-spike")
        sat = metrics.get("grad_sat")
        if sat is not None:
            if not _finite(sat):
                reasons.append("moment-saturation")
            else:
                armed = len(self._sat) >= cfg.min_history
                if (armed and sat > cfg.sat_threshold
                        and sat > cfg.sat_factor
                        * max(_median(self._sat), 1e-12)):
                    reasons.append("moment-saturation")
                self._sat.append(sat)
        qerr = metrics.get("grad_qerr")
        if qerr is not None:
            if not _finite(qerr):
                reasons.append("qerr-nonfinite")
            elif (len(self._qerr) >= cfg.min_history
                    and qerr > cfg.qerr_factor
                    * max(_median(self._qerr), 1e-12)):
                reasons.append("qerr-drift")
        return reasons

    # -- the ladder --------------------------------------------------------

    def observe(self, step: int, metrics: Dict[str, float]) -> Verdict:
        """Judge one completed (but not yet applied) train step.  ``OK``
        commits the update; ``SKIP`` discards it; ``ROLLBACK`` asks the
        trainer to restore the newest intact checkpoint and rewind (the
        sentinel arms the fallback window as a side effect)."""
        self.counts["observed"] += 1
        in_fb = self.in_fallback(step)
        if in_fb:
            self.counts["fallback_steps_run"] += 1
        reasons = self._spike_reasons(metrics)
        self.last_reasons = reasons
        if not reasons:
            self._record_healthy(metrics)
            return Verdict.OK
        self.counts["spikes"] += 1
        for r in reasons:
            self.spike_reasons[r] = self.spike_reasons.get(r, 0) + 1
        self._spike_steps = [s for s in self._spike_steps
                             if step - s < self.cfg.window]
        self._spike_steps.append(step)
        escalate = len(self._spike_steps) > self.cfg.skip_limit
        if escalate and not in_fb and not self.exhausted:
            if self.rollbacks >= self.cfg.max_rollbacks:
                self.exhausted = True           # stop escalating; skip only
            else:
                self.rollbacks += 1
                self.counts["rollbacks"] += 1
                self.counts["fallback_windows"] += 1
                self.fallback_until = step + self.cfg.fallback_steps
                self._spike_steps.clear()
                return Verdict.ROLLBACK
        self.counts["skips"] += 1
        return Verdict.SKIP

    def _record_healthy(self, metrics: Dict[str, float]) -> None:
        loss = next((metrics[k] for k in self.LOSS_KEYS if k in metrics),
                    None)
        if _finite(loss):
            self._loss.append(loss)
        gnorm = metrics.get("grad_norm")
        if _finite(gnorm):
            self._gnorm.append(gnorm)
        qerr = metrics.get("grad_qerr")
        if _finite(qerr):
            self._qerr.append(qerr)

    def in_fallback(self, step: int) -> bool:
        """Is the step-indexed fallback override active for ``step``?  The
        trainer consults this to pick the fallback-compiled train step;
        past the bound the primary (int8) path re-engages automatically."""
        return step < self.fallback_until

    def notify_rollback(self, restored_step: int) -> None:
        """The trainer rewound to ``restored_step``: the fallback window
        must cover the whole replayed region plus the configured margin."""
        self.fallback_until = max(self.fallback_until,
                                  restored_step + self.cfg.fallback_steps)

    # -- reporting ---------------------------------------------------------

    def summary(self) -> Dict[str, object]:
        return {**self.counts,
                "spike_reasons": dict(self.spike_reasons),
                "fallback_until": self.fallback_until,
                "exhausted": self.exhausted}
