"""Training loop with fault tolerance.

* periodic + preemption-triggered checkpointing (SIGTERM -> save & exit);
* resume from latest checkpoint (params, optimizer, loader state);
* deterministic data sharding (step-keyed) so restarts and elastic
  rescaling replay the exact stream;
* periodic validation on a disjoint split;
* straggler posture: the step itself is a single pjit program (bulk-
  synchronous); recovery is checkpoint-restart (DESIGN.md Section 4).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import Loader
from repro.train.step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 200
    eval_every: int = 100
    eval_batches: int = 4
    log_every: int = 10


class Trainer:
    def __init__(self, train_step: Callable, eval_step: Optional[Callable],
                 state: TrainState, loader: Loader,
                 ckpt: Optional[CheckpointManager] = None,
                 loop_cfg: Optional[LoopConfig] = None,
                 valid_loader: Optional[Loader] = None,
                 metadata: Optional[Dict] = None):
        self.train_step = train_step
        self.eval_step = eval_step
        self.state = state
        self.loader = loader
        self.valid_loader = valid_loader
        self.ckpt = ckpt
        self.cfg = loop_cfg or LoopConfig(total_steps=100)
        self.metadata = metadata or {}
        self.history: List[Dict[str, float]] = []
        self._preempted = False

    # -- fault tolerance ----------------------------------------------------

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def maybe_resume(self) -> int:
        if self.ckpt is None:
            return 0
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        self.state, meta = self.ckpt.restore(step, self.state)
        self.loader.load_state_dict(meta.get("loader", {"step": step}))
        return step

    def _save(self, step: int) -> None:
        if self.ckpt is None:
            return
        meta = dict(self.metadata)
        meta["loader"] = self.loader.state_dict()
        self.ckpt.save(step, self.state, metadata=meta)

    # -- loop ----------------------------------------------------------------

    def run(self, rng: Optional[jax.Array] = None) -> List[Dict[str, float]]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        start = int(self.state.opt.step)
        t0 = time.time()
        for step in range(start, self.cfg.total_steps):
            batch = next(self.loader)
            # step-keyed rng: resume replays the identical stream
            sub = jax.random.fold_in(rng, step)
            self.state, metrics = self.train_step(self.state, batch, sub)
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step + 1
                row["sec_per_step"] = (time.time() - t0) / max(
                    step + 1 - start, 1)
                if (self.eval_step is not None and self.valid_loader is not None
                        and (step + 1) % self.cfg.eval_every == 0):
                    row["valid_ce"] = self.evaluate()
                self.history.append(row)
            if self.ckpt and (step + 1) % self.cfg.ckpt_every == 0:
                self._save(step + 1)
            if self._preempted:
                self._save(step + 1)
                break
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    def evaluate(self) -> float:
        losses = []
        for i in range(self.cfg.eval_batches):
            batch = self.valid_loader.peek(step=i)
            m = self.eval_step(self.state.params, batch)
            losses.append(float(m["ce"]))
        return float(np.mean(losses))
