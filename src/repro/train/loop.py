"""Training loop with fault tolerance.

* periodic + preemption-triggered checkpointing (SIGTERM -> save & exit);
* resume from the newest *intact* checkpoint (params, optimizer, loader
  state) -- restore falls back through the rotation past corrupt or
  half-written checkpoints;
* deterministic data sharding (step-keyed) so restarts and elastic
  rescaling replay the exact stream;
* periodic validation on a disjoint split;
* straggler posture: the step itself is a single pjit program (bulk-
  synchronous); recovery is checkpoint-restart (DESIGN.md Section 4);
* **guarded stepping** (opt-in via ``sentinel=``): every step's metrics are
  judged by a :class:`~repro.train.sentinel.StabilitySentinel` before the
  update is committed, and its verdict drives the recovery ladder --
  skip-batch (discard the poisoned update), rollback (restore the newest
  intact checkpoint and rewind the loop), and a temporary fallback window
  (the ``fallback_step``-compiled fp/fake-quant path runs for N steps
  before the int8 path re-engages).  ``resilience_summary()`` reports what
  the guards did.  Deterministic fault injection for all of it lives in
  ``train/faults.py`` (``REPRO_FAULT``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointCorrupt, CheckpointManager
from repro.data import Loader
from repro.train.faults import FaultPlan
from repro.train.sentinel import StabilitySentinel, Verdict
from repro.train.step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 200
    eval_every: int = 100
    eval_batches: int = 4
    log_every: int = 10


class Trainer:
    def __init__(self, train_step: Callable, eval_step: Optional[Callable],
                 state: TrainState, loader: Loader,
                 ckpt: Optional[CheckpointManager] = None,
                 loop_cfg: Optional[LoopConfig] = None,
                 valid_loader: Optional[Loader] = None,
                 metadata: Optional[Dict] = None,
                 sentinel: Optional[StabilitySentinel] = None,
                 fallback_step: Optional[Callable] = None,
                 faults: Optional[FaultPlan] = None):
        self.train_step = train_step
        self.eval_step = eval_step
        self.state = state
        self.loader = loader
        self.valid_loader = valid_loader
        self.ckpt = ckpt
        self.cfg = loop_cfg or LoopConfig(total_steps=100)
        self.metadata = metadata or {}
        self.history: List[Dict[str, float]] = []
        self.sentinel = sentinel
        self.fallback_step = fallback_step
        self.faults = faults
        if faults is not None and ckpt is not None:
            faults.install(ckpt)
        self._preempted = False
        self._start_step: Optional[int] = None
        self._counters = {"saves": 0, "restores": 0, "skipped_batches": 0,
                          "rollback_failures": 0}

    # -- fault tolerance ----------------------------------------------------

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def maybe_resume(self) -> int:
        """Restore the newest intact checkpoint (falling back through the
        rotation past corrupt ones) and resume its data stream.  Returns the
        loop step to resume from (0 when nothing restorable exists)."""
        if self.ckpt is None:
            return 0
        try:
            self.state, meta, step = self.ckpt.restore_latest(self.state)
        except CheckpointCorrupt:
            return 0
        self.loader.load_state_dict(meta.get("loader", {"step": step}))
        self._counters["restores"] += 1
        self._start_step = step
        return step

    def _save(self, step: int) -> None:
        if self.ckpt is None:
            return
        meta = dict(self.metadata)
        meta["loader"] = self.loader.state_dict()
        self.ckpt.save(step, self.state, metadata=meta)
        self._counters["saves"] += 1

    def _rollback(self) -> Optional[int]:
        """Recovery ladder rung 2: restore the newest intact checkpoint.
        Returns the loop step to rewind to, or None when nothing is
        restorable (the caller degrades to skip-batch)."""
        if self.ckpt is None:
            self._counters["rollback_failures"] += 1
            return None
        self.ckpt.wait()                    # surface async-write errors now
        try:
            self.state, meta, step = self.ckpt.restore_latest(self.state)
        except CheckpointCorrupt:
            self._counters["rollback_failures"] += 1
            return None
        self.loader.load_state_dict(meta.get("loader", {"step": step}))
        self._counters["restores"] += 1
        return step

    # -- loop ----------------------------------------------------------------

    def run(self, rng: Optional[jax.Array] = None) -> List[Dict[str, float]]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        start = (self._start_step if self._start_step is not None
                 else int(self.state.opt.step))
        t0 = time.time()
        executed = 0                        # steps actually run (incl. replays)
        step = start
        while step < self.cfg.total_steps:
            batch = next(self.loader)
            # step-keyed rng: resume replays the identical stream
            sub = jax.random.fold_in(rng, step)
            guarded = self.sentinel is not None
            use_fb = (guarded and self.fallback_step is not None
                      and self.sentinel.in_fallback(step))
            step_fn = self.fallback_step if use_fb else self.train_step
            new_state, metrics = step_fn(self.state, batch, sub)
            executed += 1
            if guarded:
                # the float() casts force a host sync -- the price of
                # judging the step before committing it
                row = {k: float(v) for k, v in metrics.items()}
                verdict = self.sentinel.observe(step, row)
            else:
                row = None
                verdict = Verdict.OK
            if self.faults is not None:
                self.faults.note_step(step)     # sigterm_run delivery point
            if verdict is Verdict.OK:
                self.state = new_state
            elif verdict is Verdict.SKIP:
                # rung 1: drop the poisoned update, keep the pre-step state;
                # the batch is consumed (skip-batch semantics)
                self._counters["skipped_batches"] += 1
            else:                               # Verdict.ROLLBACK
                at = self._rollback()
                if at is None:
                    # nothing to roll back to: degrade to skip-batch (the
                    # sentinel has already armed the fallback window)
                    self._counters["skipped_batches"] += 1
                else:
                    self.sentinel.notify_rollback(at)
                    step = at
                    continue                    # rewound: no log/save tick
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                if row is None:
                    row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step + 1
                row["sec_per_step"] = (time.time() - t0) / max(executed, 1)
                if use_fb:
                    row["fallback"] = 1.0
                if (self.eval_step is not None and self.valid_loader is not None
                        and (step + 1) % self.cfg.eval_every == 0):
                    row["valid_ce"] = self.evaluate()
                self.history.append(row)
            if self.ckpt and (step + 1) % self.cfg.ckpt_every == 0 \
                    and verdict is Verdict.OK:
                self._save(step + 1)
            if self._preempted:
                self._save(step + 1)
                break
            step += 1
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    def evaluate(self) -> float:
        losses = []
        for i in range(self.cfg.eval_batches):
            batch = self.valid_loader.peek(step=i)
            m = self.eval_step(self.state.params, batch)
            losses.append(float(m["ce"]))
        return float(np.mean(losses))

    # -- reporting -----------------------------------------------------------

    def resilience_summary(self) -> Dict[str, object]:
        """What the fault-tolerance machinery did this run: loop counters
        (saves/restores/skips), the sentinel's ladder accounting, and which
        planned faults actually fired."""
        out: Dict[str, object] = dict(self._counters)
        out["preempted"] = self._preempted
        if self.sentinel is not None:
            out["sentinel"] = self.sentinel.summary()
        if self.faults is not None:
            out["faults_planned"] = self.faults.describe()
            out["faults_fired"] = self.faults.fired
        return out
