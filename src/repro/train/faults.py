"""Deterministic fault injection for the resilience test/CI gate.

A :class:`FaultPlan` is parsed from a compact spec (the ``REPRO_FAULT``
environment variable, or passed explicitly by tests and
``benchmarks/resilience.py``) and injects one of the failure modes the
stability sentinel and the hardened checkpoint manager must survive:

=====================  =====================================================
``nan_grad@K``         every gradient leaf becomes NaN on train step K
                       (injected *inside* the jitted step via ``jnp.where``
                       on the traced step counter -- bitwise no-op on every
                       other step, so the compiled artifact is unchanged)
``sat_grad@K``         gradients scaled by ``factor`` (default 1e6) on step
                       K: saturates the int8 moment codecs' stored scales
                       and spikes the global grad norm
``corrupt_ckpt@N``     the N-th (1-based) completed checkpoint write is
                       corrupted in place: ``mode=flip`` (default) flips a
                       payload byte (caught by per-leaf CRC32),
                       ``mode=truncate`` truncates ``arrays.npz``,
                       ``mode=manifest`` garbles the manifest (caught by
                       the digest/commit marker)
``sigterm_save@N``     SIGTERM is delivered in the *middle* of the N-th
                       checkpoint write (after arrays hit disk, before the
                       commit marker) -- proves the atomic tmp-dir protocol
                       never ships a half-written checkpoint
``sigterm_run@K``      SIGTERM is delivered right after train step K
                       completes (preemption-resume tests)
``dead_sched@N``       the serving scheduler's step thread raises on its
                       N-th tick (dead-thread watchdog tests)
``nan_logit@N``        the serving engine's decode step N reports slot
                       ``slot`` (default 0) as non-finite -- the engine
                       must quarantine *that request* (finish reason
                       ``"numerics"``), not the batch
``oom_pages@N``        every free page is stolen from the engine's pool
                       just before decode step N and held for ``hold``
                       steps (default 2) -- exercises mid-decode
                       preemption under pool exhaustion
``slow_step@N``        decode step N is delayed ``ms`` milliseconds
                       (default 50) on the host -- latency-watchdog and
                       deadline-shed tests
``kernel_error@N``     the decode step raises just before dispatch on
                       step N, as a failing fused kernel would -- the
                       engine must step down its compiled-path ladder
                       and retry, not kill the scheduling loop
=====================  =====================================================

Entries are ``;``-separated; key=val args follow the step after ``:`` and
are ``,``-separated, e.g.::

    REPRO_FAULT='sat_grad@6:factor=1e7;corrupt_ckpt@1:mode=truncate'

Steps are the 0-based train-loop step for ``*_grad`` / ``sigterm_run``
(the value of ``state.opt.step`` entering the step), 1-based completed-save
ordinals for the checkpoint faults, 0-based scheduler ticks for
``dead_sched``, and 0-based engine *decode* steps for the serving kinds
(``Engine._decode_steps`` -- admissions/prefills do not advance it).
Everything is deterministic: the same spec against the same run injects at
exactly the same point every time.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ENV_VAR = "REPRO_FAULT"

GRAD_KINDS = ("nan_grad", "sat_grad")
CKPT_KINDS = ("corrupt_ckpt", "sigterm_save")
ENGINE_KINDS = ("nan_logit", "oom_pages", "slow_step", "kernel_error")
KINDS = GRAD_KINDS + CKPT_KINDS + ("sigterm_run", "dead_sched") \
    + ENGINE_KINDS

_CORRUPT_MODES = ("flip", "truncate", "manifest")


class FaultInjected(RuntimeError):
    """Raised by host-side faults that simulate a hard crash.  The scheduler
    step-thread death is deliberately NOT absorbed by any guard (the
    dead-loop watchdog must surface it); ``kernel_error`` is deliberately
    raised *inside* the engine's guarded decode step, where the degradation
    ladder is expected to absorb it and retry one rung down."""


@dataclasses.dataclass(frozen=True)
class Fault:
    kind: str
    at: int                       # step / save ordinal / scheduler tick
    args: Dict[str, str] = dataclasses.field(default_factory=dict)

    def arg(self, key: str, default: str) -> str:
        return self.args.get(key, default)

    def describe(self) -> str:
        s = f"{self.kind}@{self.at}"
        if self.args:
            s += ":" + ",".join(f"{k}={v}" for k, v in sorted(self.args.items()))
        return s


class FaultPlan:
    """A parsed, immutable set of faults plus the mutable injection state
    (how many saves have happened, whether a one-shot fault already fired)."""

    def __init__(self, faults: Tuple[Fault, ...] = ()):
        self.faults = tuple(faults)
        self._saves_completed = 0
        self._fired: List[str] = []          # descriptions, in firing order

    # -- construction ------------------------------------------------------

    @classmethod
    def parse(cls, spec: Optional[str]) -> "FaultPlan":
        faults = []
        for entry in (spec or "").replace("\n", ";").split(";"):
            entry = entry.strip()
            if not entry:
                continue
            if "@" not in entry:
                raise ValueError(
                    f"bad fault entry {entry!r} (want kind@step[:k=v,...])")
            kind, rest = entry.split("@", 1)
            kind = kind.strip()
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r}; kinds: {KINDS}")
            args: Dict[str, str] = {}
            if ":" in rest:
                at_s, arg_s = rest.split(":", 1)
                for kv in arg_s.split(","):
                    kv = kv.strip()
                    if not kv:
                        continue
                    if "=" not in kv:
                        raise ValueError(f"bad fault arg {kv!r} in {entry!r} "
                                         "(want key=val)")
                    k, v = kv.split("=", 1)
                    args[k.strip()] = v.strip()
            else:
                at_s = rest
            try:
                at = int(at_s.strip())
            except ValueError:
                raise ValueError(f"bad fault step {at_s!r} in {entry!r}") \
                    from None
            mode = args.get("mode")
            if kind == "corrupt_ckpt" and mode is not None \
                    and mode not in _CORRUPT_MODES:
                raise ValueError(f"unknown corrupt_ckpt mode {mode!r}; "
                                 f"modes: {_CORRUPT_MODES}")
            faults.append(Fault(kind, at, args))
        return cls(tuple(faults))

    @classmethod
    def from_env(cls, spec: Optional[str] = None) -> "FaultPlan":
        """Plan from an explicit spec when given (CLI flag), else from the
        ``REPRO_FAULT`` environment variable."""
        if spec is None:
            spec = os.environ.get(ENV_VAR)
        return cls.parse(spec)

    # -- introspection -----------------------------------------------------

    def __bool__(self) -> bool:
        return bool(self.faults)

    def describe(self) -> str:
        return ";".join(f.describe() for f in self.faults) or "none"

    @property
    def fired(self) -> List[str]:
        """Faults that actually injected, in order -- the resilience gate
        asserts every planned fault fired."""
        return list(self._fired)

    def _of(self, *kinds: str) -> List[Fault]:
        return [f for f in self.faults if f.kind in kinds]

    def _mark(self, fault: Fault) -> None:
        self._fired.append(fault.describe())

    # -- in-trace gradient faults ------------------------------------------

    def has_grad_faults(self) -> bool:
        return bool(self._of(*GRAD_KINDS))

    def apply_grads(self, step: jnp.ndarray, grads):
        """Poison the gradient tree when the traced ``step`` counter matches
        a planned grad fault.  A single scalar multiplier is built from the
        (static) plan and broadcast into every leaf, so off-fault steps
        multiply by 1.0 and XLA folds the whole thing away when the plan is
        empty.  Firing is recorded host-side by :meth:`note_step` (this
        body runs once, at trace time)."""
        faults = self._of(*GRAD_KINDS)
        if not faults:
            return grads
        mult = jnp.float32(1.0)
        for f in faults:
            if f.kind == "nan_grad":
                hit = jnp.float32(jnp.nan)
            else:
                hit = jnp.float32(float(f.arg("factor", "1e6")))
            mult = jnp.where(step == f.at, hit, mult)
        return jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * mult).astype(g.dtype), grads)

    def grad_fault_steps(self) -> List[int]:
        return sorted(f.at for f in self._of(*GRAD_KINDS))

    def note_step(self, step: int) -> None:
        """Host-side bookkeeping after train step ``step`` ran: record grad
        faults whose step just executed, and deliver ``sigterm_run``."""
        for f in self._of(*GRAD_KINDS):
            if f.at == step:
                self._mark(f)
        for f in self._of("sigterm_run"):
            if f.at == step and f.describe() not in self._fired:
                self._mark(f)
                os.kill(os.getpid(), signal.SIGTERM)

    # -- checkpoint faults -------------------------------------------------

    def install(self, manager) -> None:
        """Bind the checkpoint faults to a ``CheckpointManager`` via its
        ``on_mid_write`` / ``on_after_write`` test hooks."""
        if not self._of(*CKPT_KINDS):
            return
        manager.on_mid_write = self._mid_write
        manager.on_after_write = self._after_write

    def _mid_write(self, step: int) -> None:
        # called after the array payload is on disk, before the manifest /
        # commit marker: the atomicity window a preemption can land in
        ordinal = self._saves_completed + 1
        for f in self._of("sigterm_save"):
            if f.at == ordinal and f.describe() not in self._fired:
                self._mark(f)
                os.kill(os.getpid(), signal.SIGTERM)

    def _after_write(self, step: int, path: str) -> None:
        self._saves_completed += 1
        for f in self._of("corrupt_ckpt"):
            if f.at == self._saves_completed:
                self._mark(f)
                corrupt_checkpoint(path, f.arg("mode", "flip"))

    # -- scheduler faults --------------------------------------------------

    def scheduler_hook(self) -> Optional[Callable[[int], None]]:
        """Hook for ``infer.scheduler.Scheduler.fault_hook``: raises
        :class:`FaultInjected` on the planned tick (simulating a crashed
        background step thread)."""
        faults = self._of("dead_sched")
        if not faults:
            return None

        def hook(tick: int) -> None:
            for f in faults:
                if f.at == tick and f.describe() not in self._fired:
                    self._mark(f)
                    raise FaultInjected(
                        f"injected scheduler-thread death at tick {tick}")
        return hook

    # -- serving (engine) faults -------------------------------------------

    def engine_hooks(self) -> Optional["EngineFaultHooks"]:
        """Hooks for ``Engine.fault_hooks``: deliver the serving fault
        kinds at the engine's decode-step hook points.  None when the plan
        carries no serving faults (the healthy path stays hook-free)."""
        faults = self._of(*ENGINE_KINDS)
        if not faults:
            return None
        return EngineFaultHooks(self, faults)


class EngineFaultHooks:
    """Deterministic serving faults, keyed on the engine's 0-based decode
    step counter.  Each fault is one-shot (marked in the plan's ``fired``
    list the step it lands).  Hook points, in the order ``Engine._step``
    calls them:

    * :meth:`pre_step` -- before the decode dispatch: ``slow_step`` sleeps
      ``ms`` on the host; ``oom_pages`` steals every free page from the
      pool (held ``hold`` steps, then released) so the next write forces a
      preemption;
    * :meth:`kernel` -- inside the guarded dispatch: ``kernel_error``
      raises :class:`FaultInjected` exactly where a failing fused kernel
      would surface;
    * :meth:`mangle_finite` -- after the step's per-slot finiteness flags
      are on the host: ``nan_logit`` flips slot ``slot`` (default 0) to
      non-finite, standing in for a real NaN logits row;
    * :meth:`post_step` -- after bookkeeping: releases expired page holds.
    """

    def __init__(self, plan: FaultPlan, faults: List[Fault]):
        self._plan = plan
        self._faults = list(faults)
        self._held: List[Tuple[int, List[int]]] = []   # (release_step, pids)

    def _due(self, kind: str, step: int) -> List[Fault]:
        return [f for f in self._faults
                if f.kind == kind and f.at == step
                and f.describe() not in self._plan._fired]

    def pre_step(self, engine, step: int) -> None:
        for f in self._due("slow_step", step):
            self._plan._mark(f)
            time.sleep(float(f.arg("ms", "50")) / 1e3)
        for f in self._due("oom_pages", step):
            self._plan._mark(f)
            if engine.pool is not None and engine.pool.free_pages > 0:
                pids = engine.pool.alloc(engine.pool.free_pages)
                self._held.append((step + int(f.arg("hold", "2")), pids))

    def kernel(self, step: int) -> None:
        for f in self._due("kernel_error", step):
            self._plan._mark(f)
            raise FaultInjected(
                f"injected fused-kernel failure at decode step {step}")

    def mangle_finite(self, step: int, finite: np.ndarray) -> np.ndarray:
        for f in self._due("nan_logit", step):
            self._plan._mark(f)
            finite = np.array(finite, copy=True)
            finite[int(f.arg("slot", "0")) % len(finite)] = False
        return finite

    def post_step(self, engine, step: int) -> None:
        keep = []
        for rel, pids in self._held:
            if step >= rel and engine.pool is not None:
                engine.pool.release(pids)
            else:
                keep.append((rel, pids))
        self._held = keep


def corrupt_checkpoint(path: str, mode: str = "flip") -> str:
    """Corrupt one on-disk checkpoint directory in place (test utility and
    the ``corrupt_ckpt`` fault body).  Returns the damaged file's path."""
    arrays = os.path.join(path, "arrays.npz")
    manifest = os.path.join(path, "manifest.json")
    if mode == "flip":
        with open(arrays, "r+b") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            # flip a byte well inside the payload region (past the zip
            # local-file headers) so np.load still parses the container
            f.seek(max(size // 2, 0))
            b = f.read(1)
            f.seek(max(size // 2, 0))
            f.write(bytes([b[0] ^ 0xFF]))
        return arrays
    if mode == "truncate":
        size = os.path.getsize(arrays)
        with open(arrays, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return arrays
    if mode == "manifest":
        with open(manifest, "w") as f:
            f.write('{"step": -1, "leaves": {}')      # invalid json
        return manifest
    raise ValueError(f"unknown corrupt mode {mode!r}; modes: {_CORRUPT_MODES}")
