"""pjit-able train / eval / serve step factories.

``make_train_step`` closes over (model, quantization policy, opt config,
sharding rules) and returns a pure function (state, batch, rng) ->
(state, metrics) suitable for jax.jit with in/out shardings -- the same
function is used by the CPU smoke tests, the real launcher, and the
multi-pod dry-run.

The ``recipe`` argument of every factory accepts the full policy surface:
None (fp), a legacy :class:`QuantRecipe`, a :class:`QuantPolicy`, or a
policy string -- all normalized via ``as_policy``.  The normalized policy's
``adam_m1``/``adam_m2`` feed the quantized optimizer states.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qadam
from repro.core.qpolicy import QuantPolicy, as_policy
from repro.models.model_api import Model
from repro.optim.adamw import (AdamState, OptConfig, adamw_update,
                               init_adam_state, opt_path_desc)


class TrainState(NamedTuple):
    params: Any                      # fp32 master weights
    opt: AdamState


#: Block-linear roles summarized by :func:`train_path_summary` (the matmuls
#: that dominate a train step; kv_cache/embed/lm_head are serving or
#: depth-less concerns).
_SUMMARY_ROLES = ("attn_qkv", "attn_out", "mlp_up", "mlp_down",
                  "ssm_in", "ssm_out")


def _path_desc(backend: str, caps, recipe=None) -> str:
    if backend == "fp":
        return "fp"
    if not caps:
        from repro.core.qlinear import residual_compressible
        specs = [] if recipe is None else \
            [s for s in (recipe.acts, recipe.weights) if s is not None]
        compressed = [residual_compressible(s) for s in specs]
        res = ("int8" if specs and all(compressed)
               else "mixed" if any(compressed) else "fp")
        return f"fake_quant(fwd=qdq,bwd=qdq,res={res})"
    bwd = "int8" if "bwd" in caps else "qdq"
    return f"{backend}(fwd=int8,bwd={bwd},res=int8)"


def train_path_summary(recipe, n_layers: int = 0,
                       opt_cfg: Optional[OptConfig] = None) -> str:
    """One-line description of the kernel path each block-linear role's train
    step actually runs: effective backend after fallback, which passes hit
    real quantized compute, and the custom-vjp residual codec.  Printed by
    the launcher and reported by benchmarks/train_throughput.py.

    Depth-banded policies resolve per layer: pass ``n_layers`` to enumerate
    the distinct per-depth paths ('/'-joined); without it the summary can
    only flag the role as depth-banded rather than misreport one band.

    Pass ``opt_cfg`` to also report the optimizer update path (``opt=``
    segment: fp/fake/int8 storage x fused-kernel vs reference loop)."""
    policy = as_policy(recipe)
    groups: Dict[str, list] = {}
    for role in _SUMMARY_ROLES:
        if policy.depth_sensitive(role):
            if n_layers:
                descs = sorted({_path_desc(
                    *policy.effective_backend(role, i, n_layers),
                    policy.resolve(role, i, n_layers).recipe)
                    for i in range(n_layers)})
                desc = "/".join(descs)
            else:
                desc = "depth-banded(pass n_layers)"
        else:
            desc = _path_desc(*policy.effective_backend(role),
                              policy.resolve(role).recipe)
        groups.setdefault(desc, []).append(role)
    summary = " ".join(f"{'+'.join(roles)}={desc}"
                       for desc, roles in groups.items())
    if opt_cfg is not None:
        summary += f" opt={opt_path_desc(policy, opt_cfg)}"
    return summary


def init_train_state(model: Model, key: jax.Array, recipe,
                     opt_cfg: OptConfig) -> TrainState:
    policy = as_policy(recipe)
    params = model.init_params(key, jnp.float32)
    return TrainState(params=params,
                      opt=init_adam_state(params, policy, opt_cfg))


def _health_err_spec(policy: QuantPolicy):
    """Spec the ``grad_qerr`` drift counter measures against: the policy
    default's gradient spec when one exists (that is the codec the backward
    actually injects), else its activation spec, else nothing."""
    r = policy.default
    if r is None:
        return None
    return r.grads if r.grads is not None else r.acts


def make_train_step(model: Model, recipe, opt_cfg: OptConfig, rules=None,
                    accum_steps: int = 1, faults=None, health: bool = False):
    """Gradient step with optional microbatch accumulation (accum_steps > 1
    splits the leading batch dim; gradients are averaged -- communication for
    the DP reduction is deferred to the last microbatch by XLA).

    ``faults`` (a ``train.faults.FaultPlan``) injects its planned gradient
    faults in-trace, keyed on the traced ``state.opt.step`` counter --
    bitwise no-op on every other step.  ``health=True`` adds the sentinel's
    quantization-health counters to the metrics (``grad_sat``: gradient
    mass exceeding the stored int8 Adam-moment scales; ``grad_qerr``:
    relative quantization error of the gradient under the policy's
    grad/act spec) -- one extra pass over the gradient leaves."""
    policy = as_policy(recipe)

    def constrain_like_params(tree, ref):
        """Pin a params-shaped tree to the parameter shardings: gradients
        then REDUCE-SCATTER to their FSDP shard instead of all-reducing
        (halves dW wire), and the bf16 cast lands BEFORE the per-layer
        weight all-gather (halves gather wire)."""
        if rules is None:
            return tree
        flat, treedef = jax.tree_util.tree_flatten(ref)
        flat_t = treedef.flatten_up_to(tree)
        flat_ax = treedef.flatten_up_to(model.axes)
        out = [jax.lax.with_sharding_constraint(
                   t, rules.sharding_for(t.shape, ax))
               for t, ax in zip(flat_t, flat_ax)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def loss_fn(params, batch, rng):
        from repro.models.common import cast_params
        compute_params = constrain_like_params(
            cast_params(params, jnp.bfloat16), params)
        loss, metrics = model.train_loss(compute_params, batch,
                                         policy=policy, rules=rules, rng=rng)
        return loss, metrics

    def grad_fn(params, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng)
        return (loss, metrics), constrain_like_params(grads, params)

    def train_step(state: TrainState, batch, rng
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, batch, rng)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mb, rng)
                return (jax.tree_util.tree_map(jnp.add, g_acc, g),
                        l_acc + l), None

            split = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {"ce": loss, "loss": loss}

        if faults is not None and faults.has_grad_faults():
            grads = faults.apply_grads(state.opt.step, grads)
        if health:
            from repro.core.diagnostics import grad_quant_health
            metrics = dict(metrics)
            metrics.update(grad_quant_health(
                grads, state.opt.m1, policy.adam_m1,
                _health_err_spec(policy), beta1=opt_cfg.b1))
        new_params, new_opt, stats = adamw_update(
            state.params, grads, state.opt, opt_cfg, policy)
        metrics = dict(metrics)
        metrics.update(stats)
        return TrainState(new_params, new_opt), metrics

    return train_step


def lower_train_hlo(model: Model, recipe, opt_cfg: OptConfig, *,
                    batch_size: int = 2, seq_len: int = 33,
                    donate: bool = True) -> str:
    """Compiled HLO text of one full train step (fwd + bwd + optimizer) on
    abstract inputs, with the state donated as a real launcher would --
    the module ``repro.lint`` train contracts analyze.  Nothing is
    materialized: the state comes from ``jax.eval_shape``."""
    policy = as_policy(recipe)
    state = jax.eval_shape(
        lambda k: init_train_state(model, k, policy, opt_cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len),
                                            jnp.int32)}
    step = make_train_step(model, policy, opt_cfg)
    jitted = jax.jit(step, donate_argnums=(0,) if donate else ())
    return jitted.lower(state, batch, None).compile().as_text()


def make_eval_step(model: Model, recipe, rules=None):
    policy = as_policy(recipe)

    def eval_step(params, batch):
        loss, metrics = model.train_loss(params, batch, policy=policy,
                                         rules=rules)
        return metrics
    return eval_step


# ---------------------------------------------------------------------------
# Sharding helpers for the full TrainState
# ---------------------------------------------------------------------------

def state_shardings(rules, model: Model, state_shapes: TrainState):
    """NamedSharding tree matching a TrainState's structure.  Optimizer
    moments mirror their parameter's logical axes when shapes match (fp/fake
    storage); int-codec QState subtrees carry the blockwise bucket layout
    (nblocks, block_size) of kernels/opt_update.py and FSDP-shard their
    leading block dim (payload AND scale/zero sidecars, so fused-kernel
    buckets concatenate shard-aligned) when it divides, else replicate."""
    if rules is None:
        return None
    flat_p, p_treedef = jax.tree_util.tree_flatten(state_shapes.params)
    flat_ax = p_treedef.flatten_up_to(model.axes)
    p_shard_leaves = [rules.sharding_for(p.shape, ax)
                      for p, ax in zip(flat_p, flat_ax)]
    p_shard = jax.tree_util.tree_unflatten(p_treedef, p_shard_leaves)

    def moments(tree):
        flat_m = p_treedef.flatten_up_to(tree)
        out = []
        for p, ax, mstate in zip(flat_p, flat_ax, flat_m):
            if isinstance(mstate, qadam.QState):
                # "embed" is the FSDP-mapped logical axis; sharding_for
                # drops it when the block count does not divide.
                out.append(qadam.QState(
                    q=rules.sharding_for(mstate.q.shape,
                                         ("embed",) + (None,)
                                         * (len(mstate.q.shape) - 1)),
                    scale=rules.sharding_for(mstate.scale.shape,
                                             ("embed",) + (None,)
                                             * (len(mstate.scale.shape) - 1)),
                    zero=rules.sharding_for(mstate.zero.shape,
                                            ("embed",) + (None,)
                                            * (len(mstate.zero.shape) - 1))))
            elif tuple(mstate.shape) == tuple(p.shape):
                out.append(rules.sharding_for(p.shape, ax))
            else:
                out.append(rules.replicated())
        return jax.tree_util.tree_unflatten(p_treedef, out)

    return TrainState(
        params=p_shard,
        opt=AdamState(step=rules.replicated(),
                      m1=moments(state_shapes.opt.m1),
                      m2=moments(state_shapes.opt.m2)))


def batch_shardings(rules, batch_specs):
    """DP-shard the leading batch dim where divisible, else replicate."""
    if rules is None:
        return None

    def one(s):
        if s.shape and s.shape[0] % rules.dp_size == 0:
            return rules.batch_sharding(len(s.shape))
        return rules.replicated()

    return jax.tree_util.tree_map(one, batch_specs)
