"""Fault-tolerant checkpointing (orbax is unavailable; built from scratch).

Properties required at 1000+-node scale:
  * atomic: write to a temp dir, fsync, rename -- a preempted writer never
    corrupts the latest checkpoint;
  * rotating: keep_n most recent checkpoints + optional keep_every milestone;
  * async: snapshot to host memory synchronously (cheap), serialize on a
    background thread so the train loop is not blocked by disk;
  * elastic / mesh-agnostic: leaves are saved as full logical arrays; restore
    takes a sharding tree and ``jax.device_put``s onto whatever mesh the new
    job has (different pod count / axis sizes are fine);
  * self-describing: manifest.json records step, leaf paths/dtypes/shapes and
    arbitrary user metadata (loader state, recipe, config digest).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_write: bool = False):
        self.directory = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: PyTree,
             metadata: Optional[Dict] = None) -> str:
        """Snapshot to host (synchronous) then serialize (async optional)."""
        named = _flatten(tree)
        host = [(n, np.asarray(x)) for n, x in named]   # device->host copy now
        meta = dict(metadata or {})
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
            return self._ckpt_dir(step)
        return self._write(step, host, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _ckpt_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _write(self, step: int, host, meta) -> str:
        final = self._ckpt_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "metadata": meta,
                    "leaves": {}}
        arrays = {}
        for name, arr in host:
            key = name.replace(_SEP, "__")
            arrays[key] = arr
            manifest["leaves"][name] = {
                "file_key": key, "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "manifest.json")) as f:
            f.read()                                    # flush sanity
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._rotate()
        return final

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._ckpt_dir(s), ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None
                ) -> Tuple[PyTree, Dict]:
        """Rebuild ``target``-structured tree from disk.  ``shardings`` (same
        structure, NamedSharding leaves) places leaves onto the current mesh
        -- this is the elastic-restore path: the saved mesh is irrelevant."""
        path = self._ckpt_dir(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        named = _flatten(target)
        shard_leaves = (None if shardings is None
                        else [s for _, s in _flatten(shardings)])
        leaves = []
        for i, (name, leaf) in enumerate(named):
            info = manifest["leaves"].get(name)
            if info is None:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = data[info["file_key"]]
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs "
                    f"target {leaf.shape}")
            if shard_leaves is not None and shard_leaves[i] is not None:
                leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                leaves.append(jax.device_put(arr))
        _, treedef = jax.tree_util.tree_flatten(target)
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                manifest["metadata"])
