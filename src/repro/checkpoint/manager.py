"""Fault-tolerant checkpointing (orbax is unavailable; built from scratch).

Properties required at 1000+-node scale:
  * atomic: write to a unique temp dir, fsync, write a digest commit marker,
    rename -- a preempted or crashed writer never corrupts the latest
    checkpoint and never collides with a concurrent writer's temp dir;
  * verified: every leaf carries a CRC32 in the manifest, and the manifest
    itself is pinned by a sha256 commit marker (``COMMIT``) written last --
    bit rot, truncation and half-written checkpoints are *detected at
    restore time*, not silently loaded;
  * rotating: keep_n most recent checkpoints; rotation never deletes a
    checkpoint that a concurrent :meth:`restore` is currently reading;
  * async: snapshot to host memory synchronously (cheap), serialize on a
    background thread so the train loop is not blocked by disk; a second
    ``save()`` joins the in-flight write first (never interleaves), and an
    exception on the writer thread propagates to the next ``save()`` /
    ``wait()`` instead of vanishing with the daemon thread;
  * elastic / mesh-agnostic: leaves are saved as full logical arrays; restore
    takes a sharding tree and ``jax.device_put``s onto whatever mesh the new
    job has (different pod count / axis sizes are fine);
  * self-describing: manifest.json records step, leaf paths/dtypes/shapes/
    CRCs and arbitrary user metadata (loader state, recipe, config digest).
    Integer-stored optimizer moments (``qadam.QState`` int8 payloads + fp32
    scale/zero sidecars) are ordinary leaves and round-trip bit-exactly.

Recovery entry point: :meth:`restore_latest` walks the rotation newest-first,
verifies each candidate, and loads the first intact one -- a corrupt or
half-written newest checkpoint costs one rotation slot, not the run.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"

#: manifest schema version: 2 adds per-leaf crc32 + the COMMIT digest marker
MANIFEST_VERSION = 2


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed verification (missing files, digest mismatch,
    CRC mismatch, unreadable payload).  Carries the offending step/path."""

    def __init__(self, msg: str, step: Optional[int] = None,
                 path: Optional[str] = None):
        super().__init__(msg)
        self.step = step
        self.path = path


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _crc(arr: np.ndarray) -> int:
    """CRC32 of the leaf payload bytes (C-contiguous view)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _manifest_digest(manifest: Dict) -> str:
    """sha256 over the canonicalized manifest content: step + leaf table
    (file keys, dtypes, shapes, CRCs).  Metadata is covered too -- the
    loader state a resume replays from must be as trustworthy as the
    params."""
    body = json.dumps({"step": manifest["step"],
                       "leaves": manifest["leaves"],
                       "metadata": manifest.get("metadata", {})},
                      sort_keys=True).encode()
    return hashlib.sha256(body).hexdigest()


def _fsync_file(path: str) -> None:
    with open(path, "rb") as f:
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_write: bool = False):
        self.directory = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._write_error: Optional[BaseException] = None
        self._save_lock = threading.Lock()     # serializes writes + rotation
        self._tmp_seq = 0
        self._reading: Set[int] = set()        # steps a restore() is inside
        #: test hooks (see train/faults.py): called with (step) after the
        #: array payload is on disk but before the commit marker, and with
        #: (step, final_path) after a completed write + rotation.
        self.on_mid_write: Optional[Callable[[int], None]] = None
        self.on_after_write: Optional[Callable[[int, str], None]] = None
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------

    def save(self, step: int, tree: PyTree,
             metadata: Optional[Dict] = None) -> str:
        """Snapshot to host (synchronous) then serialize (async optional).
        Joins any in-flight async write first -- two writers never share a
        temp dir -- and re-raises an error the previous background write hit
        (a silently-lost checkpoint must fail the *next* save, not nothing).
        """
        self.wait()                             # joins + propagates errors
        named = _flatten(tree)
        host = [(n, np.asarray(x)) for n, x in named]   # device->host copy now
        meta = dict(metadata or {})
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta),
                daemon=True)
            self._thread.start()
            return self._ckpt_dir(step)
        return self._write(step, host, meta)

    def wait(self) -> None:
        """Join the in-flight async write; raise its error, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._write_error is not None:
            err, self._write_error = self._write_error, None
            raise err

    def _write_guarded(self, step: int, host, meta) -> None:
        try:
            self._write(step, host, meta)
        except BaseException as e:              # lint: except-ok
            # daemon thread: park the error for the next save()/wait()
            self._write_error = e

    def _ckpt_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _write(self, step: int, host, meta) -> str:
        with self._save_lock:
            return self._write_locked(step, host, meta)

    def _write_locked(self, step: int, host, meta) -> str:
        final = self._ckpt_dir(step)
        # unique temp dir per write attempt: a crashed/preempted writer's
        # leftovers can never be half-reused by the next attempt
        self._tmp_seq += 1
        tmp = f"{final}.tmp-{os.getpid()}-{self._tmp_seq}"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"version": MANIFEST_VERSION, "step": step,
                    "time": time.time(), "metadata": meta, "leaves": {}}
        arrays = {}
        for name, arr in host:
            key = name.replace(_SEP, "__")
            arrays[key] = arr
            manifest["leaves"][name] = {
                "file_key": key, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "crc32": _crc(arr)}
        apath = os.path.join(tmp, "arrays.npz")
        np.savez(apath, **arrays)
        _fsync_file(apath)
        if self.on_mid_write is not None:
            # the preemption window the fault harness targets: payload on
            # disk, manifest/commit marker not yet -- the checkpoint must
            # not be restorable from this state
            self.on_mid_write(step)
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        # commit marker written LAST: its presence certifies every earlier
        # byte; its content pins the manifest (and through the CRCs, the
        # payload) against bit rot and truncation
        cpath = os.path.join(tmp, "COMMIT")
        with open(cpath, "w") as f:
            f.write(_manifest_digest(manifest))
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.directory)
        self._rotate_locked()
        if self.on_after_write is not None:
            self.on_after_write(step, final)
        return final

    def _rotate_locked(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            if s in self._reading:
                # a concurrent restore() holds this step open: deleting it
                # under the reader is the race this guard exists for.  It
                # will be collected by a later save's rotation.
                continue
            shutil.rmtree(self._ckpt_dir(s), ignore_errors=True)

    def prune_incomplete(self) -> List[str]:
        """Remove leftover ``*.tmp-*`` dirs from crashed writers (safe on
        startup: no live writer shares our pid+seq namespace)."""
        removed = []
        for name in sorted(os.listdir(self.directory)):
            if ".tmp" in name and name.startswith("step_"):
                p = os.path.join(self.directory, name)
                shutil.rmtree(p, ignore_errors=True)
                removed.append(p)
        return removed

    # -- read ---------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int, check_payload: bool = True) -> Dict:
        """Validate one checkpoint: commit marker present and matching the
        manifest digest; every manifest leaf present in the payload with a
        matching CRC32.  Returns the parsed manifest on success, raises
        :class:`CheckpointCorrupt` otherwise.  ``check_payload=False`` skips
        the (full-read) CRC pass and only checks the commit marker."""
        path = self._ckpt_dir(step)
        mpath = os.path.join(path, "manifest.json")
        cpath = os.path.join(path, "COMMIT")
        if not os.path.isdir(path):
            raise CheckpointCorrupt(f"no checkpoint at {path}", step, path)
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(
                f"step {step}: unreadable manifest ({e})", step, path) from e
        if manifest.get("version", 1) >= 2:
            try:
                with open(cpath) as f:
                    commit = f.read().strip()
            except OSError as e:
                raise CheckpointCorrupt(
                    f"step {step}: missing COMMIT marker (half-written "
                    "checkpoint?)", step, path) from e
            if commit != _manifest_digest(manifest):
                raise CheckpointCorrupt(
                    f"step {step}: manifest digest mismatch", step, path)
        if not check_payload:
            return manifest
        try:
            with np.load(os.path.join(path, "arrays.npz")) as data:
                for name, info in manifest["leaves"].items():
                    if info["file_key"] not in data.files:
                        raise CheckpointCorrupt(
                            f"step {step}: payload missing leaf {name!r}",
                            step, path)
                    arr = data[info["file_key"]]
                    crc = info.get("crc32")
                    if crc is not None and _crc(arr) != crc:
                        raise CheckpointCorrupt(
                            f"step {step}: CRC mismatch on leaf {name!r}",
                            step, path)
        except CheckpointCorrupt:
            raise
        except Exception as e:                   # zip/npz decode errors
            raise CheckpointCorrupt(
                f"step {step}: unreadable payload ({e})", step, path) from e
        return manifest

    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None,
                verify: bool = True) -> Tuple[PyTree, Dict]:
        """Rebuild ``target``-structured tree from disk.  ``shardings`` (same
        structure, NamedSharding leaves) places leaves onto the current mesh
        -- this is the elastic-restore path: the saved mesh is irrelevant.
        With ``verify`` (default) the commit marker and per-leaf CRCs are
        checked first; corruption raises :class:`CheckpointCorrupt` (see
        :meth:`restore_latest` for the falls-back-through-rotation form).
        """
        self._reading.add(step)                 # rotation must not delete us
        try:
            return self._restore_inner(step, target, shardings, verify)
        finally:
            self._reading.discard(step)

    def _restore_inner(self, step, target, shardings, verify):
        path = self._ckpt_dir(step)
        if verify:
            manifest = self.verify(step)
        else:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        named = _flatten(target)
        shard_leaves = (None if shardings is None
                        else [s for _, s in _flatten(shardings)])
        leaves = []
        for i, (name, leaf) in enumerate(named):
            info = manifest["leaves"].get(name)
            if info is None:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = data[info["file_key"]]
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs "
                    f"target {leaf.shape}")
            if hasattr(leaf, "dtype") and arr.dtype != np.dtype(leaf.dtype):
                # int8 payloads / fp32 sidecars must come back as stored --
                # a silent cast here would break bit-exact resume
                raise ValueError(
                    f"dtype mismatch for {name}: ckpt {arr.dtype} vs "
                    f"target {np.dtype(leaf.dtype)}")
            if shard_leaves is not None and shard_leaves[i] is not None:
                leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                leaves.append(jax.device_put(arr))
        _, treedef = jax.tree_util.tree_flatten(target)
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                manifest["metadata"])

    def restore_latest(self, target: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, Dict, int]:
        """Restore the newest *intact* checkpoint, falling back through the
        rotation when verification fails: a corrupt / truncated / half-
        written newest checkpoint costs one rotation slot, not the run.
        Returns ``(tree, metadata, step)``; raises
        :class:`CheckpointCorrupt` when no candidate survives."""
        steps = self.all_steps()
        errors: List[str] = []
        for step in reversed(steps):
            try:
                tree, meta = self.restore(step, target, shardings)
                return tree, meta, step
            except CheckpointCorrupt as e:
                errors.append(str(e))
        raise CheckpointCorrupt(
            "no intact checkpoint in "
            f"{self.directory!r} (candidates: {steps}); "
            + "; ".join(errors) if errors else
            f"no checkpoint in {self.directory!r}")
