"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device counts are locked at first jax initialization, and
tests/benches must see the real single CPU device, not the dry-run's 512
placeholders).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (CPU tests: 1 device -> (1,1))."""
    n = jax.device_count()
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"))
