"""Production launcher: --arch <id> --shape <shape> on whatever mesh the
host provides (falls back to single device for local runs; on a real TPU
slice the same entry point builds the full mesh and sharded train step).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --smoke --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --shape train_4k --model-parallel 4       # on hardware
    PYTHONPATH=src python -m repro.launch.train --arch gpt2-small --smoke \
        --policy 'block[0:2].*=fp,*=w8c+a8t@int8_pallas'   # per-layer policy
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_shape, get_smoke_config
from repro.core import fallback_policy, get_recipe, parse_policy
from repro.data import Loader, SyntheticCorpus
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import OptConfig
from repro.parallel.sharding import make_rules
from repro.train import (FaultPlan, LoopConfig, SentinelConfig,
                         StabilitySentinel, Trainer, init_train_state,
                         make_eval_step, make_train_step)
from repro.train.step import batch_shardings, state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small batch (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--recipe", default="paper",
                    help="preset name or compact spec ('w8c,a8t,m1:4c')")
    ap.add_argument("--policy", default="",
                    help="per-layer-role policy rules, e.g. "
                         "'embed=fp,block[0:2].*=fp,*=w8c+a8t@int8_pallas' "
                         "(overrides --recipe)")
    ap.add_argument("--state-storage", default="fake")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--sentinel", action="store_true",
                    help="guard every step with the stability sentinel "
                         "(skip-batch / rollback / fallback-window ladder)")
    ap.add_argument("--sentinel-window", type=int, default=32)
    ap.add_argument("--sentinel-sigma", type=float, default=6.0)
    ap.add_argument("--fallback-steps", type=int, default=16,
                    help="length of the fp/fake-quant window after a rollback")
    ap.add_argument("--fallback-mode", choices=("fake", "fp"), default="fake",
                    help="degraded policy during the fallback window: "
                         "'fake' keeps fake-quant (continual-QAT posture), "
                         "'fp' drops quantization entirely")
    ap.add_argument("--fault", default="",
                    help="deterministic fault-injection spec (overrides the "
                         "REPRO_FAULT env var), e.g. 'nan_grad@50'")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        batch = args.batch or 8
        seq = args.seq or 64
    else:
        cfg = get_config(args.arch)
        shape = get_shape(args.shape)
        batch = args.batch or shape.global_batch
        seq = args.seq or shape.seq_len

    model = build_model(cfg)
    recipe = (parse_policy(args.policy) if args.policy
              else get_recipe(args.recipe))
    mesh = make_host_mesh(args.model_parallel)
    multi = mesh.devices.size > 1
    rules = make_rules(mesh, "train", cfg=cfg) if multi else None
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps, state_storage=args.state_storage)
    print(f"arch={cfg.name} devices={mesh.devices.size} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"policy=[{recipe.describe()}] batch={batch} seq={seq}")
    from repro.train.step import train_path_summary
    summary = train_path_summary(recipe, getattr(cfg, "n_layers", 0),
                                 opt_cfg=opt)
    print(f"train-path: {summary}")
    faults = FaultPlan.from_env(args.fault or None)
    if faults:
        print(f"fault-plan: {faults.describe()}")
    sentinel = fallback_step = None
    if args.sentinel:
        sentinel = StabilitySentinel(SentinelConfig(
            window=args.sentinel_window, spike_sigma=args.sentinel_sigma,
            fallback_steps=args.fallback_steps))
    state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
    step_fn = make_train_step(model, recipe, opt, rules=rules,
                              accum_steps=args.accum,
                              faults=faults if faults else None,
                              health=args.sentinel)
    if multi:
        st_sh = state_shardings(rules, model, jax.eval_shape(lambda: state))
        step = jax.jit(step_fn, in_shardings=(st_sh, None, None),
                       out_shardings=(st_sh, None))
    else:
        step = jax.jit(step_fn)
    if args.sentinel:
        # the degraded policy keeps the AdamState structure (m1/m2 specs are
        # preserved) so the two compiled steps hand the state back and forth
        fb_policy = fallback_policy(
            recipe, mode="fake_quant" if args.fallback_mode == "fake"
            else "fp")
        fb_fn = make_train_step(model, fb_policy, opt, rules=rules,
                                accum_steps=args.accum, health=True)
        fallback_step = (jax.jit(fb_fn, in_shardings=(st_sh, None, None),
                                 out_shardings=(st_sh, None))
                         if multi else jax.jit(fb_fn))
    eval_step = jax.jit(make_eval_step(model, recipe, rules=rules))

    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    loader = Loader(corpus, cfg, batch_size=batch, seq_len=seq)
    valid = Loader(corpus, cfg, batch_size=batch, seq_len=seq, split="valid")
    mgr = CheckpointManager(args.ckpt, async_write=True) if args.ckpt else None
    trainer = Trainer(step, eval_step, state, loader, ckpt=mgr,
                      valid_loader=valid,
                      loop_cfg=LoopConfig(
                          total_steps=args.steps,
                          ckpt_every=max(args.steps // 3, 50),
                          eval_every=max(args.steps // 5, 20),
                          log_every=10),
                      sentinel=sentinel, fallback_step=fallback_step,
                      faults=faults if faults else None)
    trainer.install_preemption_handler()
    trainer.maybe_resume()
    for rowd in trainer.run(rng=jax.random.PRNGKey(0)):
        extra = f"  valid={rowd['valid_ce']:.4f}" if "valid_ce" in rowd else ""
        if rowd.get("fallback"):
            extra += "  [fallback]"
        print(f"step {rowd['step']:5d}  ce={rowd['ce']:.4f}"
              f"  {rowd['sec_per_step']*1e3:.0f}ms/step{extra}", flush=True)
    if args.sentinel or faults:
        print(f"resilience: {trainer.resilience_summary()}")


if __name__ == "__main__":
    main()
