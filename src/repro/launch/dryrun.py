import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count at first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json (incremental:
every cell writes its own file on completion; EXPERIMENTS.md tables are
generated from these).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config, get_shape,
                           shape_applicable)
from repro.core import get_recipe
from repro.launch.mesh import make_production_mesh
from repro.models.model_api import (build_model, decode_input_specs,
                                    decode_state_axes, input_specs,
                                    prefill_batch_specs, train_batch_specs)
from repro.optim import OptConfig
from repro.parallel.hlo_count import count_module
from repro.parallel.roofline import roofline_terms
from repro.parallel.sharding import make_rules
from repro.train.step import (TrainState, batch_shardings, init_train_state,
                              make_train_step, state_shardings)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(ma) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "peak_memory_in_bytes"]
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(ma, k))
        except Exception:
            pass
    return out


def _model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def lower_cell(arch: str, shape_name: str, mesh, recipe_name: str = "paper",
               remat_override=None, serve_sp=None, rules_mode: str = "train"):
    """Returns (lowered, meta) for one cell -- the core dry-run unit."""
    cfg = get_config(arch)
    if remat_override is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=remat_override)
    shape = get_shape(shape_name)
    recipe = get_recipe(recipe_name)
    model = build_model(cfg)
    n_dev = mesh.devices.size

    if shape.kind == "train":
        rules = make_rules(mesh, rules_mode, cfg=cfg)
        opt_cfg = OptConfig()
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(model, k, recipe, opt_cfg),
            jax.random.PRNGKey(0))
        st_sh = state_shardings(rules, model, state_shapes)
        b_specs = train_batch_specs(cfg, shape)
        b_sh = batch_shardings(rules, b_specs)
        step = make_train_step(model, recipe, opt_cfg, rules=rules)
        fn = jax.jit(lambda state, batch: step(state, batch, None),
                     in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None),
                     donate_argnums=(0,))
        with mesh:
            lowered = fn.lower(state_shapes, b_specs)
        return lowered, {"kind": "train"}

    if shape.kind == "prefill":
        rules = make_rules(mesh, "serve", cfg=cfg)
        # serving holds bf16 weights (no fp32 master at inference time)
        p_shapes = jax.eval_shape(
            lambda k: model.init_params(k, jnp.bfloat16),
            jax.random.PRNGKey(0))
        p_sh = rules.tree_shardings(p_shapes, model.axes)
        b_specs = prefill_batch_specs(cfg, shape)
        b_sh = batch_shardings(rules, b_specs)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, recipe=recipe, rules=rules)

        fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
        with mesh:
            lowered = fn.lower(p_shapes, b_specs)
        return lowered, {"kind": "prefill"}

    # decode: KV caches are seq-sharded over the tensor axis (SP flash-decode
    # -- softmax reductions over the sharded KV length lower to all-reduces).
    # Required whenever kv-head count < tp (GQA caches cannot head-shard).
    use_sp = serve_sp if serve_sp is not None else True
    rules = make_rules(mesh, "serve_sp" if use_sp else "serve", cfg=cfg)
    p_shapes = jax.eval_shape(lambda k: model.init_params(k, jnp.bfloat16),
                              jax.random.PRNGKey(0))
    p_sh = rules.tree_shardings(p_shapes, model.axes)
    specs = decode_input_specs(cfg, shape, model)
    axes_tree = _expand_axes(decode_state_axes(cfg), specs["state"])
    st_sh = jax.tree_util.tree_map(
        lambda s, ax: rules.sharding_for(
            s.shape, ax if ax else (None,) * len(s.shape)),
        specs["state"], axes_tree)
    tok_sh = batch_shardings(rules, {"t": specs["token"]})["t"]

    def decode_fn(params, state, token, pos):
        return model.decode(params, state, token, pos, recipe=recipe,
                            rules=rules)

    fn = jax.jit(decode_fn,
                 in_shardings=(p_sh, st_sh, tok_sh, None),
                 out_shardings=(None, st_sh),
                 donate_argnums=(1,))
    with mesh:
        lowered = fn.lower(p_shapes, specs["state"], specs["token"],
                           specs["pos"])
    return lowered, {"kind": "decode", "serve_sp": use_sp}


def _expand_axes(axes_tree, state_tree):
    """Broadcast per-subtree axes tuples over the matching state leaves."""
    def expand(ax, sub):
        if sub is None:
            return None
        return jax.tree_util.tree_map(lambda leaf: ax, sub)
    return jax.tree_util.tree_map(
        expand, axes_tree, state_tree,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             recipe_name: str = "paper", out_dir: str = OUT_DIR,
             tag: str = "", rules_mode: str = "train") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "recipe": recipe_name, "status": "ok"}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result.update({"status": "skipped", "reason": reason})
        return _write(result, out_dir, tag)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        lowered, meta = lower_cell(arch, shape_name, mesh, recipe_name,
                                   rules_mode=rules_mode)
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        print(f"[{arch} {shape_name} {mesh_name}] memory_analysis: {ma}")
        ca = compiled.cost_analysis()
        text = compiled.as_text()
        # loop-aware per-device counts (XLA cost_analysis counts scan bodies
        # once -- see parallel/hlo_count.py)
        counts = count_module(text, n_dev)
        print(f"[{arch} {shape_name} {mesh_name}] "
              f"flops/dev={counts['flops']:.3e} "
              f"bytes/dev={counts['bytes']:.3e} "
              f"wire/dev={counts['wire_bytes']:.3e}")
        mf = _model_flops(cfg, shape)
        terms = roofline_terms(counts["flops"], counts["bytes"],
                               counts["wire_bytes"], mf, n_dev)
        result.update({
            "kind": meta["kind"],
            "n_devices": n_dev,
            "memory": _mem_dict(ma),
            "flops_per_dev": counts["flops"],
            "bytes_per_dev": counts["bytes"],
            "collectives": {k: v for k, v in counts.items()
                            if k.startswith("wire_") or k == "coll_count"},
            "wire_bytes_per_dev": counts["wire_bytes"],
            "xla_cost_raw": {"flops_once": ca.get("flops", 0.0),
                             "bytes_once": ca.get("bytes accessed", 0.0)},
            "model_flops": mf,
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "roofline": terms,
        })
    except Exception as e:
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]})
    return _write(result, out_dir, tag)


def _write(result: dict, out_dir: str, tag: str = "") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
            f"{('__' + tag) if tag else ''}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1, default=float)
    status = result["status"]
    extra = ""
    if status == "ok":
        r = result["roofline"]
        extra = (f" dominant={r['dominant']} step={r['step_time_s']:.4f}s "
                 f"mfu={r.get('roofline_mfu', 0):.3f}")
    print(f"[{result['arch']} {result['shape']} {result['mesh']}] "
          f"{status}{extra}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--recipe", default="paper")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules-mode", default="train")
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.both_meshes
              else [bool(args.multi_pod)])
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = ("__" + args.tag) if args.tag else ""
        path = os.path.join(args.out,
                            f"{arch}__{shape}__{mesh_name}{tag}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[{arch} {shape} {mesh_name}] cached", flush=True)
                    continue
        run_cell(arch, shape, mp, args.recipe, args.out, args.tag,
                 rules_mode=args.rules_mode)


if __name__ == "__main__":
    main()
