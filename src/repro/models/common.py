"""Shared model machinery: parameter specs, norms, rope, activations.

Parameters are plain nested dicts of jnp arrays.  Each module exposes a
``spec(cfg) -> {name: ParamSpec}`` describing shapes + logical sharding axes;
``init_from_spec`` materializes values and ``axes_from_spec`` the matching
logical-axes tree consumed by ``repro.parallel.sharding``.

Logical axis vocabulary (mapped to mesh axes by sharding rules):
  "embed"   : d_model dims            (FSDP candidate)
  "vocab"   : vocabulary              (TP)
  "mlp"     : feed-forward hidden     (TP)
  "heads"   : attention q-head dim    (TP)
  "kv"      : attention kv-head dim   (TP, may be smaller than axis)
  "expert"  : MoE expert dim          (EP)
  "inner"   : SSM inner dim           (TP)
  "layers"  : stacked scan dim        (never sharded)
  None      : replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled(fan_in)
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * spec.scale).astype(dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else spec.shape[-1]
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(spec.init)


def init_from_spec(key: jax.Array, spec_tree: PyTree, dtype=jnp.float32) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def axes_from_spec(spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: s.axes, spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_layer_specs(spec_tree: PyTree, n_layers: int) -> PyTree:
    """Prepend a scan 'layers' dim to every ParamSpec (stacked-params scan)."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n_layers,) + s.shape, ("layers",) + s.axes,
                            s.init, s.scale),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Sharding-constraint hook.  ``rules`` is repro.parallel.sharding.Rules (or
# None on single-device paths); models call constrain(x, "batch", None, ...)
# with logical activation axes.
# ---------------------------------------------------------------------------

def constrain(x: jnp.ndarray, rules, *logical_axes) -> jnp.ndarray:
    if rules is None:
        return x
    return rules.constrain(x, logical_axes)


def cast_params(params: PyTree, dtype) -> PyTree:
    """Carrier-precision cast (bf16 AMP): float leaves only; int payloads and
    anything already matching pass through.  Prepared quantized weights
    (``QState`` payload + fp32 scale sidecars, see ``repro.infer.prepare``)
    are opaque: casting their scales to bf16 would change the dequant grid."""
    from repro.core.qadam import QState
    def cast(x):
        if isinstance(x, QState):
            return x
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != dtype:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(
        cast, params, is_leaf=lambda x: isinstance(x, QState))


# ---------------------------------------------------------------------------
# Normalization / activations / position embeddings.
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
            plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                      # gemma convention: weight stored as w-1
        w = w + 1.0
    return (y * w).astype(x.dtype)


def layernorm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if kind == "rmsnorm_p1":
        return rmsnorm(x, params["scale"], plus_one=True)
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    raise ValueError(kind)


def norm_spec(d: int, kind: str) -> Dict[str, ParamSpec]:
    if kind in ("rmsnorm",):
        return {"scale": ParamSpec((d,), ("embed",), "ones")}
    if kind == "rmsnorm_p1":
        return {"scale": ParamSpec((d,), ("embed",), "zeros")}
    if kind == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), "ones"),
                "bias": ParamSpec((d,), ("embed",), "zeros")}
    raise ValueError(kind)


ACT_FNS: Dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def rope(q: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
         ) -> jnp.ndarray:
    """Rotary embedding.  q: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = q.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    q1, q2 = q[..., :half], q[..., half:]
    qf1, qf2 = q1.astype(jnp.float32), q2.astype(jnp.float32)
    return jnp.concatenate(
        [qf1 * cos - qf2 * sin, qf2 * cos + qf1 * sin], axis=-1).astype(q.dtype)


def causal_mask(s_q: int, s_kv: int, q_offset: int = 0) -> jnp.ndarray:
    """Boolean (s_q, s_kv) mask: True = attend."""
    qpos = jnp.arange(s_q) + q_offset
    kpos = jnp.arange(s_kv)
    return kpos[None, :] <= qpos[:, None]


def prefix_lm_mask(s: int, prefix_len: int, s_kv: int = 0) -> jnp.ndarray:
    """PaliGemma-style: full attention within [0, prefix), causal after.
    ``s_kv`` widens the key axis for cache buffers (extra keys masked by
    causality since qpos < s <= kpos)."""
    s_kv = s_kv or s
    base = causal_mask(s, s_kv)
    qpos = jnp.arange(s)
    kpos = jnp.arange(s_kv)
    in_prefix = (qpos[:, None] < prefix_len) & (kpos[None, :] < prefix_len)
    return base | in_prefix
