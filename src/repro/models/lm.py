"""Decoder-only language model covering dense / MoE / SSM / hybrid / VLM
families: scan-over-layers (compile-time O(1) in depth), remat, chunked
cross-entropy (never materializes (B,S,V) logits), KV-cache prefill/decode.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qpolicy import LinearCtx, as_policy
from repro.models.attention import attn_apply, attn_spec, init_cache
from repro.models.blocks import block_apply, block_spec
from repro.models.common import (ParamSpec, apply_norm, cast_params,
                                 causal_mask, constrain, norm_spec,
                                 prefix_lm_mask, stack_layer_specs)
from repro.models.mlp import mlp_apply, mlp_spec
from repro.models.ssm import init_ssm_state, ssm_dims
from repro.configs.base import ArchConfig

AUX_COEF = 0.01
ZLOSS_COEF = 1e-3


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def shared_block_spec(cfg) -> Dict:
    """zamba2: one attention+MLP block shared across the depth, operating on
    concat(h, input_embedding) in 2*d_model space, projected back to d."""
    d2 = 2 * cfg.d_model
    return {
        "ln1": norm_spec(d2, cfg.norm),
        "attn": attn_spec(cfg, d_in=d2),
        "ln2": norm_spec(d2, cfg.norm),
        "mlp": mlp_spec(cfg, d_in=d2, d_ff=cfg.d_ff),
        "proj": ParamSpec((d2, cfg.d_model), ("embed2", "embed"), "fan_in",
                          scale=1.0 / max(cfg.n_layers, 1)),
    }


def lm_spec(cfg: ArchConfig) -> Dict:
    spec: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                           "normal", 0.02),
    }
    if cfg.pos == "learned":
        spec["pos_embed"] = ParamSpec((cfg.max_seq, cfg.d_model),
                                      (None, "embed"), "normal", 0.01)
    spec["blocks"] = stack_layer_specs(block_spec(cfg), cfg.n_layers)
    spec["final_norm"] = norm_spec(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                    ("embed", "vocab"), "fan_in")
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        spec["shared"] = shared_block_spec(cfg)
    if cfg.family == "vlm":
        # stub frontend: a single linear adapting precomputed patch embeddings
        spec["patch_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                       ("embed2", "embed"), "fan_in")
    return spec


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens: jnp.ndarray, cfg, positions=None,
                 dtype=None, policy=None) -> jnp.ndarray:
    """Token (+learned position) embedding.  The ``embed`` role governs a
    weight-only qdq of the table (fp under ``from_recipe`` policies unless
    ``include_embeddings`` -- the paper scopes to block linears)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    table = params["embed"]
    if policy is not None:
        table = policy.quantize_weight("embed", table)
    e = jnp.take(table, tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        e = e * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if cfg.pos == "learned":
        assert positions is not None
        pe = jnp.take(params["pos_embed"], positions, axis=0).astype(dtype)
        e = e + pe
    return e


def logits_chunk(params, h: jnp.ndarray, cfg, policy=None) -> jnp.ndarray:
    """(B, C, d) -> (B, C, V_padded) in fp32, padded tail masked to -inf.
    The ``lm_head`` role governs a weight-only qdq of the head matrix (the
    tied embedding table when ``tie_embeddings``)."""
    if cfg.tie_embeddings:
        table = params["embed"]
        if policy is not None:
            table = policy.quantize_weight("lm_head", table)
        logits = jnp.einsum("bcd,vd->bcv", h, table.astype(h.dtype),
                            preferred_element_type=jnp.float32)
    else:
        head = params["lm_head"]
        if policy is not None:
            head = policy.quantize_weight("lm_head", head)
        logits = jnp.einsum("bcd,dv->bcv", h, head.astype(h.dtype),
                            preferred_element_type=jnp.float32)
    if cfg.vocab_padded > cfg.vocab_size:
        neg = jnp.asarray(-1e30, logits.dtype)
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask[None, None, :], logits, neg)
    return logits


def _chunk_len(s: int, target: int) -> int:
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


def chunked_ce(params, h: jnp.ndarray, labels: jnp.ndarray,
               mask: Optional[jnp.ndarray], cfg, rules,
               policy=None) -> jnp.ndarray:
    """Cross entropy computed in sequence chunks so (B,S,V) never exists.
    Vocab stays sharded ('vocab' -> tensor axis) inside each chunk."""
    b, s, _ = h.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    chunk = _chunk_len(s, cfg.logit_chunk or s)
    n_chunks = s // chunk

    def body(carry, i):
        tot, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        logits = logits_chunk(params, hc, cfg, policy)
        logits = constrain(logits, rules, "batch", None, "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((logz - gold) * mc.astype(jnp.float32))
        cnt = cnt + jnp.sum(mc.astype(jnp.float32))
        return (tot, cnt), None

    # checkpoint: the backward recomputes each chunk's logits instead of
    # keeping an fp32 (B, chunk, V) slab alive per chunk
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2,
                                 jnp.arange(n_chunks))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Layer stack execution
# ---------------------------------------------------------------------------

def _scan_blocks(params, h, cfg, *, policy, rules, positions, mask,
                 caches=None, cache_offset=None, ssm_states=None,
                 decode=False, page_table=None):
    """Homogeneous layer scan.  caches/ssm_states are stacked (L, ...).
    The scanned xs carry the depth index so depth-indexed policy rules can
    select per-layer quantization inside the (layer-invariant) trace.
    ``page_table`` (paged decode) is one table for every layer -- captured
    by the body closure, not scanned."""

    def body(carry, xs):
        hh, aux, z = carry
        bp, cache, sst, li = xs
        hh, ncache, nsst, a, zz = block_apply(
            bp, hh, cfg, policy=policy, rules=rules, positions=positions,
            mask=mask, cache=cache, cache_offset=cache_offset,
            ssm_state=sst, decode=decode, layer=li, page_table=page_table)
        return (hh, aux + a, z + zz), (ncache, nsst)

    if cfg.remat and not decode:
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names("attn_ctx"))

    zero = jnp.zeros((), jnp.float32)
    (h, aux, z), (ncaches, nssts) = jax.lax.scan(
        body, (h, zero, zero),
        (params["blocks"], caches, ssm_states,
         jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    return h, ncaches, nssts, aux, z


def _shared_attn(params, h, emb0, cfg, *, policy, rules, positions, mask,
                 cache=None, cache_offset=None):
    """zamba2 shared block: operates on concat(h, emb0).  The block's weights
    are shared across depth, so its linears resolve depth-less (layer=None);
    the down-projection is the ``shared_proj`` role."""
    sp = params["shared"]
    x2 = jnp.concatenate([h, emb0], axis=-1)
    x = apply_norm(x2, sp["ln1"], cfg.norm)
    y, ncache = attn_apply(sp["attn"], x, cfg, policy=policy, rules=rules,
                           positions=positions, mask=mask, cache=cache,
                           cache_offset=cache_offset)
    x2 = x2 + y
    x = apply_norm(x2, sp["ln2"], cfg.norm)
    x2 = x2 + mlp_apply(sp["mlp"], x, cfg, policy=policy, rules=rules)
    return h + policy.linear(LinearCtx("shared_proj"), x2, sp["proj"]), ncache


def _hybrid_blocks(params, h, cfg, *, policy, rules, positions, mask,
                   emb0, caches=None, cache_offset=None, ssm_states=None,
                   decode=False):
    """zamba2: groups of `hybrid_attn_every` mamba layers, each followed by
    the shared attention block.  caches: (G, B, S, K, hd); ssm stacked (L,...)."""
    per = cfg.hybrid_attn_every
    groups = cfg.n_layers // per
    grouped = jax.tree_util.tree_map(
        lambda x: x.reshape(groups, per, *x.shape[1:]), params["blocks"])
    g_ssm = (None if ssm_states is None else jax.tree_util.tree_map(
        lambda x: x.reshape(groups, per, *x.shape[1:]), ssm_states))
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32).reshape(groups, per)

    def group_body(carry, xs):
        hh, aux, z = carry
        gparams, gssm, gcache, g_layers = xs

        def inner(c, xs2):
            hhh, a2, z2 = c
            bp, sst, li = xs2
            hhh, _, nsst, a, zz = block_apply(
                bp, hhh, cfg, policy=policy, rules=rules, positions=positions,
                mask=None, ssm_state=sst, decode=decode, layer=li)
            return (hhh, a2 + a, z2 + zz), nsst

        (hh, aux, z), nssm = jax.lax.scan(inner, (hh, aux, z),
                                          (gparams, gssm, g_layers))
        hh, ncache = _shared_attn(params, hh, emb0, cfg, policy=policy,
                                  rules=rules, positions=positions, mask=mask,
                                  cache=gcache, cache_offset=cache_offset)
        return (hh, aux, z), (nssm, ncache)

    if cfg.remat and not decode:
        group_body = jax.checkpoint(
            group_body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names("attn_ctx"))

    zero = jnp.zeros((), jnp.float32)
    (h, aux, z), (nssm, ncaches) = jax.lax.scan(
        group_body, (h, zero, zero), (grouped, g_ssm, caches, layer_ids))
    if nssm is not None:
        nssm = jax.tree_util.tree_map(
            lambda x: x.reshape(cfg.n_layers, *x.shape[2:]), nssm)
    return h, ncaches, nssm, aux, z


def run_stack(params, h, cfg, **kw):
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        assert kw.pop("page_table", None) is None, \
            "paged KV is dense/moe-family only"
        return _hybrid_blocks(params, h, cfg, **kw)
    kw.pop("emb0", None)
    return _scan_blocks(params, h, cfg, **kw)


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, *,
            policy=None, rules=None,
            rng: Optional[jax.Array] = None) -> Tuple[jnp.ndarray, Dict]:
    """batch: {"tokens": (B, S+1) int32[, "patches": (B,P,d)]}.
    Returns (loss, metrics).  ``policy`` is anything ``as_policy`` accepts
    (None / QuantRecipe / QuantPolicy / policy string)."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    b, s_text = inp.shape
    positions_text = jnp.broadcast_to(jnp.arange(s_text), (b, s_text))

    if cfg.family == "vlm":
        patches = batch["patches"].astype(dtype)
        patches = policy.linear(LinearCtx("patch_proj"), patches,
                                params["patch_proj"])
        p = patches.shape[1]
        positions = jnp.broadcast_to(jnp.arange(p + s_text), (b, p + s_text))
        e = embed_tokens(params, inp, cfg, positions=positions_text + p,
                         dtype=dtype, policy=policy)
        h = jnp.concatenate([patches, e], axis=1)
        mask = {"kind": "prefix", "prefix": p}
    else:
        positions = positions_text
        h = embed_tokens(params, inp, cfg, positions=positions, dtype=dtype,
                         policy=policy)
        mask = {"kind": "causal"} if cfg.family != "ssm" else None

    h = constrain(h, rules, "batch", "seq", None)
    h, _, _, aux, z = run_stack(params, h, cfg, policy=policy, rules=rules,
                                positions=positions, mask=mask, emb0=h)
    h = apply_norm(h, params["final_norm"], cfg.norm)

    if cfg.family == "vlm":
        h = h[:, h.shape[1] - s_text:, :]
    loss_mask = batch.get("loss_mask")
    ce = chunked_ce(params, h, labels, loss_mask, cfg, rules, policy)
    total = ce
    metrics = {"ce": ce}
    if cfg.n_experts:
        total = total + AUX_COEF * aux / cfg.n_layers + \
            ZLOSS_COEF * z / cfg.n_layers
        metrics.update({"moe_aux": aux / cfg.n_layers,
                        "moe_z": z / cfg.n_layers})
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_seq: int, dtype,
                kv_spec=None):
    """Stacked decode state for the whole stack.  ``kv_spec`` (from
    ``policy.kv_spec()``) selects int8 KV storage; fp is the default."""
    caches = None
    ssm_states = None
    if cfg.family in ("dense", "moe", "vlm"):
        one = init_cache(cfg, batch, max_seq, dtype, kv_spec=kv_spec)
        caches = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), one)
    elif cfg.family == "ssm":
        one = init_ssm_state(cfg, batch, dtype)
        ssm_states = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), one)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.hybrid_attn_every
        one = init_cache(cfg, batch, max_seq, dtype, kv_spec=kv_spec)
        caches = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (groups,) + x.shape).copy(), one)
        s_one = init_ssm_state(cfg, batch, dtype)
        ssm_states = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            s_one)
    return caches, ssm_states


def lm_prefill(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, *,
               policy=None, rules=None, max_seq: Optional[int] = None,
               last_pos=None, segments=None):
    """Process the full prompt; returns (last_logits (B,V), caches, ssm_states).
    Cache buffers sized to max_seq (defaults to prompt length).

    ``last_pos`` selects which position's logits are returned: None (default)
    takes the final row; a scalar or per-row (B,) index supports right-padded
    prompts (the serving engine pads prompts to bucketed lengths -- causal
    masking makes the pad tail invisible to positions <= last_pos); an
    (M, 2) array of ``(row, col)`` pairs gathers one hidden vector per packed
    prompt (returns (M, V) logits, M independent of B).  Indices are into the
    full hidden sequence (VLM callers account for patch rows).

    ``segments`` (B, S) int32 packs multiple prompts into one row: equal ids
    mark one prompt's span, -1 marks padding.  Positions restart at each
    segment start and the attention mask is ``same-segment AND causal``, so
    every packed prompt computes exactly what it would alone (pad/binary
    neighbours contribute exact zeros through the softmax) -- the chunked-
    prefill idiom (MaxText ``prefill_concat`` segment-id masks).  Decoder-
    only attention families; requires ``segments`` spans to be contiguous."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    if segments is not None and (cfg.family not in ("dense", "moe")
                                 or (cfg.family == "vlm")):
        raise NotImplementedError(
            "packed (segment-id) prefill is attention-family only")
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(dtype)
        patches = policy.linear(LinearCtx("patch_proj"), patches,
                                params["patch_proj"])
        p = patches.shape[1]
        s = p + tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        e = embed_tokens(params, tokens, cfg,
                         positions=positions[:, p:], dtype=dtype,
                         policy=policy)
        h = jnp.concatenate([patches, e], axis=1)
        max_seq = max_seq or s
        mask_full = {"kind": "prefix", "prefix": p}
    else:
        s = tokens.shape[1]
        max_seq = max_seq or s
        if segments is not None:
            seg = jnp.asarray(segments, jnp.int32)
            t = jnp.arange(s)
            is_start = jnp.concatenate(
                [jnp.ones((b, 1), bool), seg[:, 1:] != seg[:, :-1]], axis=1)
            starts = jax.lax.cummax(
                jnp.where(is_start, t[None, :], 0), axis=1)
            positions = t[None, :] - starts          # restart per segment
            segk = jnp.pad(seg, ((0, 0), (0, max_seq - s)),
                           constant_values=-1)
            mask_full = ((seg[:, :, None] == segk[:, None, :])
                         & (t[:, None] >= jnp.arange(max_seq)[None, :])[None]
                         & (seg >= 0)[:, :, None])   # (B, S, max_seq)
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
            mask_full = {"kind": "causal"}
        h = embed_tokens(params, tokens, cfg, positions=positions, dtype=dtype,
                         policy=policy)
    h = constrain(h, rules, "batch", "seq", None)

    caches, ssm_states = init_caches(cfg, b, max_seq, dtype,
                                     kv_spec=policy.kv_spec())
    mask = None
    if cfg.family != "ssm":
        mask = mask_full
    h, caches, ssm_states, _, _ = run_stack(
        params, h, cfg, policy=policy, rules=rules, positions=positions,
        mask=mask, caches=caches, cache_offset=0, ssm_states=ssm_states,
        emb0=h)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    if last_pos is None:
        hc = h[:, -1:, :]
    else:
        lp = jnp.asarray(last_pos, jnp.int32)
        if lp.ndim == 0:
            hc = jax.lax.dynamic_slice_in_dim(h, lp, 1, axis=1)
        elif lp.ndim == 2:                       # (M, 2) packed (row, col)
            hc = h[lp[:, 0], lp[:, 1]][:, None, :]
        else:                                    # (B,) per-row last indices
            hc = h[jnp.arange(b)[:, None], lp[:, None], :]
    logits = logits_chunk(params, hc, cfg, policy)[:, 0, :]
    return logits, caches, ssm_states


def lm_decode(params, caches, ssm_states, token: jnp.ndarray,
              pos: jnp.ndarray, cfg: ArchConfig, *, policy=None, rules=None,
              page_table=None):
    """One-token decode.  token: (B,1) int32; pos: the number of tokens
    already in the cache -- a scalar int32 (uniform batch, the legacy path)
    or a (B,) vector of per-slot positions (continuous batching: each slot
    writes its cache row and masks its history independently).
    ``page_table`` (B, max_pages) switches the cache interpretation to paged
    pools (L, n_pages, page_size, K, hd) shared across slots (repro.infer.
    pages); the logical row space is then ``max_pages * page_size`` long.
    Returns (logits (B,V), caches, ssm_states)."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        positions = pos[:, None]                            # (B, 1)
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    h = embed_tokens(params, token, cfg, positions=positions, dtype=dtype,
                     policy=policy)

    mask = None
    if cfg.family != "ssm":
        leaf = jax.tree_util.tree_leaves(caches)[0]
        if page_table is not None:             # (L, P, page, K, hd) pools
            kv_len = page_table.shape[1] * leaf.shape[2]
        else:
            kv_len = leaf.shape[2]             # (L, B, S, K, hd)
        if pos.ndim == 1:                                   # (B, 1, kv_len)
            mask = (jnp.arange(kv_len)[None, None, :]
                    <= pos[:, None, None])
        else:
            mask = (jnp.arange(kv_len) <= pos)[None, :]     # (1, kv_len)
    h, caches, ssm_states, _, _ = run_stack(
        params, h, cfg, policy=policy, rules=rules, positions=positions,
        mask=mask, caches=caches, cache_offset=pos, ssm_states=ssm_states,
        decode=True, emb0=h, page_table=page_table)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = logits_chunk(params, h, cfg, policy)[:, 0, :]
    return logits, caches, ssm_states
