"""Multi-head attention with GQA/MQA, RoPE, qk-norm, KV cache, cross-attn.

All weight-bearing projections route through the layer-aware
``QuantPolicy.linear`` dispatch (roles ``attn_qkv`` / ``attn_out``); the
score/context einsums are not linear layers and stay in carrier precision.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.core.qconfig import Granularity
from repro.core.qpolicy import LinearCtx, as_policy
from repro.core.quantizer import (compute_scale_zero, quantize_int,
                                  storage_dtype)
from repro.models.common import ParamSpec, constrain, rmsnorm, rope


def attn_spec(cfg, d_in: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = d_in if d_in is not None else cfg.d_model
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads"), "fan_in"),
        "wk": ParamSpec((d, k * hd), ("embed", "kv"), "fan_in"),
        "wv": ParamSpec((d, k * hd), ("embed", "kv"), "fan_in"),
        "wo": ParamSpec((h * hd, d), ("heads", "embed"), "fan_in",
                        scale=1.0 / max(cfg.n_layers, 1)),
    }
    if cfg.use_bias:
        spec.update({
            "bq": ParamSpec((h * hd,), ("heads",), "zeros"),
            "bk": ParamSpec((k * hd,), ("kv",), "zeros"),
            "bv": ParamSpec((k * hd,), ("kv",), "zeros"),
            "bo": ParamSpec((d,), ("embed",), "zeros"),
        })
    if cfg.qk_norm:
        spec.update({
            "q_norm": ParamSpec((hd,), (None,), "ones"),
            "k_norm": ParamSpec((hd,), (None,), "ones"),
        })
    return spec


def init_cache(cfg, batch: int, max_seq: int, dtype, d_in: Optional[int] = None,
               kv_spec=None) -> Dict[str, jnp.ndarray]:
    """KV cache buffers for one layer.  ``kv_spec`` (a symmetric QuantSpec,
    from ``policy.kv_spec()``) switches storage to integer payloads plus fp32
    per-(position, head) scale sidecars -- the resident cache is ~1/2 (bf16)
    to ~1/4 (fp32) the size, consumed directly by the fused attention kernels
    where supported (kernels/decode_attn.py) and dequantized on read
    otherwise."""
    k, hd = cfg.n_kv_heads, cfg.head_dim
    if kv_spec is not None:
        qdt = storage_dtype(kv_spec.bits)
        return {
            "k": jnp.zeros((batch, max_seq, k, hd), qdt),
            "v": jnp.zeros((batch, max_seq, k, hd), qdt),
            "k_scale": jnp.zeros((batch, max_seq, k, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, max_seq, k, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_seq, k, hd), dtype),
        "v": jnp.zeros((batch, max_seq, k, hd), dtype),
    }


def _kv_guard(scale: jnp.ndarray) -> jnp.ndarray:
    """Scale sidecars of never-written cache rows are 0 (buffers init to
    zeros; every *written* row's scale is > 0 via the ``maximum(absmax, eps)``
    guard in ``compute_scale_zero``).  Guard 0 -> 1.0 before any dequant /
    reciprocal so padding rows cannot emit NaN/Inf -- the payloads there are
    0, so the dequantized value stays exactly 0.  Mirrors
    ``kernels.int8_matmul.scale_guard`` (kept local: the reference path must
    not pull in pallas imports)."""
    return jnp.where(scale == 0.0, 1.0, scale)


def _kv_quant(t: jnp.ndarray, spec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize new K/V rows (B, s, K, hd) for cache storage.  Per-token
    specs give one scale per (batch, position, head); per-tensor specs give
    one scale per *slot's* write block (never reducing over the batch axis:
    a request's stored precision must not depend on its batch neighbours)."""
    if spec.granularity is Granularity.PER_TENSOR:
        xf = t.astype(jnp.float32)
        scale, _ = compute_scale_zero(xf, spec, axes=(1, 2, 3))  # (B,1,1,1)
        q = jnp.clip(jnp.round(xf / scale), spec.qmin,
                     spec.qmax).astype(storage_dtype(spec.bits))
    else:
        q, scale, _ = quantize_int(t, spec)
    scale = jnp.broadcast_to(scale.astype(jnp.float32), t.shape[:-1] + (1,))
    return q, scale


def _cache_update(buf: jnp.ndarray, rows: jnp.ndarray,
                  offset) -> jnp.ndarray:
    """Write ``rows`` (B, s, ...) into ``buf`` (B, S_max, ...) at ``offset``:
    a scalar (all rows at one position -- the uniform-batch path) or a (B,)
    vector of per-slot positions (continuous batching)."""
    off = jnp.asarray(offset)
    if off.ndim == 0:
        return jax.lax.dynamic_update_slice(
            buf, rows, (0, off) + (0,) * (buf.ndim - 2))
    def one(b, r, o):
        return jax.lax.dynamic_update_slice(b, r, (o,) + (0,) * (b.ndim - 1))
    return jax.vmap(one)(buf, rows, off)


MAX_DENSE_Q = 1024        # q-chunk length for the memory-bounded path


def _mask_chunk(mask, qpos: jnp.ndarray, s_kv: int) -> Optional[jnp.ndarray]:
    """Materialize a (len(qpos), s_kv) boolean mask for one query chunk.
    ``mask`` is None (full), a dict spec, or a ready (Sq, Skv) array."""
    if mask is None:
        return None
    if isinstance(mask, dict):
        kpos = jnp.arange(s_kv)
        kind = mask["kind"]
        if kind == "causal":
            return kpos[None, :] <= qpos[:, None]
        if kind == "prefix":
            p = mask["prefix"]
            base = kpos[None, :] <= qpos[:, None]
            return base | ((qpos[:, None] < p) & (kpos[None, :] < p))
        if kind == "full":
            return None
        raise ValueError(kind)
    return mask


def _attend_block(qg, k, v, mask_b) -> jnp.ndarray:
    """qg: (B,qc,K,G,hd); k,v: (B,Skv,K,hd); mask_b: (qc,Skv) or None.

    Keeps XLA's native softmax pattern: a hand-rolled "minimal-pass" variant
    (bf16 probs, normalization on the context) was tried and REFUTED -- it
    added an fp32 exp slab before the cast and broke XLA's softmax fusion
    (memory term 5.65s -> 6.28s; see EXPERIMENTS.md Section Perf iter 2)."""
    hd = qg.shape[-1]
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if mask_b is not None:
        if mask_b.ndim == 2:                 # (Sq, Skv) shared across batch
            mask_b = mask_b[None, None, None]
        elif mask_b.ndim == 3:               # (B, Sq, Skv) per-slot masks
            mask_b = mask_b[:, None, None]
        scores = jnp.where(mask_b, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)


def _pick_chunk(sq: int, skv: int, b: int, h: int, rules,
                budget_bytes: float = 768e6) -> int:
    """Largest power-of-two q-chunk (<= MAX_DENSE_Q, dividing sq) whose fp32
    score slab (b_loc, h_loc, chunk, skv) stays under the budget."""
    dp = rules.dp_size if rules is not None else 1
    tp = rules.tp_size if rules is not None else 1
    b_loc = max(b // max(dp, 1), 1)
    h_loc = h // tp if h % tp == 0 else h
    chunk = MAX_DENSE_Q
    while chunk > 128 and b_loc * h_loc * chunk * skv * 4 > budget_bytes:
        chunk //= 2
    while sq % chunk:
        chunk //= 2
    return max(chunk, 1)


def _fused_kv_ok(policy, rules, kv_source,
                 n_kv_heads: Optional[int] = None) -> bool:
    """Static gate for the int8-KV attention kernels (fused decode + q8
    prefill): self-attention, a registered backend whose kernels consume the
    stored spec directly, and the ``REPRO_FUSED_DECODE`` switch (default:
    TPU only -- interpret mode keeps the bit-compared dequantize-on-read
    path as the oracle).

    Under sharding rules the gate is decode-only: callers pass
    ``n_kv_heads`` and the kernels run per-shard via ``shard_map`` over the
    kv-head axis when the head count divides the mesh
    (:func:`~repro.kernels.decode_attn.spmd_head_shardable`); otherwise --
    and always for the q8 *prefill* kernel, which is not shard_mapped --
    SPMD keeps the XLA gather/reference path."""
    if kv_source is not None:
        return False
    if rules is not None:
        from repro.kernels.decode_attn import spmd_head_shardable
        if n_kv_heads is None or not spmd_head_shardable(n_kv_heads, rules):
            return False
    from repro.kernels.decode_attn import fused_decode_enabled
    if not fused_decode_enabled():
        return False
    name, _ = policy.decode_attn_backend()
    return name == "int8_pallas"


def _flash_path_ok(impl: str, sq: int, mask) -> bool:
    if impl != "flash_pallas" or sq == 1:
        return False
    return mask is None or (isinstance(mask, dict)
                            and mask["kind"] in ("causal", "full"))


def _gqa_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                mask, rules, q_offset=0, impl: str = "xla") -> jnp.ndarray:
    """q: (B,Sq,H,hd); k,v: (B,Skv,K,hd).  Softmax in fp32.

    Training/prefill (Sq > 1): kv heads are repeated to the full head count
    so the head dim shards cleanly on the tensor axis (GQA group dims like
    8x4 cannot map onto a 16-way mesh axis), and the computation runs
    query-chunked: the (Sq,Skv) score matrix never materializes -- only a
    (chunk,Skv) slab per scan step, with per-chunk masks synthesized from the
    mask spec (flash-attention memory behaviour, XLA-native).

    Decode (Sq == 1): grouped-query form so the KV cache is NOT inflated."""
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads

    if sq == 1:
        qg = q.reshape(b, sq, kheads, g, hd)
        qpos = jnp.arange(sq) + q_offset
        ctx = _attend_block(qg, k, v, _mask_chunk(mask, qpos, k.shape[1]))
        return ctx.reshape(b, sq, h * hd)

    if g > 1:
        # pre-repeat boundary: gather seq / settle kv sharding BEFORE the
        # broadcast-reshape, else SPMD back-propagates the post-repeat head
        # sharding into half-head splits (involuntary full rematerialization)
        k = constrain(k, rules, "batch", None, "kv", None)
        v = constrain(v, rules, "batch", None, "kv", None)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # Megatron-SP boundary: gather sequence, shard heads (clean bwd since
    # GQA group dims like 8x4 cannot map onto a 16-way axis, but the repeated
    # h-dim can).
    k = constrain(k, rules, "batch", None, "heads", None)
    v = constrain(v, rules, "batch", None, "heads", None)
    qg = constrain(q, rules, "batch", None, "heads", None
                   ).reshape(b, sq, h, 1, hd)

    if _flash_path_ok(impl, sq, mask) and rules is None:
        # Pallas flash attention: VMEM-resident online softmax (fwd+bwd
        # kernels, kernels/flash_attn.py).  Single-device/TPU path; under
        # pjit the XLA q-chunked path below is used (interpret-mode pallas
        # does not partition).
        from repro.kernels.flash_attn import flash_attention
        qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
        kt = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], hd)
        vt = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], hd)
        causal = mask is not None and mask.get("kind") == "causal"
        ot = flash_attention(qt, kt, vt, causal, q_offset)
        return ot.reshape(b, h, sq, hd).transpose(0, 2, 1, 3).reshape(
            b, sq, h * hd)

    chunk = _pick_chunk(sq, k.shape[1], b, h, rules)
    if not (mask is None or isinstance(mask, dict)):
        # materialized (B, Sq, Skv) masks (packed-prefill segment masks)
        # cannot be re-sliced per chunk -- run the direct path
        chunk = sq
    if sq <= chunk:
        qpos = jnp.arange(sq) + q_offset
        ctx = _attend_block(qg, k, v, _mask_chunk(mask, qpos, k.shape[1]))
        return ctx.reshape(b, sq, h * hd)

    n_chunks = sq // chunk

    def body(_, xs):
        qc, i = xs
        qpos = jnp.arange(chunk) + i * chunk + q_offset
        mb = _mask_chunk(mask, qpos, k.shape[1])
        return None, _attend_block(qc, k, v, mb)

    # checkpoint: the chunk scan's backward recomputes scores/probs from
    # (qc, k, v) instead of saving a probs slab per chunk
    body = jax.checkpoint(body, prevent_cse=False)
    q_chunks = jnp.moveaxis(qg.reshape(b, n_chunks, chunk, h, 1, hd), 1, 0)
    _, chunks = jax.lax.scan(body, None, (q_chunks, jnp.arange(n_chunks)))
    # chunks: (n_chunks, B, chunk, H, 1, hd) -> (B, Sq, H*hd)
    ctx = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, h, 1, hd)
    return ctx.reshape(b, sq, h * hd)


def _paged_decode(q, k, v, cache, page_table, pos_vec, cfg, *,
                  policy, rules, kv_source, out_dtype):
    """One decode step on *paged* KV pools (``repro.infer.pages``).

    cache leaves are page pools ``(P, page, K, hd)`` shared by every slot;
    ``page_table`` (B, maxp) maps each slot's logical pages to physical ones.
    int8 pools with a supported backend run the fused paged kernel (page-
    routed DMA, in-register dequant, fused row quantize+scatter); otherwise
    the bit-compared gather reference: scatter the new row at
    ``(table[pos//page], pos%page)``, gather the slot's logical view, and
    return fp K/V for the shared masked-softmax path.

    Returns ``(ctx_or_None, k_full, v_full, new_cache)`` -- ``ctx`` is set
    only on the fused path."""
    b = q.shape[0]
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    page = cache["k"].shape[1]
    maxp = page_table.shape[1]
    quantized = "k_scale" in cache
    if quantized and _fused_kv_ok(policy, rules, kv_source, n_kv_heads=kh):
        from repro.kernels.decode_attn import (decode_attention_paged,
                                               decode_attention_paged_spmd)
        kv_spec = policy.kv_spec()
        qg = q[:, 0].reshape(b, kh, h // kh, hd)
        if rules is not None:
            ctx, nkq, nks, nvq, nvs = decode_attention_paged_spmd(
                qg, cache["k"], cache["k_scale"],
                cache["v"], cache["v_scale"],
                k[:, 0], v[:, 0], pos_vec, page_table,
                mesh=rules.mesh, kv_axis=rules.axis_map["kv"][0],
                qmin=kv_spec.qmin, qmax=kv_spec.qmax)
        else:
            ctx, nkq, nks, nvq, nvs = decode_attention_paged(
                qg, cache["k"], cache["k_scale"],
                cache["v"], cache["v_scale"],
                k[:, 0], v[:, 0], pos_vec, page_table,
                qmin=kv_spec.qmin, qmax=kv_spec.qmax)
        new_cache = {"k": nkq, "v": nvq, "k_scale": nks, "v_scale": nvs}
        return ctx.reshape(b, 1, h * hd), None, None, new_cache
    # gather reference: same values at the same logical rows as the dense
    # reference path, so tokens stay bitwise identical to a dense engine
    pc = jnp.minimum(pos_vec, maxp * page - 1)
    pid = page_table[jnp.arange(b), pc // page]
    row = pc % page
    if quantized:
        kv_spec = policy.kv_spec()
        kqn, ksn = _kv_quant(k, kv_spec)
        vqn, vsn = _kv_quant(v, kv_spec)
        new_cache = {
            "k": cache["k"].at[pid, row].set(kqn[:, 0]),
            "v": cache["v"].at[pid, row].set(vqn[:, 0]),
            "k_scale": cache["k_scale"].at[pid, row].set(ksn[:, 0]),
            "v_scale": cache["v_scale"].at[pid, row].set(vsn[:, 0]),
        }
        kf = (new_cache["k"][page_table].astype(jnp.float32)
              * _kv_guard(new_cache["k_scale"][page_table]))
        vf = (new_cache["v"][page_table].astype(jnp.float32)
              * _kv_guard(new_cache["v_scale"][page_table]))
    else:
        new_cache = {
            "k": cache["k"].at[pid, row].set(k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[pid, row].set(v[:, 0].astype(cache["v"].dtype)),
        }
        kf, vf = new_cache["k"][page_table], new_cache["v"][page_table]
    kf = kf.reshape(b, maxp * page, kh, hd).astype(out_dtype)
    vf = vf.reshape(b, maxp * page, kh, hd).astype(out_dtype)
    return None, kf, vf, new_cache


def attn_apply(params, x: jnp.ndarray, cfg, *,
               policy=None, rules=None,
               positions: jnp.ndarray,
               mask: Optional[jnp.ndarray],
               kv_source: Optional[jnp.ndarray] = None,
               cache: Optional[Dict[str, jnp.ndarray]] = None,
               cache_offset=None,
               page_table: Optional[jnp.ndarray] = None,
               layer=None, n_layers: int = 0,
               ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One attention call.

    * self-attention:  kv_source is None -> k/v from x, RoPE applied.
    * cross-attention: kv_source is the encoder output; no RoPE on k.
    * decode:          cache holds (B, S_max, K, hd); the new k/v rows are
      written at ``cache_offset`` and attention runs over the whole buffer
      with a validity mask supplied by the caller.

    ``policy`` is anything ``as_policy`` accepts (None / QuantRecipe /
    QuantPolicy); ``layer`` may be a traced index from the layer scan.
    """
    policy = as_policy(policy)
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ctx_qkv = LinearCtx("attn_qkv", layer, n_layers)
    ctx_out = LinearCtx("attn_out", layer, n_layers)

    q = policy.linear(ctx_qkv, x, params["wq"], params.get("bq")
                      ).reshape(b, s, h, hd)
    src = x if kv_source is None else kv_source
    k = policy.linear(ctx_qkv, src, params["wk"], params.get("bk"))
    v = policy.linear(ctx_qkv, src, params["wv"], params.get("bv"))
    k = k.reshape(b, k.shape[1], kh, hd)
    v = v.reshape(b, v.shape[1], kh, hd)

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])

    if cfg.pos == "rope" and kv_source is None:
        q = rope(q, positions, cfg.rope_theta)
        # k rows carry the same per-token positions as q: in decode the
        # caller's ``positions`` already equals the write offset, and under
        # packed (segment-id) prefill each segment restarts from 0 -- the
        # offset-derived arange the cache path used before is only correct
        # for single-segment rows
        k = rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    ctx = None
    if cache is not None and page_table is not None:
        # paged decode (s == 1 only: prefill fills dense buffers that the
        # engine pages in afterwards)
        pos_vec = jnp.broadcast_to(
            jnp.asarray(cache_offset, jnp.int32).reshape(-1), (b,))
        ctx, kf, vf, new_cache = _paged_decode(
            q, k, v, cache, page_table, pos_vec, cfg, policy=policy,
            rules=rules, kv_source=kv_source, out_dtype=x.dtype)
        if ctx is None:
            k, v = kf, vf
    elif cache is not None:
        # decode / incremental: write rows at cache_offset (scalar, or (B,)
        # per-slot offsets under continuous batching), attend over buffer
        if "k_scale" in cache:
            # int8 KV storage (role ``kv_cache``): payload + per-(position,
            # head) scale sidecars.  Capability dispatch: when a backend's
            # attention kernels consume the stored form directly, decode runs
            # the fused quantize+scatter+attend launch and prefill the
            # dequant-prologue flash kernel; otherwise (the bit-compared
            # oracle) quantize the new rows here and dequantize the whole
            # buffer for the attention read.
            kv_spec = policy.kv_spec()
            fused_dec = _fused_kv_ok(policy, rules, kv_source, n_kv_heads=kh)
            fused_pre = _fused_kv_ok(policy, rules, kv_source)
            if fused_dec and s == 1:
                # fused decode: one read of the int8 cache, one int8 row
                # write; the kernel quantizes and scatters this step's rows
                # (decode contract: ``cache_offset`` IS the per-slot count of
                # valid prior rows, matching the caller's validity mask)
                from repro.kernels.decode_attn import (decode_attention,
                                                       decode_attention_spmd)
                pos = jnp.broadcast_to(
                    jnp.asarray(cache_offset, jnp.int32).reshape(-1), (b,))
                qg = q[:, 0].reshape(b, kh, h // kh, hd)
                if rules is not None:
                    ctx, nkq, nks, nvq, nvs = decode_attention_spmd(
                        qg, cache["k"], cache["k_scale"],
                        cache["v"], cache["v_scale"],
                        k[:, 0], v[:, 0], pos,
                        mesh=rules.mesh, kv_axis=rules.axis_map["kv"][0],
                        qmin=kv_spec.qmin, qmax=kv_spec.qmax)
                else:
                    ctx, nkq, nks, nvq, nvs = decode_attention(
                        qg, cache["k"], cache["k_scale"],
                        cache["v"], cache["v_scale"],
                        k[:, 0], v[:, 0], pos,
                        qmin=kv_spec.qmin, qmax=kv_spec.qmax)
                new_cache = {"k": nkq, "v": nvq,
                             "k_scale": nks, "v_scale": nvs}
                ctx = ctx.reshape(b, 1, h * hd)
            else:
                kq, ks = _kv_quant(k, kv_spec)
                vq, vs = _kv_quant(v, kv_spec)
                new_cache = {
                    "k": _cache_update(cache["k"], kq, cache_offset),
                    "v": _cache_update(cache["v"], vq, cache_offset),
                    "k_scale": _cache_update(cache["k_scale"], ks,
                                             cache_offset),
                    "v_scale": _cache_update(cache["v_scale"], vs,
                                             cache_offset),
                }
                if (fused_pre and s > 1 and isinstance(mask, dict)
                        and mask["kind"] == "causal"
                        and isinstance(cache_offset, int)):
                    # int8-KV prefill: flash forward with a dequant prologue
                    # on the stored payloads -- no fp K/V copy of the
                    # max_seq-sized buffer; causal masking hides the
                    # never-written tail (kernels/flash_attn.py)
                    from repro.kernels.flash_attn import flash_attention_fwd_q8
                    ctx = flash_attention_fwd_q8(
                        q, new_cache["k"], new_cache["k_scale"],
                        new_cache["v"], new_cache["v_scale"],
                        causal=True, q_offset=cache_offset)
                    ctx = ctx.reshape(b, s, h * hd)
                else:
                    k = (new_cache["k"].astype(jnp.float32)
                         * _kv_guard(new_cache["k_scale"])).astype(x.dtype)
                    v = (new_cache["v"].astype(jnp.float32)
                         * _kv_guard(new_cache["v_scale"])).astype(x.dtype)
        else:
            ck = _cache_update(cache["k"], k.astype(cache["k"].dtype),
                               cache_offset)
            cv = _cache_update(cache["v"], v.astype(cache["v"].dtype),
                               cache_offset)
            ck = constrain(ck, rules, "batch", "kv_seq", "kv", None)
            cv = constrain(cv, rules, "batch", "kv_seq", "kv", None)
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv

    if ctx is None:
        ctx = _gqa_attend(q, k, v, mask, rules,
                          impl=getattr(cfg, "attention_impl", "xla"))
    # named for the remat policy: saving ctx prunes one full score-chain
    # recompute from the backward (EXPERIMENTS.md Section Perf iter 4)
    ctx = checkpoint_name(ctx, "attn_ctx")
    y = policy.linear(ctx_out, ctx, params["wo"], params.get("bo"))
    return y, new_cache
