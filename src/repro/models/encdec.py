"""Encoder-decoder LM (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d) which pass through a small adapter
linear.  Encoder: bidirectional self-attn blocks.  Decoder: causal self-attn +
cross-attn + MLP.  Decode caches self-attn KV incrementally and cross-attn KV
once at prefill.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qpolicy import LinearCtx, as_policy
from repro.models.attention import attn_apply, attn_spec, init_cache
from repro.models.common import (ParamSpec, apply_norm, cast_params,
                                 causal_mask, constrain, norm_spec,
                                 stack_layer_specs)
from repro.models.lm import chunked_ce, embed_tokens, logits_chunk
from repro.models.mlp import mlp_apply, mlp_spec


def _enc_block_spec(cfg):
    return {
        "ln1": norm_spec(cfg.d_model, cfg.norm),
        "attn": attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model, cfg.norm),
        "mlp": mlp_spec(cfg),
    }


def _dec_block_spec(cfg):
    return {
        "ln1": norm_spec(cfg.d_model, cfg.norm),
        "self_attn": attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model, cfg.norm),
        "cross_attn": attn_spec(cfg),
        "ln3": norm_spec(cfg.d_model, cfg.norm),
        "mlp": mlp_spec(cfg),
    }


def encdec_spec(cfg) -> Dict:
    spec = {
        "frame_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                ("embed2", "embed"), "fan_in"),
        "enc_blocks": stack_layer_specs(_enc_block_spec(cfg), cfg.enc_layers),
        "enc_norm": norm_spec(cfg.d_model, cfg.norm),
        "embed": ParamSpec((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                           "normal", 0.02),
        "dec_blocks": stack_layer_specs(_dec_block_spec(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                    ("embed", "vocab"), "fan_in")
    return spec


def encode(params, frames: jnp.ndarray, cfg, *, policy=None, rules=None
           ) -> jnp.ndarray:
    """Bidirectional encoder.  Depth-indexed policy rules address encoder
    blocks by their position within the encoder stack."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    h = policy.linear(LinearCtx("frame_proj"), frames.astype(dtype),
                      params["frame_proj"])
    h = constrain(h, rules, "batch", "seq", None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    nl = cfg.enc_layers

    def body(hh, xs):
        bp, li = xs
        x = apply_norm(hh, bp["ln1"], cfg.norm)
        y, _ = attn_apply(bp["attn"], x, cfg, policy=policy, rules=rules,
                          positions=positions, mask=None,    # bidirectional
                          layer=li, n_layers=nl)
        hh = hh + y
        x = apply_norm(hh, bp["ln2"], cfg.norm)
        hh = hh + mlp_apply(bp["mlp"], x, cfg, policy=policy, rules=rules,
                            layer=li, n_layers=nl)
        hh = constrain(hh, rules, "batch", "seq", None)
        return hh, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, (params["enc_blocks"],
                                  jnp.arange(nl, dtype=jnp.int32)))
    return apply_norm(h, params["enc_norm"], cfg.norm)


def _dec_block(bp, h, enc_out, cfg, *, policy, rules, positions, mask,
               cache=None, cache_offset=None, cross_kv=None,
               layer=None):
    """cross_kv: precomputed {"k","v"} (B,S_enc,K,hd) or None (compute).
    Cross-attention projections share the attn_qkv/attn_out roles."""
    nl = cfg.n_layers
    x = apply_norm(h, bp["ln1"], cfg.norm)
    y, ncache = attn_apply(bp["self_attn"], x, cfg, policy=policy,
                           rules=rules, positions=positions, mask=mask,
                           cache=cache, cache_offset=cache_offset,
                           layer=layer, n_layers=nl)
    h = h + y
    x = apply_norm(h, bp["ln2"], cfg.norm)
    if cross_kv is not None:
        from repro.models.attention import _gqa_attend
        b, sq = x.shape[0], x.shape[1]
        hd = cfg.head_dim
        q = policy.linear(LinearCtx("attn_qkv", layer, nl), x,
                          bp["cross_attn"]["wq"], bp["cross_attn"].get("bq")
                          ).reshape(b, sq, cfg.n_heads, hd)
        ctx = _gqa_attend(q, cross_kv["k"], cross_kv["v"], None, rules)
        y = policy.linear(LinearCtx("attn_out", layer, nl), ctx,
                          bp["cross_attn"]["wo"], bp["cross_attn"].get("bo"))
    else:
        y, _ = attn_apply(bp["cross_attn"], x, cfg, policy=policy,
                          rules=rules, positions=positions, mask=None,
                          kv_source=enc_out, layer=layer, n_layers=nl)
    h = h + y
    x = apply_norm(h, bp["ln3"], cfg.norm)
    h = h + mlp_apply(bp["mlp"], x, cfg, policy=policy, rules=rules,
                      layer=layer, n_layers=nl)
    return constrain(h, rules, "batch", "seq", None), ncache


def encdec_loss(params, batch, cfg, *, policy=None, rules=None, rng=None
                ) -> Tuple[jnp.ndarray, Dict]:
    """batch: {"frames": (B,S_enc,d), "tokens": (B,S_dec+1)}."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    enc_out = encode(params, batch["frames"], cfg, policy=policy, rules=rules)
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    b, s = inp.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = embed_tokens(params, inp, cfg, positions=positions, dtype=dtype,
                     policy=policy)
    mask = {"kind": "causal"}

    def body(hh, xs):
        bp, li = xs
        hh, _ = _dec_block(bp, hh, enc_out, cfg, policy=policy, rules=rules,
                           positions=positions, mask=mask, layer=li)
        return hh, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, (params["dec_blocks"],
                                  jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    h = apply_norm(h, params["final_norm"], cfg.norm)
    ce = chunked_ce(params, h, labels, batch.get("loss_mask"), cfg, rules,
                    policy)
    return ce, {"ce": ce, "loss": ce}


def encdec_prefill(params, batch, cfg, *, policy=None, rules=None,
                   max_seq: Optional[int] = None):
    """Encode frames, precompute cross KV per layer, run the decoder prompt.
    Returns (last_logits, cache) with cache = {"self": stacked kv,
    "cross": stacked kv}."""
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    enc_out = encode(params, batch["frames"], cfg, policy=policy, rules=rules)
    b, s_enc, _ = enc_out.shape
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    nl = cfg.n_layers

    def cross_kv_one(xs):
        bp, li = xs
        ctx = LinearCtx("attn_qkv", li, nl)
        k = policy.linear(ctx, enc_out, bp["cross_attn"]["wk"],
                          bp["cross_attn"].get("bk")).reshape(b, s_enc, kh, hd)
        v = policy.linear(ctx, enc_out, bp["cross_attn"]["wv"],
                          bp["cross_attn"].get("bv")).reshape(b, s_enc, kh, hd)
        return {"k": k, "v": v}

    layer_ids = jnp.arange(nl, dtype=jnp.int32)
    cross = jax.lax.map(cross_kv_one, (params["dec_blocks"], layer_ids))

    tokens = batch["tokens"]
    s = tokens.shape[1]
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = embed_tokens(params, tokens, cfg, positions=positions, dtype=dtype,
                     policy=policy)
    mask = {"kind": "causal"}
    self_cache0 = init_cache(cfg, b, max_seq, dtype)

    def body(hh, xs):
        bp, ckv, li = xs
        cache = {"k": jnp.zeros_like(self_cache0["k"]),
                 "v": jnp.zeros_like(self_cache0["v"])}
        hh, ncache = _dec_block(bp, hh, None, cfg, policy=policy, rules=rules,
                                positions=positions, mask=mask, cache=cache,
                                cache_offset=0, cross_kv=ckv, layer=li)
        return hh, ncache

    h, self_caches = jax.lax.scan(body, h, (params["dec_blocks"], cross,
                                            layer_ids))
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = logits_chunk(params, h[:, -1:, :], cfg, policy)[:, 0, :]
    return logits, {"self": self_caches, "cross": cross}


def encdec_decode(params, cache, token: jnp.ndarray, pos: jnp.ndarray, cfg, *,
                  policy=None, rules=None):
    policy = as_policy(policy)
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    b = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    h = embed_tokens(params, token, cfg, positions=positions, dtype=dtype,
                     policy=policy)
    max_seq = cache["self"]["k"].shape[2]
    mask = (jnp.arange(max_seq) <= pos)[None, :]

    def body(hh, xs):
        bp, sc, ckv, li = xs
        hh, ncache = _dec_block(bp, hh, None, cfg, policy=policy, rules=rules,
                                positions=positions, mask=mask, cache=sc,
                                cache_offset=pos, cross_kv=ckv, layer=li)
        return hh, ncache

    h, self_caches = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["self"], cache["cross"],
                  jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = logits_chunk(params, h, cfg, policy)[:, 0, :]
    return logits, {"self": self_caches, "cross": cache["cross"]}
