"""Mixture-of-Experts with capacity-based token dispatch.

Three execution modes, chosen by divisibility against the tensor axis:

* ``local``       : no mesh / tp==1.  Pure scatter-dispatch on the device.
* ``ep_alltoall`` : E % tp == 0 and tokens split over tp.  Tokens are
  sharded along the tensor axis for routing, dispatched to expert-owner ranks
  with ``all_to_all``, FFN'd, and returned (GShard/Switch pattern).  Used for
  training shapes (phi3.5-moe: one expert per rank on the 16-way axis).
* ``ep_masked``   : E % tp == 0 but too few tokens to split (decode).  Every
  rank holds its experts, dispatches the full (replicated) token set against
  its local experts only, and the combine is a psum.
* ``ff_sharded``  : E does not divide tp (granite's 40 experts on a 16-way
  axis).  Expert weights are tensor-sharded on d_ff inside each expert;
  dispatch is replicated across tp and the down-projection psums partials.

Gradients flow through gate weights via the softmax (standard top-k routing);
dropped tokens (beyond capacity) fall back to the residual stream.  A
Switch-style load-balance aux loss and router z-loss are returned.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.qpolicy import LinearCtx, as_policy
from repro.parallel.compat import axis_size, shard_map
from repro.models.common import ACT_FNS, ParamSpec


def moe_spec(cfg) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "w_router": ParamSpec((d, e), ("embed", "expert"), "fan_in"),
        "w_gate": ParamSpec((e, d, ff), ("expert", "embed", "mlp"), "fan_in"),
        "w_up": ParamSpec((e, d, ff), ("expert", "embed", "mlp"), "fan_in"),
        "w_down": ParamSpec((e, ff, d), ("expert", "mlp", "embed"), "fan_in",
                            scale=1.0 / max(cfg.n_layers, 1)),
    }


def _route(x2: jnp.ndarray, w_router: jnp.ndarray, cfg, policy,
           ctx_router: LinearCtx
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router in fp32 (role ``router``; fp under from_recipe policies --
    quantizing the router is a beyond-paper ablation)."""
    logits = policy.linear(ctx_router, x2.astype(jnp.float32),
                           w_router.astype(jnp.float32))       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, top_e = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)                # renormalized
    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    sel = jax.nn.one_hot(top_e[:, 0], cfg.n_experts, dtype=jnp.float32)
    aux = cfg.n_experts * jnp.sum(jnp.mean(sel, axis=0) * jnp.mean(probs, axis=0))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, top_e, aux, z_loss


def _dispatch_indices(top_e: jnp.ndarray, n_experts: int, capacity: int,
                      k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Slot assignment with first-come-first-served capacity.

    Returns (slot (T*k,), keep (T*k,), token_idx (T*k,)); dropped pairs get
    the dummy slot n_experts*capacity.
    """
    t = top_e.shape[0]
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    onehot = (flat_e[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # (T*k, E)
    mypos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = mypos < capacity
    slot = jnp.where(keep, flat_e * capacity + mypos, n_experts * capacity)
    token_idx = jnp.repeat(jnp.arange(t), k)
    return slot, keep, token_idx


def _expert_ffn(buf: jnp.ndarray, params, cfg, policy, layer,
                n_layers: int) -> jnp.ndarray:
    """buf: (E_local, C, d) -> (E_local, C, d).  vmapped policy linears so
    per-channel/per-token scales stay per-expert."""
    act = ACT_FNS[cfg.act]
    ctx_up = LinearCtx("mlp_up", layer, n_layers)
    ctx_down = LinearCtx("mlp_down", layer, n_layers)

    def one(xb, wg, wu, wd):
        g = policy.linear(ctx_up, xb, wg)
        u = policy.linear(ctx_up, xb, wu)
        return policy.linear(ctx_down, act(g) * u, wd)

    return jax.vmap(one)(buf, params["w_gate"], params["w_up"], params["w_down"])


def _local_moe(x2: jnp.ndarray, params, cfg, policy, capacity: int,
               layer, n_layers: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Capacity dispatch + expert FFN on one device's token set.  Used both
    standalone (no mesh) and as the per-shard body of the ff_sharded mode."""
    t, d = x2.shape
    e, k = cfg.n_experts, cfg.top_k
    gates, top_e, aux, z_loss = _route(x2, params["w_router"], cfg, policy,
                                       LinearCtx("router", layer, n_layers))
    slot, keep, token_idx = _dispatch_indices(top_e, e, capacity, k)

    rows = jnp.take(x2, token_idx, axis=0)                       # (T*k, d)
    buf = jnp.zeros((e * capacity + 1, d), x2.dtype)
    buf = buf.at[slot].set(rows, mode="drop", unique_indices=True)
    h = _expert_ffn(buf[:e * capacity].reshape(e, capacity, d), params, cfg,
                    policy, layer, n_layers)
    h = h.reshape(e * capacity, -1)
    out_rows = jnp.take(jnp.concatenate(
        [h, jnp.zeros((1, h.shape[-1]), h.dtype)], axis=0), slot, axis=0)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(x2.dtype)
    y = jnp.zeros((t, h.shape[-1]), x2.dtype)
    y = y.at[token_idx].add(out_rows * w[:, None])
    return y, aux, z_loss


def _capacity(tokens: int, cfg) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(cap, cfg.top_k)


MAX_DISPATCH_TOKENS = 16384


def _local_moe_chunked(x2, params, cfg, policy, layer, n_layers):
    """Token-chunked dispatch: bounds the (E*C, d) scatter buffers at train
    shapes (capacity is per-chunk -- standard grouped dispatch semantics)."""
    t, d = x2.shape
    if t <= MAX_DISPATCH_TOKENS:
        return _local_moe(x2, params, cfg, policy, _capacity(t, cfg),
                          layer, n_layers)
    chunk = MAX_DISPATCH_TOKENS
    while t % chunk:
        chunk //= 2
    cap = _capacity(chunk, cfg)

    def body(_, xc):
        y, aux, z = _local_moe(xc, params, cfg, policy, cap, layer, n_layers)
        return None, (y, aux, z)

    body = jax.checkpoint(body, prevent_cse=False)
    xcs = x2.reshape(t // chunk, chunk, d)
    _, (ys, auxs, zs) = jax.lax.scan(body, None, xcs)
    return ys.reshape(t, d), jnp.mean(auxs), jnp.mean(zs)


def _alltoall_moe(x2, params, cfg, policy, tp_axis: str, layer, n_layers):
    """Per-shard body (tokens already split over tp_axis; expert weights
    already sharded over tp_axis): route locally, all_to_all to expert
    owners, FFN, all_to_all back, combine."""
    tp = axis_size(tp_axis)
    t_loc, d = x2.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // tp
    cap = _capacity(t_loc, cfg)

    gates, top_e, aux, z_loss = _route(x2, params["w_router"], cfg, policy,
                                       LinearCtx("router", layer, n_layers))
    slot, keep, token_idx = _dispatch_indices(top_e, e, cap, k)
    rows = jnp.take(x2, token_idx, axis=0)
    send = jnp.zeros((e * cap + 1, d), x2.dtype)
    send = send.at[slot].set(rows, mode="drop", unique_indices=True)
    send = send[:e * cap].reshape(tp, e_loc * cap, d)
    # (tp, rows, d) -> each rank receives its expert block from every source
    recv = jax.lax.all_to_all(send, tp_axis, split_axis=0, concat_axis=0,
                              tiled=False)                       # (tp, e_loc*cap, d)
    ffn_in = (recv.reshape(tp, e_loc, cap, d)
              .transpose(1, 0, 2, 3).reshape(e_loc, tp * cap, d))
    # expert weights arrive pre-sharded: (e_loc, d, ff) per rank
    h = _expert_ffn(ffn_in, params, cfg, policy, layer,
                    n_layers)                                    # (e_loc, tp*cap, d)
    back = (h.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
            .reshape(tp, e_loc * cap, d))
    got = jax.lax.all_to_all(back, tp_axis, split_axis=0, concat_axis=0,
                             tiled=False).reshape(e * cap, d)
    got = jnp.concatenate([got, jnp.zeros((1, d), got.dtype)], axis=0)
    out_rows = jnp.take(got, slot, axis=0)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(x2.dtype)
    y = jnp.zeros((t_loc, d), x2.dtype).at[token_idx].add(out_rows * w[:, None])
    return y, aux, z_loss


def moe_apply(params, x: jnp.ndarray, cfg, *,
              policy=None, rules=None, layer=None, n_layers: int = 0
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss, z_loss)."""
    policy = as_policy(policy)
    b, s, d = x.shape
    if rules is None or rules.tp_size == 1:
        y, aux, z = _local_moe_chunked(x.reshape(-1, d), params, cfg, policy,
                                       layer, n_layers)
        return y.reshape(b, s, d), aux, z

    mesh = rules.mesh
    dp_axes, tp_axis = rules.dp_axes, rules.tp_axis
    tp = rules.tp_size

    if tp_axis in dp_axes:
        # flat-FSDP mapping: every rank dispatches its own token slice
        # against the (boundary-gathered) full expert set -- no EP collective.
        # Shard the batch over the longest dp-axis prefix that divides it
        # (multi-pod: global_batch 256 < 512 chips -> the model axis ranks
        # replicate the dispatch; correct, compiles, mildly wasteful --
        # MoE archs prefer the TP/EP mapping anyway, see EXPERIMENTS §Perf).
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        use = []
        prod = 1
        for a in dp_axes:
            if b % (prod * sizes[a]) == 0:
                use.append(a)
                prod *= sizes[a]
            else:
                break
        dp_axes = tuple(use) if use else (dp_axes[0],)

        def body(xb, p):
            xl = xb.reshape(-1, d)
            y, aux, z = _local_moe_chunked(xl, p, cfg, policy, layer, n_layers)
            return y.reshape(xb.shape), aux, z

        in_specs = (P(dp_axes, None, None), {
            "w_router": P(None, None), "w_gate": P(None, None, None),
            "w_up": P(None, None, None), "w_down": P(None, None, None)})
        out_specs = (P(dp_axes, None, None), P(), P())
        y, aux, z = shard_map(body, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=False)(
            x, {k: params[k] for k in
                ("w_router", "w_gate", "w_up", "w_down")})
        return y, jnp.mean(aux), jnp.mean(z)
    tokens_dp = (b // rules.dp_size) * s       # tokens per dp shard

    if cfg.n_experts % tp == 0 and s % tp == 0:
        # --- all-to-all expert parallelism (training shapes) --------------
        def body(xb, p):
            xl = xb.reshape(-1, d)
            y, aux, z = _alltoall_moe(xl, p, cfg, policy, tp_axis, layer,
                                      n_layers)
            return (y.reshape(xb.shape),
                    jax.lax.pmean(aux, tp_axis), jax.lax.pmean(z, tp_axis))

        in_specs = (P(dp_axes, tp_axis, None), {
            "w_router": P(None, None),
            "w_gate": P(tp_axis, None, None),
            "w_up": P(tp_axis, None, None),
            "w_down": P(tp_axis, None, None),
        })
        out_specs = (P(dp_axes, tp_axis, None), P(), P())
        y, aux, z = shard_map(body, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=False)(
            x, {k: params[k] for k in
                ("w_router", "w_gate", "w_up", "w_down")})
        return y, jnp.mean(aux), jnp.mean(z)

    if cfg.n_experts % tp == 0:
        # --- masked EP (decode: tokens replicated over tp) -----------------
        e_loc = cfg.n_experts // tp
        cap = _capacity(b * s // rules.dp_size, cfg)

        def body(xb, p):
            xl = xb.reshape(-1, d)
            gates, top_e, aux, z = _route(xl, p["w_router"], cfg, policy,
                                          LinearCtx("router", layer, n_layers))
            my = jax.lax.axis_index(tp_axis)
            # keep only pairs routed to my expert block (weights arrive
            # pre-sharded: p["w_gate"] is (e_loc, d, ff) on this rank)
            rel = top_e - my * e_loc
            mine = (rel >= 0) & (rel < e_loc)
            loc_e = jnp.where(mine, rel, e_loc)     # e_loc = dummy expert
            slot, keep, token_idx = _dispatch_indices(
                loc_e, e_loc + 1, cap, cfg.top_k)
            keep = keep & mine.reshape(-1)
            slot = jnp.where(keep, slot, (e_loc + 1) * cap)
            rows = jnp.take(xl, token_idx, axis=0)
            buf = jnp.zeros(((e_loc + 1) * cap + 1, d), xl.dtype)
            buf = buf.at[slot].set(rows, mode="drop", unique_indices=True)
            h = _expert_ffn(buf[:e_loc * cap].reshape(e_loc, cap, d),
                            p, cfg, policy, layer,
                            n_layers).reshape(e_loc * cap, d)
            h = jnp.concatenate(
                [h, jnp.zeros((1 + cap, d), h.dtype)], axis=0)
            out_rows = jnp.take(h, jnp.minimum(slot, e_loc * cap + cap), axis=0)
            w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(xl.dtype)
            y = jnp.zeros((xl.shape[0], d), xl.dtype)
            y = y.at[token_idx].add(out_rows * w[:, None])
            y = jax.lax.psum(y, tp_axis)
            return (y.reshape(xb.shape), jax.lax.pmean(aux, tp_axis),
                    jax.lax.pmean(z, tp_axis))

        in_specs = (P(dp_axes, None, None), {
            "w_router": P(None, None), "w_gate": P(tp_axis, None, None),
            "w_up": P(tp_axis, None, None), "w_down": P(tp_axis, None, None)})
        out_specs = (P(dp_axes, None, None), P(), P())
        y, aux, z = shard_map(body, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=False)(
            x, {k: params[k] for k in
                ("w_router", "w_gate", "w_up", "w_down")})
        return y, jnp.mean(aux), jnp.mean(z)

    # --- ff_sharded: experts do not divide tp (granite 40e on 16) ---------

    def body(xb, p):
        xl = xb.reshape(-1, d)
        y, aux, z = _local_moe_chunked(xl, p, cfg, policy, layer, n_layers)
        y = jax.lax.psum(y, tp_axis)
        return (y.reshape(xb.shape), jax.lax.pmean(aux, tp_axis),
                jax.lax.pmean(z, tp_axis))

    in_specs = (P(dp_axes, None, None), {
        "w_router": P(None, None),
        "w_gate": P(None, None, tp_axis),
        "w_up": P(None, None, tp_axis),
        "w_down": P(None, tp_axis, None)})
    out_specs = (P(dp_axes, None, None), P(), P())
    y, aux, z = shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)(
        x, {k: params[k] for k in ("w_router", "w_gate", "w_up", "w_down")})
    return y, jnp.mean(aux), jnp.mean(z)
