"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones, all
routing their linear layers through the quantized-linear core."""
from repro.models.model_api import (Model, build_model, decode_input_specs,
                                    input_specs, prefill_batch_specs,
                                    train_batch_specs)

__all__ = ["Model", "build_model", "decode_input_specs", "input_specs",
           "prefill_batch_specs", "train_batch_specs"]
