"""Dense feed-forward variants: gated (SwiGLU/GeGLU) and classic 2-layer.

All projections go through the policy-dispatched quantized linear (roles
``mlp_up`` for the expanding projections, ``mlp_down`` for the contraction
back to the residual -- the sublayer Bondarenko et al. find range-sensitive).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.qpolicy import LinearCtx, as_policy
from repro.models.common import ACT_FNS, ParamSpec, constrain


def mlp_spec(cfg, d_in: Optional[int] = None, d_ff: Optional[int] = None
             ) -> Dict[str, ParamSpec]:
    d = d_in if d_in is not None else cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp_kind == "gated":
        spec = {
            "w_gate": ParamSpec((d, ff), ("embed", "mlp"), "fan_in"),
            "w_up": ParamSpec((d, ff), ("embed", "mlp"), "fan_in"),
            "w_down": ParamSpec((ff, d), ("mlp", "embed"), "fan_in",
                                scale=1.0 / max(cfg.n_layers, 1)),
        }
        if cfg.use_bias:
            spec.update({
                "b_gate": ParamSpec((ff,), ("mlp",), "zeros"),
                "b_up": ParamSpec((ff,), ("mlp",), "zeros"),
                "b_down": ParamSpec((d,), ("embed",), "zeros"),
            })
        return spec
    # classic: fc1 -> act -> fc2 (GPT-2)
    spec = {
        "w_fc1": ParamSpec((d, ff), ("embed", "mlp"), "fan_in"),
        "w_fc2": ParamSpec((ff, d), ("mlp", "embed"), "fan_in",
                           scale=1.0 / max(cfg.n_layers, 1)),
    }
    if cfg.use_bias:
        spec.update({
            "b_fc1": ParamSpec((ff,), ("mlp",), "zeros"),
            "b_fc2": ParamSpec((d,), ("embed",), "zeros"),
        })
    return spec


def mlp_apply(params, x: jnp.ndarray, cfg, *,
              policy=None, rules=None, layer=None,
              n_layers: int = 0) -> jnp.ndarray:
    policy = as_policy(policy)
    ctx_up = LinearCtx("mlp_up", layer, n_layers)
    ctx_down = LinearCtx("mlp_down", layer, n_layers)
    act = ACT_FNS[cfg.act]
    if cfg.mlp_kind == "gated":
        g = policy.linear(ctx_up, x, params["w_gate"], params.get("b_gate"))
        u = policy.linear(ctx_up, x, params["w_up"], params.get("b_up"))
        h = act(g) * u
        h = constrain(h, rules, "batch", None, "mlp")
        return policy.linear(ctx_down, h, params["w_down"],
                             params.get("b_down"))
    h = act(policy.linear(ctx_up, x, params["w_fc1"], params.get("b_fc1")))
    h = constrain(h, rules, "batch", None, "mlp")
    return policy.linear(ctx_down, h, params["w_fc2"], params.get("b_fc2"))
