"""Mamba2 (state-space duality) layer: chunked SSD for training/prefill and a
recurrent step for decode.

The paper's technique applies to the in/out projections (linear layers) which
route through the quantized linear; SSD scan internals (A, dt, conv, state
recurrence) run in fp32 for stability and are outside the paper's linear-layer
scope (DESIGN.md Section 5).

Reference: Dao & Gu, "Transformers are SSMs" (arXiv:2405.21060).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qpolicy import LinearCtx, as_policy
from repro.models.common import ParamSpec, constrain, rmsnorm

CHUNK = 128


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    n_state: int
    n_groups: int
    conv_width: int
    conv_dim: int


def ssm_dims(cfg) -> SSMDims:
    d_inner = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_inner // p
    g = 1
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    return SSMDims(d_inner, h, p, n, g, cfg.ssm_conv, conv_dim)


def ssm_spec(cfg) -> Dict[str, ParamSpec]:
    """Input projection split into TP-shardable segments (z / x / BC / dt):
    the fused Mamba in_proj concatenates dims that are not individually
    divisible by the tensor axis (dt has n_heads columns), so each segment is
    its own quantized linear -- also matching the paper's per-linear-layer
    quantization granularity."""
    d = cfg.d_model
    dm = ssm_dims(cfg)
    gn = dm.n_groups * dm.n_state
    return {
        "in_z": ParamSpec((d, dm.d_inner), ("embed", "inner"), "fan_in"),
        "in_x": ParamSpec((d, dm.d_inner), ("embed", "inner"), "fan_in"),
        "in_bc": ParamSpec((d, 2 * gn), ("embed", "state"), "fan_in"),
        "in_dt": ParamSpec((d, dm.n_heads), ("embed", "dt"), "fan_in"),
        "conv_w": ParamSpec((dm.conv_width, dm.conv_dim), (None, "inner"),
                            "fan_in"),
        "conv_b": ParamSpec((dm.conv_dim,), ("inner",), "zeros"),
        "A_log": ParamSpec((dm.n_heads,), (None,), "ones"),
        "dt_bias": ParamSpec((dm.n_heads,), (None,), "zeros"),
        "D": ParamSpec((dm.n_heads,), (None,), "ones"),
        "gate_norm": ParamSpec((dm.d_inner,), ("inner",), "ones"),
        "out_proj": ParamSpec((dm.d_inner, d), ("inner", "embed"), "fan_in",
                              scale=1.0 / max(cfg.n_layers, 1)),
    }


def _in_projections(params, u, policy, ctx_in: LinearCtx):
    """Returns (z, xbc, dt_raw) with xbc = concat(x, B, C) for the conv."""
    z = policy.linear(ctx_in, u, params["in_z"])
    x = policy.linear(ctx_in, u, params["in_x"])
    bc = policy.linear(ctx_in, u, params["in_bc"])
    dt_raw = policy.linear(ctx_in, u, params["in_dt"])
    return z, jnp.concatenate([x, bc], axis=-1), dt_raw


def _causal_conv(xbc: jnp.ndarray, conv_w: jnp.ndarray, conv_b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along seq.  xbc: (B,S,C); conv_w: (W,C).
    ``tail`` is the (B, W-1, C) left context (decode); returns (out, new_tail)."""
    w = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    padded = jnp.concatenate([tail, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(w):
        out = out + (padded[:, i:i + xbc.shape[1], :].astype(jnp.float32)
                     * conv_w[i].astype(jnp.float32))
    out = jax.nn.silu(out + conv_b.astype(jnp.float32)).astype(xbc.dtype)
    new_tail = padded[:, -(w - 1):, :] if w > 1 else tail
    return out, new_tail


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bmat: jnp.ndarray, cmat: jnp.ndarray,
                init_state: Optional[jnp.ndarray] = None,
                chunk: int = CHUNK
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD (Dao & Gu 2024, Sec. 6).

    x: (B,S,H,P), dt: (B,S,H) (already softplus'd), a: (H,) negative,
    bmat/cmat: (B,S,G,N) with G dividing H.  Returns (y (B,S,H,P),
    final_state (B,H,N,P)).  fp32 internally.
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    bf = jnp.repeat(bmat.astype(jnp.float32), rep, axis=2).reshape(
        b, nc, chunk, h, n)
    cf = jnp.repeat(cmat.astype(jnp.float32), rep, axis=2).reshape(
        b, nc, chunk, h, n)

    da = dtf * a  # (b,nc,l,h), negative
    cum = jnp.cumsum(da, axis=2)
    # intra-chunk: att[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j, j<=i.
    # The (b,nc,l,l,h) tensors are the memory hot spot -> carrier precision
    # (exp(seg) <= 1 and CB are attention-like weights; states stay fp32).
    intra_dtype = x.dtype if x.dtype != jnp.float32 else jnp.float32
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,nc,i,j,h)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg).astype(intra_dtype)
    cb = jnp.einsum("bclhn,bcmhn->bclmh", cf.astype(intra_dtype),
                    bf.astype(intra_dtype))                      # (b,nc,i,j,h)
    att = cb * decay * dtf[:, :, None, :, :].astype(intra_dtype)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", att,
                         xf.astype(intra_dtype),
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    last = cum[:, :, -1:, :]                                      # (b,nc,1,h)
    state_decay = jnp.exp(last - cum)                             # (b,nc,l,h)
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp",
                        state_decay * dtf, bf, xf)                # (b,nc,h,n,p)
    chunk_decay = jnp.exp(last[:, :, 0, :])                       # (b,nc,h)

    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                             # (b,h,n,p),(b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                         # emit PREV state

    states_t = jnp.moveaxis(states, 1, 0)                         # (nc,b,h,n,p)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                     # (nc,b,h)
    final, prev_states = jax.lax.scan(step, h0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (b,nc,h,n,p)

    # inter-chunk: y_i += (C_i . h_prev) * exp(cum_i)
    y_inter = jnp.einsum("bclhn,bchnp,bclh->bclhp",
                         cf, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_reference(x, dt, a, bmat, cmat, init_state=None):
    """Sequential-scan oracle for tests: h_t = exp(dt a) h + dt B (x) x."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(bmat.astype(jnp.float32), rep, axis=2)
    cf = jnp.repeat(cmat.astype(jnp.float32), rep, axis=2)
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, t):
        da = jnp.exp(dtf[:, t] * a)                               # (b,h)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dtf[:, t], bf[:, t], xf[:, t])
        new = carry * da[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", cf[:, t], new)
        return new, y

    final, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssm_apply(params, u: jnp.ndarray, cfg, *,
              policy=None, rules=None,
              state: Optional[Dict[str, jnp.ndarray]] = None,
              return_state: bool = False, layer=None, n_layers: int = 0):
    """Full-sequence Mamba2 layer.  u: (B,S,d).

    state (decode/prefill carry): {"ssm": (B,H,N,P) fp32, "conv": (B,W-1,C)}.
    Returns (out, new_state_or_None).
    """
    policy = as_policy(policy)
    dm = ssm_dims(cfg)
    z, xbc, dt_raw = _in_projections(
        params, u, policy, LinearCtx("ssm_in", layer, n_layers))
    tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"], tail)

    di, gn = dm.d_inner, dm.n_groups * dm.n_state
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + gn].reshape(*xbc.shape[:2], dm.n_groups, dm.n_state)
    cmat = xbc[..., di + gn:].reshape(*xbc.shape[:2], dm.n_groups, dm.n_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    x4 = xs.reshape(*xs.shape[:2], dm.n_heads, dm.head_dim)
    # shard SSD internals over heads on the tensor axis (the intra-chunk
    # decay/attention tensors are the memory hot spot at train shapes)
    x4 = constrain(x4, rules, "batch", None, "dt", None)
    init = state["ssm"] if state is not None else None
    s_len = u.shape[1]
    chunk = CHUNK if s_len % CHUNK == 0 else s_len
    y4, final = ssd_chunked(x4, dt, a, bmat, cmat, init_state=init,
                            chunk=chunk)
    y4 = constrain(y4, rules, "batch", None, "dt", None)
    y4 = y4 + (params["D"].astype(jnp.float32)[None, None, :, None]
               * x4.astype(jnp.float32)).astype(y4.dtype)
    y = y4.reshape(*xs.shape[:2], dm.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["gate_norm"])
    out = policy.linear(LinearCtx("ssm_out", layer, n_layers), y,
                        params["out_proj"])
    new_state = ({"ssm": final, "conv": new_tail} if return_state else None)
    return out, new_state


def ssm_decode_step(params, u: jnp.ndarray, cfg, *,
                    policy=None, rules=None,
                    state: Dict[str, jnp.ndarray] = None,
                    layer=None, n_layers: int = 0):
    """Single-token recurrent update.  u: (B,1,d).  O(1) in context length --
    this is what makes long_500k tractable for SSM/hybrid archs."""
    policy = as_policy(policy)
    dm = ssm_dims(cfg)
    z, xbc, dt_raw = _in_projections(
        params, u, policy, LinearCtx("ssm_in", layer, n_layers))
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 state["conv"])
    di, gn = dm.d_inner, dm.n_groups * dm.n_state
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + gn].reshape(-1, dm.n_groups, dm.n_state)
    cmat = xbc[..., di + gn:].reshape(-1, dm.n_groups, dm.n_state)
    rep = dm.n_heads // dm.n_groups
    bf = jnp.repeat(bmat.astype(jnp.float32), rep, axis=1)        # (B,H,N)
    cf = jnp.repeat(cmat.astype(jnp.float32), rep, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                           # (B,H)
    x3 = xs[:, 0].reshape(-1, dm.n_heads, dm.head_dim).astype(jnp.float32)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, bf, x3)
    new_ssm = state["ssm"] * da[:, :, None, None] + upd
    y3 = jnp.einsum("bhn,bhnp->bhp", cf, new_ssm)
    y3 = y3 + params["D"].astype(jnp.float32)[None, :, None] * x3
    y = y3.reshape(-1, 1, dm.d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["gate_norm"])
    out = policy.linear(LinearCtx("ssm_out", layer, n_layers), y,
                        params["out_proj"])
    return out, {"ssm": new_ssm, "conv": new_tail}


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    dm = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, dm.n_heads, dm.n_state, dm.head_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, dm.conv_width - 1, dm.conv_dim), dtype),
    }
