"""Per-family transformer blocks (pre-norm residual wiring).

One block = the unit scanned over by the layer loop in ``lm.py``.  Returns
auxiliary losses (MoE load-balance / z-loss) so the scan can accumulate them.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.qpolicy import as_policy
from repro.models.attention import attn_apply, attn_spec
from repro.models.common import ParamSpec, apply_norm, constrain, norm_spec
from repro.models.mlp import mlp_apply, mlp_spec
from repro.models.moe import moe_apply, moe_spec
from repro.models.ssm import ssm_apply, ssm_decode_step, ssm_spec


def block_spec(cfg) -> Dict:
    if cfg.family in ("ssm",):
        return {"norm": norm_spec(cfg.d_model, cfg.norm), "ssm": ssm_spec(cfg)}
    if cfg.family == "hybrid":
        # the scanned unit is a mamba layer; the shared attn block lives at
        # the LM level (weights shared across invocations)
        return {"norm": norm_spec(cfg.d_model, cfg.norm), "ssm": ssm_spec(cfg)}
    spec = {
        "ln1": norm_spec(cfg.d_model, cfg.norm),
        "attn": attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model, cfg.norm),
    }
    if cfg.n_experts:
        spec["moe"] = moe_spec(cfg)
    else:
        spec["mlp"] = mlp_spec(cfg)
    return spec


def block_apply(params, h: jnp.ndarray, cfg, *,
                policy=None, rules=None,
                positions=None, mask=None,
                cache=None, cache_offset=None,
                ssm_state=None, decode: bool = False,
                layer=None, page_table=None):
    """Returns (h, new_cache, new_ssm_state, aux, z_loss).

    ``layer`` is this block's depth index -- a traced scalar inside the layer
    scan -- consumed by depth-indexed policy rules (``block[0:2].*=fp``)."""
    policy = as_policy(policy)
    nl = cfg.n_layers
    zero = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        x = apply_norm(h, params["norm"], cfg.norm)
        if decode:
            y, new_state = ssm_decode_step(params["ssm"], x, cfg,
                                           policy=policy, rules=rules,
                                           state=ssm_state,
                                           layer=layer, n_layers=nl)
        else:
            y, new_state = ssm_apply(params["ssm"], x, cfg, policy=policy,
                                     rules=rules, state=ssm_state,
                                     return_state=ssm_state is not None,
                                     layer=layer, n_layers=nl)
        h = h + y
        h = constrain(h, rules, "batch", "seq", None)
        return h, None, new_state, zero, zero

    x = apply_norm(h, params["ln1"], cfg.norm)
    y, new_cache = attn_apply(params["attn"], x, cfg, policy=policy,
                              rules=rules, positions=positions, mask=mask,
                              cache=cache, cache_offset=cache_offset,
                              page_table=page_table,
                              layer=layer, n_layers=nl)
    h = h + y
    h = constrain(h, rules, "batch", "seq", None)
    x = apply_norm(h, params["ln2"], cfg.norm)
    if cfg.n_experts:
        y, aux, z = moe_apply(params["moe"], x, cfg, policy=policy,
                              rules=rules, layer=layer, n_layers=nl)
    else:
        y, aux, z = mlp_apply(params["mlp"], x, cfg, policy=policy,
                              rules=rules, layer=layer, n_layers=nl), \
            zero, zero
    h = h + y
    h = constrain(h, rules, "batch", "seq", None)
    return h, new_cache, None, aux, z
