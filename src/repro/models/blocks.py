"""Per-family transformer blocks (pre-norm residual wiring).

One block = the unit scanned over by the layer loop in ``lm.py``.  Returns
auxiliary losses (MoE load-balance / z-loss) so the scan can accumulate them.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.qconfig import QuantRecipe
from repro.models.attention import attn_apply, attn_spec
from repro.models.common import ParamSpec, apply_norm, constrain, norm_spec
from repro.models.mlp import mlp_apply, mlp_spec
from repro.models.moe import moe_apply, moe_spec
from repro.models.ssm import ssm_apply, ssm_decode_step, ssm_spec


def block_spec(cfg) -> Dict:
    if cfg.family in ("ssm",):
        return {"norm": norm_spec(cfg.d_model, cfg.norm), "ssm": ssm_spec(cfg)}
    if cfg.family == "hybrid":
        # the scanned unit is a mamba layer; the shared attn block lives at
        # the LM level (weights shared across invocations)
        return {"norm": norm_spec(cfg.d_model, cfg.norm), "ssm": ssm_spec(cfg)}
    spec = {
        "ln1": norm_spec(cfg.d_model, cfg.norm),
        "attn": attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model, cfg.norm),
    }
    if cfg.n_experts:
        spec["moe"] = moe_spec(cfg)
    else:
        spec["mlp"] = mlp_spec(cfg)
    return spec


def block_apply(params, h: jnp.ndarray, cfg, *,
                recipe: Optional[QuantRecipe], rules,
                positions, mask,
                cache=None, cache_offset=None,
                ssm_state=None, decode: bool = False):
    """Returns (h, new_cache, new_ssm_state, aux, z_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        x = apply_norm(h, params["norm"], cfg.norm)
        if decode:
            y, new_state = ssm_decode_step(params["ssm"], x, cfg,
                                           recipe=recipe, rules=rules,
                                           state=ssm_state)
        else:
            y, new_state = ssm_apply(params["ssm"], x, cfg, recipe=recipe,
                                     rules=rules, state=ssm_state,
                                     return_state=ssm_state is not None)
        h = h + y
        h = constrain(h, rules, "batch", "seq", None)
        return h, None, new_state, zero, zero

    x = apply_norm(h, params["ln1"], cfg.norm)
    y, new_cache = attn_apply(params["attn"], x, cfg, recipe=recipe,
                              rules=rules, positions=positions, mask=mask,
                              cache=cache, cache_offset=cache_offset)
    h = h + y
    h = constrain(h, rules, "batch", "seq", None)
    x = apply_norm(h, params["ln2"], cfg.norm)
    if cfg.n_experts:
        y, aux, z = moe_apply(params["moe"], x, cfg, recipe=recipe, rules=rules)
    else:
        y, aux, z = mlp_apply(params["mlp"], x, cfg, recipe=recipe,
                              rules=rules), zero, zero
    h = h + y
    h = constrain(h, rules, "batch", "seq", None)
    return h, new_cache, None, aux, z
