"""Unified model facade: every architecture exposes the same four entry
points (init / train_loss / prefill / decode) plus ShapeDtypeStruct input
specs for dry-run lowering (no allocation).

Quantization is configured with either ``recipe=`` (a legacy
:class:`QuantRecipe`, wrapped via ``QuantPolicy.from_recipe``) or
``policy=`` (a :class:`~repro.core.qpolicy.QuantPolicy` / policy string);
``policy`` wins when both are given.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.qpolicy import as_policy
from repro.models import encdec as ed
from repro.models import lm
from repro.models.common import axes_from_spec, init_from_spec


class Model(NamedTuple):
    cfg: ArchConfig
    spec: Any
    init_params: Callable            # (key, dtype=f32) -> params
    axes: Any                        # logical-axes tree matching params
    train_loss: Callable             # (params, batch, *, recipe/policy, rules, rng)
    prefill: Callable                # (params, batch, *, recipe/policy, rules, max_seq, last_pos) -> (logits, state)
    decode: Callable                 # (params, state, token, pos, *, recipe/policy, rules); pos: scalar or (B,)
    init_decode_state: Callable      # (batch, max_seq, enc_len, dtype, policy) -> state tree


def _pick(policy, recipe):
    return as_policy(policy if policy is not None else recipe)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        spec = ed.encdec_spec(cfg)

        def train_loss(params, batch, *, recipe=None, policy=None,
                       rules=None, rng=None):
            return ed.encdec_loss(params, batch, cfg,
                                  policy=_pick(policy, recipe),
                                  rules=rules, rng=rng)

        def prefill(params, batch, *, recipe=None, policy=None, rules=None,
                    max_seq=None, last_pos=None, segments=None):
            if last_pos is not None or segments is not None:
                raise NotImplementedError(
                    "last_pos / segments (bucketed-prompt prefill) "
                    "is decoder-only")
            logits, cache = ed.encdec_prefill(params, batch, cfg,
                                              policy=_pick(policy, recipe),
                                              rules=rules, max_seq=max_seq)
            return logits, cache

        def decode(params, state, token, pos, *, recipe=None, policy=None,
                   rules=None, page_table=None):
            if page_table is not None:
                raise NotImplementedError("paged KV cache is decoder-only")
            return ed.encdec_decode(params, state, token, pos, cfg,
                                    policy=_pick(policy, recipe), rules=rules)

        def init_decode_state(batch: int, max_seq: int, enc_len: int,
                              dtype=jnp.bfloat16, policy=None):
            if policy is not None and as_policy(policy).kv_spec() is not None:
                raise NotImplementedError("int8 KV cache is decoder-only")
            kh, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
            kv = lambda s: {"k": jnp.zeros((L, batch, s, kh, hd), dtype),
                            "v": jnp.zeros((L, batch, s, kh, hd), dtype)}
            return {"self": kv(max_seq), "cross": kv(enc_len)}
    else:
        spec = lm.lm_spec(cfg)

        def train_loss(params, batch, *, recipe=None, policy=None,
                       rules=None, rng=None):
            return lm.lm_loss(params, batch, cfg,
                              policy=_pick(policy, recipe), rules=rules,
                              rng=rng)

        def prefill(params, batch, *, recipe=None, policy=None, rules=None,
                    max_seq=None, last_pos=None, segments=None):
            logits, caches, ssm = lm.lm_prefill(params, batch, cfg,
                                                policy=_pick(policy, recipe),
                                                rules=rules, max_seq=max_seq,
                                                last_pos=last_pos,
                                                segments=segments)
            return logits, {"caches": caches, "ssm": ssm}

        def decode(params, state, token, pos, *, recipe=None, policy=None,
                   rules=None, page_table=None):
            logits, caches, ssm = lm.lm_decode(
                params, state.get("caches"), state.get("ssm"), token, pos,
                cfg, policy=_pick(policy, recipe), rules=rules,
                page_table=page_table)
            return logits, {"caches": caches, "ssm": ssm}

        def init_decode_state(batch: int, max_seq: int, enc_len: int = 0,
                              dtype=jnp.bfloat16, policy=None):
            kv_spec = as_policy(policy).kv_spec() if policy is not None \
                else None
            caches, ssm = lm.init_caches(cfg, batch, max_seq, dtype,
                                         kv_spec=kv_spec)
            return {"caches": caches, "ssm": ssm}

    def init_params(key, dtype=jnp.float32):
        return init_from_spec(key, spec, dtype)

    return Model(cfg=cfg, spec=spec, init_params=init_params,
                 axes=axes_from_spec(spec), train_loss=train_loss,
                 prefill=prefill, decode=decode,
                 init_decode_state=init_decode_state)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs (dry-run: no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def enc_len_for(cfg: ArchConfig, seq: int) -> int:
    return max(seq // max(cfg.frame_ratio, 1), 1)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    gb, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        p = cfg.num_patches
        return {"patches": _sds((gb, p, cfg.d_model), dtype),
                "tokens": _sds((gb, s - p + 1), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": _sds((gb, enc_len_for(cfg, s), cfg.d_model), dtype),
                "tokens": _sds((gb, s + 1), jnp.int32)}
    return {"tokens": _sds((gb, s + 1), jnp.int32)}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    gb, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        p = cfg.num_patches
        return {"patches": _sds((gb, p, cfg.d_model), dtype),
                "tokens": _sds((gb, s - p), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": _sds((gb, enc_len_for(cfg, s), cfg.d_model), dtype),
                "tokens": _sds((gb, s), jnp.int32)}
    return {"tokens": _sds((gb, s), jnp.int32)}


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                       model: Optional[Model] = None) -> Dict[str, Any]:
    """Specs for one decode step: token, pos, and the decode-state tree."""
    model = model or build_model(cfg)
    gb, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    state = jax.eval_shape(
        lambda: model.init_decode_state(gb, s, enc_len_for(cfg, s), dtype))
    return {"token": _sds((gb, 1), jnp.int32),
            "pos": _sds((), jnp.int32),
            "state": state}


def decode_state_axes(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical-axes tree matching ``init_decode_state`` output (for sharding
    the serve-state: KV caches, SSM states, cross-attn KV)."""
    kv_axes = ("layers", "batch", "kv_seq", "kv", None)
    ssm_axes = {"ssm": ("layers", "batch", "dt", None, None),
                "conv": ("layers", "batch", None, "inner")}
    if cfg.family == "encdec":
        kv = {"k": kv_axes, "v": kv_axes}
        return {"self": kv, "cross": kv}
    if cfg.family == "ssm":
        return {"caches": None, "ssm": ssm_axes}
    if cfg.family == "hybrid":
        return {"caches": {"k": kv_axes, "v": kv_axes}, "ssm": ssm_axes}
    return {"caches": {"k": kv_axes, "v": kv_axes}, "ssm": None}


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                model: Optional[Model] = None) -> Dict[str, Any]:
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, model)
    raise ValueError(shape.kind)
