"""yi-6b [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, llama-family.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab_size=64000,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        rope_theta=5000000.0,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="yi-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        logit_chunk=64,
    )
