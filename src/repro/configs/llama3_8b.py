"""llama3-8b [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, SwiGLU.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=128256,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        rope_theta=500000.0,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llama3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        logit_chunk=64,
    )
