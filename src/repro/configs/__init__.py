"""Architecture registry: the 10 assigned architectures + the paper's own
GPT-2 small.  ``--arch <id>`` everywhere resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, SHAPES, ShapeConfig,
                                shape_applicable)

_MODULES: Dict[str, str] = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6p6b",
    "zamba2-2.7b": "zamba2_2p7b",
    "paligemma-3b": "paligemma_3b",
    "gemma-2b": "gemma_2b",
    "qwen3-32b": "qwen3_32b",
    "llama3-8b": "llama3_8b",
    "yi-6b": "yi_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-130m": "mamba2_130m",
    "gpt2-small": "gpt2_small",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "gpt2-small"]


def _module(name: str):
    try:
        return importlib.import_module(f"repro.configs.{_MODULES[name]}")
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; options: {sorted(_MODULES)}") from None


def get_config(name: str) -> ArchConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(_MODULES)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ASSIGNED_ARCHS",
           "get_config", "get_smoke_config", "get_shape", "list_archs",
           "shape_applicable"]
