"""Architecture + shape configuration system.

Every assigned architecture ships as ``src/repro/configs/<id>.py`` exposing
``config()`` (exact published numbers) and ``smoke_config()`` (reduced, same
family, CPU-runnable).  ``repro.configs.get_config(name)`` resolves them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # block flavour
    act: str = "silu"
    mlp_kind: str = "gated"          # gated | classic
    norm: str = "rmsnorm"            # rmsnorm | rmsnorm_p1 | layernorm
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    use_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: embeddings * sqrt(d_model)
    max_seq: int = 8192              # learned-pos table size
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    hybrid_attn_every: int = 0       # zamba2: shared attn block cadence
    # enc-dec / multimodal frontends
    enc_layers: int = 0
    frontend: str = "none"           # none | audio_stub | vision_stub
    num_patches: int = 0             # vlm prefix length
    frame_ratio: int = 1             # audio: encoder frames = seq // ratio
    # numerics / execution
    attention_impl: str = "xla"      # xla | flash_pallas (Pallas kernel)
    dtype: str = "bfloat16"
    remat: bool = True
    logit_chunk: int = 512           # chunked cross-entropy chunk length
    sub_quadratic: bool = False      # can run long_500k (SSM/hybrid)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/lm-head rows padded to a multiple of 128 so the vocab
        dim always tensor-shards (e.g. seamless' 256206 is not 16-divisible
        -> its CE logits would replicate).  Padded logits are masked to -inf
        in the loss/serve heads; padded ids are never produced."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, k, hd = self.n_heads, self.n_kv_heads, self.head_dim
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            attn = d * (h * hd) * 2 + d * (k * hd) * 2
            if self.mlp_kind == "gated":
                mlp = 3 * d * ff
            else:
                mlp = 2 * d * ff
            if self.n_experts:
                mlp = self.n_experts * 3 * d * ff + d * self.n_experts
            per_layer = attn + mlp
        elif self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            nh = di // self.ssm_head_dim
            proj = d * (2 * di + 2 * self.ssm_state + nh) + di * d
            per_layer = proj
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            d2 = 2 * d
            shared = d2 * (h * hd) * 2 + d2 * (k * hd) * 2 + 3 * d2 * ff + d2 * d
            total += shared
        if self.family == "encdec":
            # encoder blocks + decoder cross-attn
            attn = d * (h * hd) * 2 + d * (k * hd) * 2
            mlp = 2 * d * ff if self.mlp_kind == "classic" else 3 * d * ff
            total += self.enc_layers * (attn + mlp) + self.n_layers * attn
        emb = v * d * (1 if self.tie_embeddings else 2)
        return total + emb

    def active_param_count(self) -> int:
        """MoE: only top_k experts' FFN params count as active (6*N_active*D)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_like = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * d * ff * self.n_layers
        return dense_like - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-not).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k-token decode is "
                       "quadratic-prohibitive; skipped per DESIGN.md Section 5")
    return True, ""
