"""mamba2-130m [arXiv:2405.21060; unverified].

24L d_model=768, attention-free SSD (state-space duality), ssm_state=128,
vocab=50280.  Sub-quadratic -> runs long_500k (O(1)-in-context decode).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=1,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        norm="rmsnorm", pos="none", tie_embeddings=True, sub_quadratic=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=1,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
        norm="rmsnorm", pos="none", tie_embeddings=True, sub_quadratic=True,
        logit_chunk=64,
    )
