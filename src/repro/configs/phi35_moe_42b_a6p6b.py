"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert vocab=32064, MoE 16 experts
top-2.  E=16 divides the tensor axis exactly -> full expert parallelism via
all_to_all (one expert per rank).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064, n_experts=16, top_k=2,
        act="silu", mlp_kind="gated", norm="layernorm", pos="rope",
        rope_theta=10000.0, use_bias=False,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="phi35-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512, n_experts=4, top_k=2,
        capacity_factor=8.0,  # dropless at smoke scale (decode==prefill)
        act="silu", mlp_kind="gated", norm="layernorm", pos="rope",
        logit_chunk=64,
    )
