"""gemma-2b [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU, head_dim=256,
tied embeddings, embeddings scaled by sqrt(d_model), rmsnorm with (1+w).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=256000,
        act="gelu", mlp_kind="gated", norm="rmsnorm_p1", pos="rope",
        tie_embeddings=True, embed_scale=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        act="gelu", mlp_kind="gated", norm="rmsnorm_p1", pos="rope",
        tie_embeddings=True, embed_scale=True, logit_chunk=64,
    )
