"""paligemma-3b [arXiv:2407.07726; hf].

Backbone only per the assignment: 18L gemma (d_model=2048 8H MQA kv=1
head_dim=256 d_ff=16384 GeGLU) vocab=257216.  The SigLIP frontend is a STUB:
input_specs provides precomputed patch embeddings (B, 256, d_model); training
uses PaliGemma's prefix-LM masking (full attention over the image prefix).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216,
        act="gelu", mlp_kind="gated", norm="rmsnorm_p1", pos="rope",
        tie_embeddings=True, embed_scale=True, num_patches=256,
        frontend="vision_stub",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        act="gelu", mlp_kind="gated", norm="rmsnorm_p1", pos="rope",
        tie_embeddings=True, embed_scale=True, num_patches=8,
        frontend="vision_stub", logit_chunk=64,
    )
