"""zamba2-2.7b [arXiv:2411.15242; hf].

54 Mamba2 layers d_model=2560 ssm_state=64, plus a SHARED attention+MLP block
(32H MHA kv=32, d_ff=10240) applied after every 6th mamba layer on
concat(h, input_embedding) in 2*d_model space (9 invocations, shared weights,
per-invocation KV cache).  Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        head_dim=160,                  # shared block operates in 2*d = 5120
        d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        hybrid_attn_every=6,
        act="gelu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        tie_embeddings=True, sub_quadratic=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
        hybrid_attn_every=2,
        act="gelu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        tie_embeddings=True, sub_quadratic=True, logit_chunk=64,
    )
