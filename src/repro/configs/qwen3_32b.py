"""qwen3-32b [hf:Qwen/Qwen3-8B family; hf].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm.
head_dim=128 (the published Qwen3 value; see DESIGN.md Section 5).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=25600, vocab_size=151936,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        rope_theta=1e6, qk_norm=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        qk_norm=True, logit_chunk=64,
    )
