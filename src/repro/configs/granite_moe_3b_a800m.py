"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40 experts
top-8.  E=40 does not divide the 16-way tensor axis -> ff_sharded expert mode
(DESIGN.md Section 5).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155, n_experts=40, top_k=8,
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        rope_theta=10000.0, tie_embeddings=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512, n_experts=8, top_k=2,
        capacity_factor=8.0,  # dropless at smoke scale (decode==prefill)
        act="silu", mlp_kind="gated", norm="rmsnorm", pos="rope",
        tie_embeddings=True, logit_chunk=64,
    )
