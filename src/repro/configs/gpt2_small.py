"""GPT-2 small (124M) -- the paper's own experimental model (Radford et al.
2019): 12L d_model=768 12H d_ff=3072 vocab=50257, learned positions,
LayerNorm, GELU 2-layer MLP, biases, tied embeddings, context 1024.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gpt2-small", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=50257,
        act="gelu", mlp_kind="classic", norm="layernorm", pos="learned",
        use_bias=True, tie_embeddings=True, max_seq=1024,
    )


def smoke_config() -> ArchConfig:
    """The mini GPT-2 used for the paper-validation pre-training runs."""
    return ArchConfig(
        name="gpt2-mini", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=256,
        act="gelu", mlp_kind="classic", norm="layernorm", pos="learned",
        use_bias=True, tie_embeddings=True, max_seq=512, logit_chunk=128,
    )
