"""seamless-m4t-medium [arXiv:2308.11596; hf].

Encoder-decoder, 12L enc + 12L dec, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206.  The audio frontend is a STUB: input_specs provides precomputed
frame embeddings; encoder frames = seq_len // 4 (conv downsampling ratio).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        head_dim=64, d_ff=4096, vocab_size=256206,
        act="gelu", mlp_kind="classic", norm="layernorm", pos="rope",
        use_bias=True, frontend="audio_stub", frame_ratio=4,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="encdec",
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512,
        act="gelu", mlp_kind="classic", norm="layernorm", pos="rope",
        use_bias=True, frontend="audio_stub", frame_ratio=4, logit_chunk=64,
    )
