"""Logical-axis sharding rules (MaxText-style) for DP + FSDP + TP + EP + SP.

Meshes (prescribed):
  single-pod : (16, 16)    axes ("data", "model")
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model")

Mapping (train mode):
  batch   -> ("pod", "data")   data parallelism across pods and data rows
  embed   -> "data"            FSDP: params/optimizer sharded over the data
                               axis; GSPMD all-gathers per layer inside the
                               scan (ZeRO-3 semantics)
  vocab/mlp/heads/kv/inner/... -> "model"   tensor parallelism
  expert  -> "model"           expert parallelism (when E % tp == 0)
  kv_seq  -> "model" for decode shapes (SP flash-decode: softmax reductions
             over the sharded KV length lower to all-reduces)

Divisibility-driven: any mapping whose dim is not evenly divisible by the
mesh-axis size (or whose mesh axis is already taken by an earlier dim of the
same tensor) is dropped for that tensor (e.g. seamless' 256206 vocab is not
16-divisible -> its embedding shards on d_model instead).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    axis_map: Dict[str, Tuple[str, ...]]
    dp_axes: Tuple[str, ...]
    tp_axis: str

    @property
    def dp_size(self) -> int:
        sizes = _axis_sizes(self.mesh)
        return math.prod(sizes[a] for a in self.dp_axes)

    @property
    def tp_size(self) -> int:
        return _axis_sizes(self.mesh)[self.tp_axis]

    def spec_for(self, shape: Tuple[int, ...], axes) -> P:
        """Greedy per-dim assignment with divisibility + uniqueness checks."""
        sizes = _axis_sizes(self.mesh)
        used = set()
        parts = []
        for dim, logical in zip(shape, axes):
            mesh_axes = self.axis_map.get(logical) if logical else None
            if not mesh_axes:
                parts.append(None)
                continue
            total = math.prod(sizes[a] for a in mesh_axes)
            if dim % total == 0 and not (set(mesh_axes) & used):
                used.update(mesh_axes)
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            else:
                parts.append(None)
        return P(*parts)

    def sharding_for(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))

    def constrain(self, x: jnp.ndarray, logical_axes) -> jnp.ndarray:
        return jax.lax.with_sharding_constraint(
            x, self.sharding_for(x.shape, logical_axes))

    def tree_shardings(self, shape_tree: PyTree, axes_tree: PyTree) -> PyTree:
        """NamedSharding tree for a params/state tree.  ``shape_tree`` leaves
        need a ``.shape``; axes leaves are tuples of logical names."""
        return jax.tree_util.tree_map(
            lambda leaf, ax: self.sharding_for(leaf.shape, ax),
            shape_tree, axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def batch_spec(self, ndim: int) -> P:
        return P(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0],
                 *([None] * (ndim - 1)))

    def batch_sharding(self, ndim: int) -> NamedSharding:
        return NamedSharding(self.mesh, self.batch_spec(ndim))


_TRAIN_MAP = {
    "batch": ("pod", "data"),
    "seq": ("model",),           # sequence-parallel residual stream (SP):
                                 # per-layer saved activations shrink by tp;
                                 # GSPMD inserts the gather/scatter at the
                                 # attention/SSD boundary
    "embed": ("data",),          # FSDP
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "inner": ("model",),
    "state": ("model",),
    "dt": ("model",),
    "expert": ("model",),
    "embed2": (),                # second d_model-like dim: replicated
    "kv_seq": (),                # sequence never sharded in train
    "layers": (),
}

_SERVE_MAP = dict(_TRAIN_MAP, embed=(), seq=())  # no FSDP/SP at serve time
_SERVE_SP_MAP = dict(_SERVE_MAP, kv_seq=("model",))   # long-context decode

# Multi-chip serving (the Engine's mesh mode): weights FSDP-shard over the
# data axis -- prepared int8 QState payloads live sharded and GSPMD gathers
# the (cheap, integer) payload per layer -- while heads/kv/mlp/vocab stay
# tensor-parallel over the model axis, which is what shards the KV cache by
# kv-head.  Batch (decode slots) and sequence are replicated: admission is
# host-side bookkeeping and must stay shard-local, and the decode kernels
# shard_map over the kv-head axis only.
_SERVE_FSDP_MAP = dict(_SERVE_MAP, embed=("data",), batch=())

# Flat FSDP-256 (beyond-paper perf remap, EXPERIMENTS.md Section Perf):
# batch shards over BOTH mesh axes (4096 tokens/chip at train_4k) and every
# parameter FSDP-shards over the flat 256; no tensor parallelism.  Megatron
# TP-16's four per-layer h-sized all-reduces disappear; the remaining
# collectives are per-layer bf16 weight gathers + fp32 grad reduce-scatters,
# which overlap with compute.  Chunked CE makes the unsharded-vocab logits
# affordable (b_loc=1).
_TRAIN_FSDP_MAP = {
    "batch": ("data", "model"),
    "seq": (),
    "embed": ("data", "model"),
    "vocab": (), "mlp": (), "heads": (), "kv": (), "inner": (),
    "state": (), "dt": (), "expert": (), "embed2": (), "kv_seq": (),
    "layers": (),
}


def make_rules(mesh: Mesh, mode: str = "train", cfg=None) -> Rules:
    """mode: train | serve | serve_sp (sequence-sharded KV for long decode)
    | serve_fsdp (multi-chip Engine: FSDP weights + TP kv-heads).

    ``cfg`` enables head-count-aware TP: a GQA projection whose FLAT dim
    divides the axis (e.g. 8 kv heads x 128 = 1024 on a 16-way axis) but
    whose HEAD count does not would get half-head splits -- SPMD then falls
    back to full rematerialization at the (b,s,k,hd) reshape.  Megatron's
    answer is kv duplication: keep those projections replicated on the
    tensor axis (they are small) and shard the repeated q-heads instead.
    """
    names = set(mesh.axis_names)
    amap = {"train": _TRAIN_MAP, "serve": _SERVE_MAP,
            "serve_sp": _SERVE_SP_MAP, "serve_fsdp": _SERVE_FSDP_MAP,
            "train_fsdp": _TRAIN_FSDP_MAP}[mode]
    amap = {k: tuple(a for a in v if a in names) for k, v in amap.items()}
    if mode == "train_fsdp":
        dp_axes = tuple(a for a in ("pod", "data", "model") if a in names)
        tp_axis = "model" if "model" in names else mesh.axis_names[-1]
        return Rules(mesh=mesh, axis_map=amap, dp_axes=dp_axes,
                     tp_axis=tp_axis)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    if not dp_axes:
        dp_axes = (mesh.axis_names[0],)
    tp_axis = "model" if "model" in names else mesh.axis_names[-1]
    if cfg is not None and tp_axis in names:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))[tp_axis]
        if getattr(cfg, "n_heads", 0) and cfg.n_heads % tp != 0:
            amap["heads"] = ()
        if getattr(cfg, "n_kv_heads", 0) and cfg.n_kv_heads % tp != 0:
            amap["kv"] = ()
    return Rules(mesh=mesh, axis_map=amap, dp_axes=dp_axes, tp_axis=tp_axis)
