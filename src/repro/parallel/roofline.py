"""Roofline-term extraction from compiled XLA artifacts (EXPERIMENTS.md
Section Roofline).

Hardware model: TPU v5e -- 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (prompt-prescribed constants).

Conventions (verified empirically on this jax build):
  * ``compiled.cost_analysis()['flops']`` / ``['bytes accessed']`` are
    PER-DEVICE (the partitioned module).
  * ``compiled.as_text()`` is the per-partition HLO; collective operand
    shapes are per-device.  Wire bytes per device use ring costs:
      all-reduce        2 * b * (n-1)/n
      all-gather        b_out * (n-1)/n
      reduce-scatter    b_in * (n-1)/n      (b_in = n * b_out)
      all-to-all        b * (n-1)/n
      collective-permute b
  * collective term assumes 1 active ICI link per hop (conservative).
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\(?[\w\[\],\s{}]*?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind + total, from partitioned HLO."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        if "replica_groups" not in line and "collective-permute" not in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[0]:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("result"))
        n = max(_group_size(line, n_devices), 1)
        frac = (n - 1) / n if n > 1 else 0.0
        if op == "all-reduce":
            wire = 2.0 * b * frac
        elif op == "all-gather":
            wire = b * frac                      # result is the gathered array
        elif op == "reduce-scatter":
            wire = b * (n - 1)                   # result is the shard
        elif op == "all-to-all":
            wire = b * frac
        else:                                    # collective-permute
            wire = float(b)
        out[op] += wire
        out["count"] += 1
    out["total_wire_bytes"] = sum(out[k] for k in
                                  ("all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"))
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float,
                   model_flops_total: Optional[float] = None,
                   n_devices: int = 256) -> Dict[str, float]:
    """Three terms in seconds + bottleneck + usefulness ratio."""
    compute_s = flops_per_dev / PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / HBM_BW
    collective_s = wire_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(compute_s, memory_s, collective_s)
    out = dict(terms)
    out["dominant"] = dominant
    out["step_time_s"] = step_s
    if model_flops_total:
        model_per_dev = model_flops_total / n_devices
        out["model_flops_total"] = model_flops_total
        out["useful_flops_ratio"] = (model_per_dev / flops_per_dev
                                     if flops_per_dev else 0.0)
        # MFU against the dominant-term step time
        out["roofline_mfu"] = (model_per_dev / PEAK_FLOPS_BF16) / step_s \
            if step_s else 0.0
    return out
