"""Loop-aware FLOP / byte / collective counting over compiled HLO text.

``compiled.cost_analysis()`` counts every computation ONCE -- a
scan-over-layers train step under-reports by the trip count (verified: a
32-layer llama step reports ~1/22000 of its true FLOPs).  This module parses
``compiled.as_text()`` (the per-partition module), reconstructs the call
graph (entry -> fusions / while bodies / conditionals), extracts while-loop
trip counts from their condition computations (`compare(iv, constant(N)),
direction=LT`), and accumulates:

  * flops: dot ops (2*M*N*K from result shape x contracted size via the
    per-computation symbol table), convolutions (approx), and elementwise /
    reduce ops at 1 flop per output element;
  * bytes: per-instruction operand+result bytes at fusion boundaries (inside
    fused computations nothing re-counts -- mirrors XLA "bytes accessed");
  * collective wire bytes by kind, ring-cost weighted (see roofline.py).

All quantities are PER-DEVICE (the module is the partitioned program).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_TRIP_BACKEND = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s*([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:{[^}]*})?")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP_LT = re.compile(r"constant\((\d+)\)")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"\{?([%\w\.\-, ]+)\}?")
#: one computation-reference attribute: either a brace-list
#: (``branch_computations={%a, %b}``) or a single ``%name`` -- the value
#: must NOT be allowed to run past a comma into the next ``attr=`` pair
#: (``condition=%c, body=%b`` is two separate references on one line)
_ANY_CALL_ATTR = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations|"
    r"called_computations)=(\{[^}]*\}|%[\w\.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "rsqrt", "sqrt", "maximum", "minimum", "negate", "abs", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "logistic", "cosine",
    "sine", "atan2", "expm1", "log1p", "compare", "select", "clamp",
    "reduce", "exponential-minus-one",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dtype, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dtype, shape in _shape_list(type_str):
        total += _DTYPE_BYTES[dtype] * int(math.prod(shape)) if shape else \
            _DTYPE_BYTES[dtype]
    return total


def _nelems(type_str: str) -> int:
    total = 0
    for _, shape in _shape_list(type_str):
        total += int(math.prod(shape)) if shape else 1
    return total


class Instr:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name, self.type_str, self.op, self.rest = name, type_str, op, rest


def parse_module(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                if stripped.startswith("ENTRY"):
                    entry = cur
                comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _symbol_table(instrs: List[Instr]) -> Dict[str, str]:
    return {i.name: i.type_str for i in instrs}


def entry_name(comps: Dict[str, List[Instr]]) -> Optional[str]:
    """Real name of the ENTRY computation (``__entry__`` is an alias of the
    same instruction list, so identity comparison recovers it)."""
    body = comps.get("__entry__")
    if body is None:
        return None
    return next((k for k, v in comps.items()
                 if k != "__entry__" and v is body), None)


def instr_callees(ins: Instr) -> List[str]:
    """Computation names an instruction references (fusion bodies, while
    body/condition, reduce to_apply, conditional branches, custom-call
    called_computations)."""
    out: List[str] = []
    for m in _ANY_CALL_ATTR.finditer(ins.rest):
        out.extend(re.findall(r"%([\w\.\-]+)", m.group(1)))
    return out


def reachable_computations(comps: Dict[str, List[Instr]]) -> List[str]:
    """Computation names reachable from ENTRY via call attributes, in BFS
    order starting at the entry computation.  Compiled modules can retain
    dead computations (e.g. branches DCE'd after inlining); op counts over
    the whole dict would charge ops that never execute."""
    start = entry_name(comps)
    if start is None:
        return []
    seen, order, frontier = {start}, [start], [start]
    while frontier:
        nxt: List[str] = []
        for name in frontier:
            for ins in comps.get(name, []):
                for callee in instr_callees(ins):
                    if callee in comps and callee not in seen:
                        seen.add(callee)
                        order.append(callee)
                        nxt.append(callee)
        frontier = nxt
    return order


def _fusion_param_bytes(body: List[Instr]) -> Dict[int, int]:
    """Effective bytes read per fusion parameter.

    A fusion that dynamic-slices a parameter (the scan-over-layers pattern:
    read layer i of an (L, ...) stacked tensor) only touches the slice, so
    charging the full operand overstates HBM traffic by L.  Returns
    {param_index: effective_bytes}; parameters not sliced are charged fully.
    """
    params: Dict[str, int] = {}
    full: Dict[str, int] = {}
    for ins in body:
        if ins.op == "parameter":
            mm = re.match(r"(\d+)\)", ins.rest.strip())
            if mm:
                params[ins.name] = int(mm.group(1))
                full[ins.name] = _nbytes(ins.type_str)
    sliced: Dict[int, int] = {}
    used_whole: Dict[int, bool] = {}
    for ins in body:
        refs = _OPERAND.findall(ins.rest)
        for j, r in enumerate(refs):
            if r not in params:
                continue
            idx = params[r]
            if ins.op in ("dynamic-slice", "slice", "gather") and j == 0:
                sliced[idx] = sliced.get(idx, 0) + _nbytes(ins.type_str)
            elif ins.op != "parameter":
                used_whole[idx] = True
    out = {}
    for name, idx in params.items():
        if idx in sliced and not used_whole.get(idx, False):
            out[idx] = sliced[idx]
        else:
            out[idx] = full[name]
    return out


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    out_elems = _nelems(instr.type_str)
    ops = _OPERAND.findall(instr.rest)
    k = 1
    m = _CONTRACT.search(instr.rest)
    if ops and m is not None:
        lhs_type = symtab.get(ops[0], "")
        shapes = _shape_list(lhs_type)
        if shapes:
            lhs_shape = shapes[0][1]
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs_shape):
                    k *= lhs_shape[int(d)]
    return 2.0 * out_elems * k


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(op: str, b: float, n: int) -> float:
    frac = (n - 1) / n if n > 1 else 0.0
    if op == "all-reduce":
        return 2.0 * b * frac
    if op == "all-gather":
        return b * frac
    if op == "reduce-scatter":
        return b * (n - 1)
    if op == "all-to-all":
        return b * frac
    return float(b)              # collective-permute


class Counts:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.wire = {k: 0.0 for k in _COLLECTIVES}
        self.coll_count = 0

    def scaled(self, mult: float) -> "Counts":
        c = Counts()
        c.flops = self.flops * mult
        c.bytes = self.bytes * mult
        c.wire = {k: v * mult for k, v in self.wire.items()}
        c.coll_count = self.coll_count * mult
        return c

    def add(self, other: "Counts"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.wire:
            self.wire[k] += other.wire[k]
        self.coll_count += other.coll_count


def _trip_count_fallback(cond: List[Instr]) -> int:
    """When backend_config lacks known_trip_count: scan-style while conditions
    contain only the induction variable and the loop bound constant."""
    best = 1
    for i in cond:
        if i.op == "constant" and i.type_str.strip().startswith("s32"):
            mm = re.match(r"(\d+)\)", i.rest.strip())
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def count_module(hlo: str, n_devices: int = 256) -> Dict[str, float]:
    comps = parse_module(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
    memo: Dict[str, Counts] = {}

    def visit(name: str, depth: int = 0) -> Counts:
        if name in memo:
            return memo[name]
        if depth > 50 or name not in comps:
            return Counts()
        memo[name] = Counts()        # cycle guard
        instrs = comps[name]
        symtab = _symbol_table(instrs)
        c = Counts()
        for ins in instrs:
            op = ins.op
            if op == "dot":
                c.flops += _dot_flops(ins, symtab)
                c.bytes += _nbytes(ins.type_str) + sum(
                    _nbytes(symtab.get(o, ""))
                    for o in _OPERAND.findall(ins.rest)[:3])
            elif op == "convolution":
                c.flops += 2.0 * _nelems(ins.type_str) * 32   # approx
                c.bytes += _nbytes(ins.type_str)
            elif op == "fusion":
                called = _CALL_ATTR.search(ins.rest)
                inner = Counts()
                target = None
                if called:
                    target = called.group(1).split(",")[0].strip().lstrip("%")
                    inner = visit(target, depth + 1)
                c.flops += inner.flops
                # fusion boundary bytes: result + operands, with operands
                # that the body only slices charged at slice size
                eff = (_fusion_param_bytes(comps.get(target, []))
                       if target else {})
                operands = _OPERAND.findall(ins.rest.split("kind=")[0])
                ob = 0
                for idx, o in enumerate(operands):
                    ob += eff.get(idx, _nbytes(symtab.get(o, "")))
                c.bytes += _nbytes(ins.type_str) + ob
                c.wire = {k: c.wire[k] + inner.wire[k] for k in c.wire}
                c.coll_count += inner.coll_count
            elif op == "while":
                attrs = dict(re.findall(r"(body|condition)=%?([\w\.\-]+)",
                                        ins.rest))
                body = visit(attrs.get("body", ""), depth + 1)
                m = _TRIP_BACKEND.search(ins.rest)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = _trip_count_fallback(
                        comps.get(attrs.get("condition", ""), []))
                c.add(body.scaled(trips))
            elif op == "conditional":
                for target in re.findall(r"%([\w\.\-]+)",
                                         ins.rest.split("),")[-1]):
                    if target in comps:
                        c.add(visit(target, depth + 1))
            elif op in ("call", "async-start", "custom-call"):
                called = _CALL_ATTR.search(ins.rest)
                if called:
                    target = called.group(1).split(",")[0].strip().lstrip("%")
                    c.add(visit(target, depth + 1))
                c.bytes += _nbytes(ins.type_str)
                if op == "custom-call":
                    # Opaque launches (Pallas kernels) read their operands
                    # from HBM like a fusion boundary; charging result bytes
                    # only undercounts kernel-heavy modules.  Operand names
                    # live before the first close paren (call attrs after).
                    for o in _OPERAND.findall(ins.rest.split(")")[0]):
                        c.bytes += _nbytes(symtab.get(o, ""))
            elif any(op.startswith(k) for k in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                base = next(k for k in _COLLECTIVES if op.startswith(k))
                b = _nbytes(ins.type_str)
                n = _group_size(ins.rest, n_devices)
                c.wire[base] += _wire_bytes(base, b, n)
                c.coll_count += 1
                c.bytes += b
            elif op in _ELEMENTWISE_FLOP_OPS:
                c.flops += _nelems(ins.type_str)
                c.bytes += _nbytes(ins.type_str)
            elif op in ("copy", "copy-start", "transpose", "reshape",
                        "broadcast", "concatenate", "slice", "dynamic-slice",
                        "dynamic-update-slice", "gather", "scatter", "pad",
                        "convert", "bitcast-convert", "iota", "reverse",
                        "sort", "reduce-window", "select-and-scatter"):
                c.bytes += _nbytes(ins.type_str)
        memo[name] = c
        return c

    # fusion bodies must not be double counted: visit only from entry
    total = visit("__entry__")
    out = {"flops": total.flops, "bytes": total.bytes,
           "wire_bytes": sum(total.wire.values()),
           "coll_count": total.coll_count}
    out.update({f"wire_{k}": v for k, v in total.wire.items()})
    return out


def count_ops(hlo: str, prefix: str,
              result_type: Optional[str] = None,
              include_unreachable: bool = False) -> int:
    """Static count of instructions whose op name starts with ``prefix``,
    across every computation reachable from ENTRY (fusion bodies, loop
    bodies, the entry itself).  Not loop-multiplied -- this answers "does
    the compiled program contain op X at all", e.g. asserting a
    prepared-weights decode step holds zero ``round-nearest`` ops (no
    in-trace weight quantization).

    Dead computations (left behind by DCE after inlining) are skipped: an op
    there never executes, so counting it can mask a missing op on the live
    path or inflate a "zero ops" assertion into a false failure.  Pass
    ``include_unreachable=True`` for the old scan-everything behavior
    (debugging: "does this text mention op X anywhere").

    ``result_type`` additionally filters on the instruction's result dtype
    prefix, e.g. ``count_ops(hlo, "dot", result_type="s32")`` counts integer
    matmuls (int8 x int8 dots accumulate to s32) -- the training fast path's
    "real int8 compute in the backward" assertion."""
    comps = parse_module(hlo)
    if include_unreachable:
        names = [k for k in comps if k != "__entry__"]   # alias of ENTRY
    else:
        names = reachable_computations(comps)
    n = 0
    for name in names:
        for ins in comps[name]:
            if not ins.op.startswith(prefix):
                continue
            if (result_type is not None and not
                    ins.type_str.strip().lstrip("(").startswith(result_type)):
                continue
            n += 1
    return n


def top_contributors(hlo: str, n_devices: int = 256, top: int = 20):
    """Debug: (multiplied) byte contributions per instruction, descending."""
    comps = parse_module(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    trips: Dict[str, float] = {"__entry__": 1.0}
    out = []

    def walk(name: str, mult: float, depth: int = 0):
        if depth > 50 or name not in comps:
            return
        instrs = comps[name]
        symtab = _symbol_table(instrs)
        for ins in instrs:
            op = ins.op
            if op == "while":
                attrs = dict(re.findall(r"(body|condition)=%?([\w\.\-]+)",
                                        ins.rest))
                m = _TRIP_BACKEND.search(ins.rest)
                t = int(m.group(1)) if m else _trip_count_fallback(
                    comps.get(attrs.get("condition", ""), []))
                walk(attrs.get("body", ""), mult * t, depth + 1)
            elif op == "fusion":
                called = _CALL_ATTR.search(ins.rest)
                target = (called.group(1).split(",")[0].strip().lstrip("%")
                          if called else None)
                eff = (_fusion_param_bytes(comps.get(target, []))
                       if target else {})
                operands = _OPERAND.findall(ins.rest.split("kind=")[0])
                b = _nbytes(ins.type_str) + sum(
                    eff.get(idx, _nbytes(symtab.get(o, "")))
                    for idx, o in enumerate(operands))
                out.append((b * mult, mult, ins.op, ins.name, b))
            elif op == "dot":
                b = _nbytes(ins.type_str) + sum(
                    _nbytes(symtab.get(o, ""))
                    for o in _OPERAND.findall(ins.rest)[:3])
                out.append((b * mult, mult, ins.op, ins.name, b))
            elif op in ("copy", "transpose", "reshape", "broadcast",
                        "concatenate", "slice", "dynamic-slice",
                        "dynamic-update-slice", "gather", "scatter", "pad",
                        "convert", "sort", "reduce", "reduce-window"):
                b = _nbytes(ins.type_str)
                out.append((b * mult, mult, ins.op, ins.name, b))
    walk("__entry__", 1.0)
    out.sort(reverse=True)
    return out[:top]
