"""Version shims for the shard_map / axis-introspection APIs.

``jax.shard_map`` (with the ``check_vma`` kwarg) is the modern spelling;
older releases expose ``jax.experimental.shard_map.shard_map`` with
``check_rep`` instead.  ``shard_map(...)`` here accepts the modern kwargs
and translates for whichever implementation this environment provides.
``axis_size(name)`` shims ``jax.lax.axis_size`` (newer) via the mesh-axis
env lookup on older releases.
"""
from __future__ import annotations

import jax


if hasattr(jax.lax, "axis_size"):
    def axis_size(name: str) -> int:
        return jax.lax.axis_size(name)
else:                                      # pragma: no cover - version path
    def axis_size(name: str) -> int:
        # psum of a concrete 1 over a mesh axis constant-folds to the static
        # axis size on every release that predates jax.lax.axis_size
        return jax.lax.psum(1, name)

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:                                      # pragma: no cover - version path
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
