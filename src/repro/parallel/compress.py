"""Int8-compressed gradient all-reduce (beyond-paper, DESIGN.md Section 2).

The paper cites Markov et al. 2023 (quantized distributed training) as the
bandwidth-saving direction for gradient quantization; this implements the
TPU-idiomatic version with shard_map:

  1. split the flat gradient into n_dev chunks;
  2. quantize each chunk to int8 with a per-chunk fp32 scale (symmetric
     absmax -- the paper's Eq. 1);
  3. all_to_all the quantized chunks (each rank receives every rank's copy of
     ITS chunk);
  4. dequantize + sum locally in fp32 (the reduce);
  5. re-quantize the reduced chunk, all_gather payloads + scales;
  6. dequantize into the full reduced gradient.

Bytes on the wire per device: ~2 * N/n_dev * 1B (int8) vs 2 * N/n_dev * 4B
for a ring all-reduce in fp32 -> ~4x bisection-bandwidth saving, visible in
the roofline collective term.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.parallel.compat import axis_size, shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _quant_chunks(x: jnp.ndarray, qmax: int = 127
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n, chunk) -> (int8 (n, chunk), scales (n, 1))."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_psum_flat(flat: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Per-shard body: compressed psum of a replicated flat fp32 vector.
    flat length must be divisible by the axis size."""
    n = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    chunks = flat.reshape(n, -1)                     # (n_dev, chunk)
    q, s = _quant_chunks(chunks)
    # all_to_all: rank r receives every rank's chunk r
    q_r = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0)
    s_r = jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0)
    reduced = jnp.sum(_dequant(q_r, s_r), axis=0)    # (chunk,)
    q2, s2 = _quant_chunks(reduced[None, :])
    q_all = jax.lax.all_gather(q2[0], axis_name, axis=0)      # (n, chunk)
    s_all = jax.lax.all_gather(s2[0], axis_name, axis=0)      # (n, 1)
    return _dequant(q_all, s_all).reshape(flat.shape)


def compressed_allreduce(tree, mesh: Mesh, axis_name: str):
    """All-reduce (sum) a gradient pytree with int8 wire format.

    Inputs are replicated along ``axis_name`` holding per-shard partial
    gradients conceptually; exposed for shard_map-based DP train-step
    variants and benchmarked for the collective-bound hillclimb cell
    (tests/test_distributed.py pins parity against the fp ``psum``).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])

    def body(v):
        nn = axis_size(axis_name)
        padlen = (-v.size) % nn
        vp = jnp.pad(v, (0, padlen))
        out = int8_psum_flat(vp, axis_name)
        return out[:v.size]

    out = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                        check_vma=False)(flat)
    parts = []
    off = 0
    for x, size in zip(leaves, sizes):
        parts.append(out[off:off + size].reshape(x.shape))
        off += size
    return jax.tree_util.tree_unflatten(treedef, parts)
