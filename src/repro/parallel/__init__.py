from repro.parallel.sharding import Rules, make_rules

__all__ = ["Rules", "make_rules"]
