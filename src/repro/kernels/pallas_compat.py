"""Version shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever this environment provides so the kernels
import (and run in interpret mode) across the supported version range.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
