"""jit'd public wrappers around the Pallas kernels: padding to block/lane
alignment, granularity dispatch, and the quantize->int8-matmul->dequant
composite that realizes the paper's W8A8 recipe with real integer compute.

``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
(functional validation; the kernels TARGET TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qconfig import Granularity, QuantSpec
from repro.core.quantizer import quantize_int
from repro.kernels import int8_matmul as _mm
from repro.kernels import qdq as _qdq


def _auto_interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    r, c = x.shape
    pr, pc = (-r) % mult_r, (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@partial(jax.jit, static_argnames=("spec", "interpret"))
def fused_fake_quant(x: jnp.ndarray, spec: QuantSpec,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Pallas-fused equivalent of core.quantizer.fake_quant_nograd for 2D+
    inputs with symmetric specs (the hot training path)."""
    interp = _auto_interpret(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r, c = x2.shape
    x2p = _pad_to(x2, 8, 128)
    if spec.granularity is Granularity.PER_TOKEN:
        out = _qdq.qdq_row(x2p, spec.bits, interpret=interp)
    else:
        xf = x2.astype(jnp.float32)
        if spec.granularity is Granularity.PER_CHANNEL:
            absmax = jnp.max(jnp.abs(xf), axis=0, keepdims=True)
            scale = jnp.maximum(absmax, 1e-12) / spec.qmax
            scale = _pad_to(scale, 1, 128)
            # padded columns get scale 0 -> guard
            scale = jnp.where(scale == 0, 1.0, scale)
        else:
            absmax = jnp.max(jnp.abs(xf))
            scale = (jnp.maximum(absmax, 1e-12) / spec.qmax).reshape(1, 1)
        out = _qdq.qdq_scaled(x2p, scale, spec.bits, interpret=interp)
    return out[:r, :c].reshape(shape)


def int8_linear(x: jnp.ndarray, w: jnp.ndarray, a_spec: QuantSpec,
                w_spec: QuantSpec, out_dtype=None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Spec-driven real-int8 linear: quantize x per ``a_spec`` (per-token or
    per-tensor) and w per ``w_spec`` (per-channel or per-tensor), run the int8
    MXU matmul, apply the rank-1 dequant epilogue.  x: (..., K); w: (K, N).

    Integer payloads come from ``core.quantizer.quantize_int`` -- the same
    codec behind ``fake_quant_nograd`` -- so a backward pass built on the
    fake-quant residuals sees exactly what the kernel multiplied, by
    construction.  Caller gates eligibility (symmetric 8-bit, no blocking)
    -- see ``core.qlinear.int8_backend_supported``.
    """
    interp = _auto_interpret(interpret)
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xq, row_scale, _ = quantize_int(x2, a_spec)     # zero == 0 (symmetric)
    wq, col_scale, _ = quantize_int(w, w_spec)
    # per-tensor scales arrive (1, 1); the kernel wants rank-1 (M,1) x (1,N)
    row_scale = jnp.broadcast_to(row_scale.astype(jnp.float32),
                                 (x2.shape[0], 1))
    col_scale = jnp.broadcast_to(col_scale.astype(jnp.float32),
                                 (1, w.shape[1]))

    m, n = xq.shape[0], wq.shape[1]
    out = _mm.int8_matmul(_pad_to(xq, 128, 128), _pad_to(wq, 128, 128),
                          _pad_to(row_scale, 128, 1),
                          _pad_to(col_scale, 1, 128),
                          out_dtype=out_dtype, interpret=interp)
    return out[:m, :n].reshape(*shape[:-1], n)


def int8_prepared_linear(x: jnp.ndarray, wq: jnp.ndarray,
                         w_scale: jnp.ndarray, a_spec: QuantSpec,
                         out_dtype=None,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Real-int8 linear consuming a *pre-quantized* weight: ``wq`` (K, N)
    int8 payload and ``w_scale`` (1, N) fp32 (quantized once at engine
    construction, ``repro.infer.prepare``).  Only the activations are
    quantized in-trace, so the decode step's HLO carries no weight absmax /
    round -- the serving half of the paper's W8A8 recipe."""
    interp = _auto_interpret(interpret)
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xq, row_scale, _ = quantize_int(x2, a_spec)     # zero == 0 (symmetric)
    row_scale = jnp.broadcast_to(row_scale.astype(jnp.float32),
                                 (x2.shape[0], 1))
    col_scale = jnp.broadcast_to(w_scale.astype(jnp.float32).reshape(1, -1),
                                 (1, wq.shape[1]))
    m, n = xq.shape[0], wq.shape[1]
    out = _mm.int8_matmul(_pad_to(xq, 128, 128), _pad_to(wq, 128, 128),
                          _pad_to(row_scale, 128, 1),
                          _pad_to(col_scale, 1, 128),
                          out_dtype=out_dtype, interpret=interp)
    return out[:m, :n].reshape(*shape[:-1], n)


@partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def int8_quantized_matmul(x: jnp.ndarray, w: jnp.ndarray,
                          out_dtype=jnp.bfloat16,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Real-int8 W8A8 linear with the paper's recommended granularity pair
    baked in: per-token x, per-channel w (int8_linear with fixed specs)."""
    return int8_linear(x, w, QuantSpec(8, Granularity.PER_TOKEN),
                       QuantSpec(8, Granularity.PER_CHANNEL),
                       out_dtype=out_dtype, interpret=interpret)
