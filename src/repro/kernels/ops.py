"""jit'd public wrappers around the Pallas kernels: padding to block/lane
alignment, granularity dispatch, and the quantize->int8-matmul->dequant
composite that realizes the paper's W8A8 recipe with real integer compute.

``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
(functional validation; the kernels TARGET TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qconfig import Granularity, QuantSpec
from repro.kernels import int8_matmul as _mm
from repro.kernels import qdq as _qdq


def _auto_interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    r, c = x.shape
    pr, pc = (-r) % mult_r, (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@partial(jax.jit, static_argnames=("spec", "interpret"))
def fused_fake_quant(x: jnp.ndarray, spec: QuantSpec,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Pallas-fused equivalent of core.quantizer.fake_quant_nograd for 2D+
    inputs with symmetric specs (the hot training path)."""
    interp = _auto_interpret(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r, c = x2.shape
    x2p = _pad_to(x2, 8, 128)
    if spec.granularity is Granularity.PER_TOKEN:
        out = _qdq.qdq_row(x2p, spec.bits, interpret=interp)
    else:
        xf = x2.astype(jnp.float32)
        if spec.granularity is Granularity.PER_CHANNEL:
            absmax = jnp.max(jnp.abs(xf), axis=0, keepdims=True)
            scale = jnp.maximum(absmax, 1e-12) / spec.qmax
            scale = _pad_to(scale, 1, 128)
            # padded columns get scale 0 -> guard
            scale = jnp.where(scale == 0, 1.0, scale)
        else:
            absmax = jnp.max(jnp.abs(xf))
            scale = (jnp.maximum(absmax, 1e-12) / spec.qmax).reshape(1, 1)
        out = _qdq.qdq_scaled(x2p, scale, spec.bits, interpret=interp)
    return out[:r, :c].reshape(shape)


@partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def int8_quantized_matmul(x: jnp.ndarray, w: jnp.ndarray,
                          out_dtype=jnp.bfloat16,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Real-int8 W8A8 linear: per-token quantize x, per-channel quantize w,
    int8 MXU matmul, fused rank-1 dequant epilogue.  x: (..., K); w: (K, N)."""
    interp = _auto_interpret(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]).astype(jnp.float32)
    wf = w.astype(jnp.float32)

    row_absmax = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
    row_scale = jnp.maximum(row_absmax, 1e-12) / 127.0
    col_absmax = jnp.max(jnp.abs(wf), axis=0, keepdims=True)
    col_scale = jnp.maximum(col_absmax, 1e-12) / 127.0

    xq = jnp.clip(jnp.round(x2 / row_scale), -128, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(wf / col_scale), -128, 127).astype(jnp.int8)

    m, k = xq.shape
    n = wq.shape[1]
    xqp = _pad_to(xq, 128, 128)
    wqp = _pad_to(wq, 128, 128)
    rsp = _pad_to(row_scale, 128, 1)
    csp = _pad_to(col_scale, 1, 128)
    out = _mm.int8_matmul(xqp, wqp, rsp, csp, out_dtype=out_dtype,
                          interpret=interp)
    return out[:m, :n].reshape(*shape[:-1], n)
