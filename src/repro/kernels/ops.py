"""jit'd public wrappers around the Pallas kernels: padding to block/lane
alignment, granularity dispatch, and the quantize->int8-matmul->dequant
composite that realizes the paper's W8A8 recipe with real integer compute.

``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
(functional validation; the kernels TARGET TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qconfig import Granularity, QuantSpec, RoundMode
from repro.core.quantizer import quantize_int
from repro.kernels import int8_matmul as _mm
from repro.kernels import qdq as _qdq

_EPS = 1e-12


def _auto_interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    r, c = x.shape
    pr, pc = (-r) % mult_r, (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def fused_fake_quant_eligible(spec: Optional[QuantSpec],
                              x: jnp.ndarray) -> bool:
    """Can :func:`fused_fake_quant` stand in for
    ``core.quantizer.fake_quant_nograd`` on this call?  The kernel covers the
    hot training shapes: 2-D+ inputs, symmetric nearest-rounded specs with no
    block-wise / sqrt-domain codec."""
    return (spec is not None and x.ndim >= 2 and spec.symmetric
            and spec.block_size == 0 and not spec.sqrt_domain
            and spec.round_mode is RoundMode.NEAREST)


@partial(jax.jit, static_argnames=("spec", "interpret"))
def fused_fake_quant(x: jnp.ndarray, spec: QuantSpec,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Pallas-fused equivalent of core.quantizer.fake_quant_nograd for 2D+
    inputs with symmetric specs (the hot training path)."""
    interp = _auto_interpret(interpret)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r, c = x2.shape
    x2p = _pad_to(x2, 8, 128)
    if spec.granularity is Granularity.PER_TOKEN:
        out = _qdq.qdq_row(x2p, spec.bits, interpret=interp)
    else:
        xf = x2.astype(jnp.float32)
        if spec.granularity is Granularity.PER_CHANNEL:
            absmax = jnp.max(jnp.abs(xf), axis=0, keepdims=True)
            scale = jnp.maximum(absmax, 1e-12) / spec.qmax
            scale = _pad_to(scale, 1, 128)
            # padded columns get scale 0 -> guard
            scale = jnp.where(scale == 0, 1.0, scale)
        else:
            absmax = jnp.max(jnp.abs(xf))
            scale = (jnp.maximum(absmax, 1e-12) / spec.qmax).reshape(1, 1)
        out = _qdq.qdq_scaled(x2p, scale, spec.bits, interpret=interp)
    return out[:r, :c].reshape(shape)


def int8_payload_linear(xq: jnp.ndarray, x_scale: jnp.ndarray,
                        wq: jnp.ndarray, w_scale: jnp.ndarray,
                        out_dtype=jnp.bfloat16,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Rank-1-dequant int8 matmul on *pre-quantized* operands: ``xq`` (M, K)
    int8 + per-token/per-tensor ``x_scale``, ``wq`` (K, N) int8 + per-channel/
    per-tensor ``w_scale``.  The shared core of the spec-driven, prepared and
    custom-vjp forward entries -- padding to MXU blocks, scale broadcast to
    the kernel's (M,1) x (1,N) layout, and the output slice live here once."""
    interp = _auto_interpret(interpret)
    m, n = xq.shape[0], wq.shape[1]
    row_scale = jnp.broadcast_to(x_scale.astype(jnp.float32).reshape(-1, 1),
                                 (m, 1))
    col_scale = jnp.broadcast_to(w_scale.astype(jnp.float32).reshape(1, -1),
                                 (1, n))
    out = _mm.int8_matmul(_pad_to(xq, 128, 128), _pad_to(wq, 128, 128),
                          _pad_to(row_scale, 128, 1),
                          _pad_to(col_scale, 1, 128),
                          out_dtype=out_dtype, interpret=interp)
    return out[:m, :n]


def int8_linear(x: jnp.ndarray, w: jnp.ndarray, a_spec: QuantSpec,
                w_spec: QuantSpec, out_dtype=None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Spec-driven real-int8 linear: quantize x per ``a_spec`` (per-token or
    per-tensor) and w per ``w_spec`` (per-channel or per-tensor), run the int8
    MXU matmul, apply the rank-1 dequant epilogue.  x: (..., K); w: (K, N).

    Integer payloads come from ``core.quantizer.quantize_int`` -- the same
    codec behind ``fake_quant_nograd`` -- so a backward pass built on the
    fake-quant residuals sees exactly what the kernel multiplied, by
    construction.  Caller gates eligibility (symmetric 8-bit, no blocking)
    -- see ``core.qlinear.int8_backend_supported``.
    """
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xq, row_scale, _ = quantize_int(x2, a_spec)     # zero == 0 (symmetric)
    wq, col_scale, _ = quantize_int(w, w_spec)
    out = int8_payload_linear(xq, row_scale, wq, col_scale,
                              out_dtype=out_dtype, interpret=interpret)
    return out.reshape(*shape[:-1], w.shape[1])


def int8_prepared_linear(x: jnp.ndarray, wq: jnp.ndarray,
                         w_scale: jnp.ndarray, a_spec: QuantSpec,
                         out_dtype=None,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Real-int8 linear consuming a *pre-quantized* weight: ``wq`` (K, N)
    int8 payload and ``w_scale`` (1, N) fp32 (quantized once at engine
    construction, ``repro.infer.prepare``).  Only the activations are
    quantized in-trace, so the decode step's HLO carries no weight absmax /
    round -- the serving half of the paper's W8A8 recipe."""
    out_dtype = out_dtype or x.dtype
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    xq, row_scale, _ = quantize_int(x2, a_spec)     # zero == 0 (symmetric)
    out = int8_payload_linear(xq, row_scale, wq, w_scale,
                              out_dtype=out_dtype, interpret=interpret)
    return out.reshape(*shape[:-1], wq.shape[1])


# ---------------------------------------------------------------------------
# Training backward: both matmuls on the int8 MXU path, consuming the stored
# forward payloads.  The scale algebra that keeps the epilogues rank-1:
#
#   dx[m,k] = sum_n g[m,n] * (w_int[k,n]*sw[n])     fold sw into g, quantize
#           ~= sh[m] * sum_n hq[m,n] * w_int[k,n]   h = g*sw per-TOKEN (sh)
#   dW[k,n] = sum_m (x_int[m,k]*sx[m]) * g[m,n]     fold sx into g, quantize
#           ~= sh[n] * sum_m x_int[m,k] * hq[m,n]   h = g*sx per-CHANNEL (sh)
#
# Folding the counterpart operand's dequant scale into the fp gradient moves
# every scale off the contracted axis, so the int32 accumulators dequantize
# with one broadcast multiply -- and the int8 residual payloads are consumed
# exactly as stored.  The absmax reduce runs outside (one fused XLA pass over
# g, nothing materialized); round/clip/cast run inside the kernel grid.
# ---------------------------------------------------------------------------

def int8_bwd_dx(g: jnp.ndarray, wq: jnp.ndarray, w_scale: jnp.ndarray,
                out_dtype=None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """dx = qdq_token(g * w_scale) @ wq^T.  g: fp (M, N); wq: int8 (K, N)
    stored forward payload; w_scale: fp32 per-channel (1, N) or per-tensor
    (1, 1) -> (M, K) out_dtype."""
    interp = _auto_interpret(interpret)
    out_dtype = out_dtype or g.dtype
    m, n = g.shape
    k = wq.shape[0]
    fold = jnp.broadcast_to(w_scale.astype(jnp.float32).reshape(1, -1),
                            (1, n))
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)) * fold, axis=1,
                     keepdims=True)
    q_scale = jnp.maximum(absmax, _EPS) / 127.0
    out = _mm.int8_matmul_nt(_pad_to(g, 128, 128), _pad_to(wq, 128, 128),
                             _pad_to(fold, 1, 128), _pad_to(q_scale, 128, 1),
                             out_dtype=out_dtype, interpret=interp)
    return out[:m, :k]


def int8_bwd_dw(xq: jnp.ndarray, x_scale: jnp.ndarray, g: jnp.ndarray,
                out_dtype=jnp.float32,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """dW = xq^T @ qdq_channel(g * x_scale).  xq: int8 (M, K) stored forward
    payload; x_scale: fp32 per-token (M, 1) or per-tensor (1, 1); g: fp
    (M, N) -> (K, N) out_dtype."""
    interp = _auto_interpret(interpret)
    m, n = g.shape
    k = xq.shape[1]
    fold = jnp.broadcast_to(x_scale.astype(jnp.float32).reshape(-1, 1),
                            (m, 1))
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)) * fold, axis=0,
                     keepdims=True)
    q_scale = jnp.maximum(absmax, _EPS) / 127.0
    out = _mm.int8_matmul_tn(_pad_to(xq, 128, 128), _pad_to(g, 128, 128),
                             _pad_to(fold, 128, 1), _pad_to(q_scale, 1, 128),
                             out_dtype=out_dtype, interpret=interp)
    return out[:k, :n]


@partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def int8_quantized_matmul(x: jnp.ndarray, w: jnp.ndarray,
                          out_dtype=jnp.bfloat16,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Real-int8 W8A8 linear with the paper's recommended granularity pair
    baked in: per-token x, per-channel w (int8_linear with fixed specs)."""
    return int8_linear(x, w, QuantSpec(8, Granularity.PER_TOKEN),
                       QuantSpec(8, Granularity.PER_CHANNEL),
                       out_dtype=out_dtype, interpret=interpret)
