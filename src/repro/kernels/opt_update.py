"""Fused 8-bit AdamW update kernel (VPU, one VMEM pass) -- paper Section 4.4
deployed the way Dettmers-style 8-bit optimizers actually ship.

The reference loop in ``optim/adamw.py`` decodes each int8 moment into a full
fp32 materialization, runs the update as unfused XLA elementwise ops, and
re-encodes: roughly six HBM round trips over moment-sized buffers per step,
which erases most of the 4x storage win at the bandwidth level.  This kernel
executes the whole step per (block_rows, block_size) tile in one VMEM pass:

  stream in   grad tile + fp32 param tile + int8 m1/m2 payloads + fp32
              scale/zero sidecars + an SMEM scalar vector
              (clip, lr, b1, b2, eps, wd, c1, c2)
  in-register dequantize m1/m2 (square for sqrt-domain m2), apply the
              bias-corrected AdamW update with the global-norm clip factor
              folded into g, blockwise absmax (or min/max for asymmetric
              codecs) and re-quantize both moments
  write out   updated param + new int8 payloads + new scales/zeros + a
              per-tile partial sum of ||lr * update||^2 (the update_norm stat)

one read and one write per buffer instead of ~6.  The row layout is exactly
``core.qadam``'s blockwise codec: each moment row is one quantization block of
``spec.block_size`` elements with its own (scale, zero) pair, so payloads are
consumed and produced in their stored form -- the optimizer counterpart of the
int8 residuals of kernels/int8_matmul.py.

Arithmetic follows ``optim/adamw.py``'s decode -> update -> encode loop op
for op (same reduction axis, same ``maximum(.., 1e-12)`` guards), so the two
paths agree to float rounding; tests/test_opt_update.py pins the parity.
Fully-padded bucket rows (added to round the row count up to a tile) carry
scale == 0 sidecars; decode only multiplies by the scale (no division), and
the encode guard ``maximum(absmax, 1e-12)`` keeps the fresh scales nonzero,
so padding can never emit NaN/Inf.

``REPRO_OPT_BLOCK`` overrides the tile row count (here and in qdq.py's
kernels, via the shared ``qdq.default_block_rows`` read at call time) for
block-size autotune sweeps.

TARGET: TPU (pl.pallas_call + BlockSpec).  VALIDATED: interpret=True on CPU
against the adamw.py loop (tests/test_opt_update.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams
# kernel tile row count; the shared REPRO_OPT_BLOCK knob (read at call time)
# retiles this kernel and the qdq family together for autotune sweeps
from repro.kernels.qdq import default_block_rows as tile_rows

_EPS = 1e-12

#: SMEM scalar vector layout (one fp32 slot per AdamW hyper/step scalar).
SCALARS = ("clip", "lr", "b1", "b2", "eps", "wd", "c1", "c2")


class MomentCodec(NamedTuple):
    """Static (hashable) per-moment codec parameters the kernel bakes in --
    mirrors the QuantSpec fields the blockwise int path consumes."""
    qmin: int
    qmax: int
    symmetric: bool
    sqrt_domain: bool


def codec_of(spec) -> MomentCodec:
    return MomentCodec(qmin=spec.qmin, qmax=spec.qmax,
                       symmetric=spec.symmetric,
                       sqrt_domain=spec.sqrt_domain)


def _dequant(q_ref, s_ref, z_ref, codec: MomentCodec) -> jnp.ndarray:
    """dequantize_int + (sqrt-domain square), blockwise rows.  Multiplies by
    the stored scale only -- 0-scale padding rows decode to exact 0."""
    deq = s_ref[...] * (q_ref[...].astype(jnp.float32) + z_ref[...])
    if codec.sqrt_domain:
        deq = jnp.square(deq)
    return deq


def _requant(x: jnp.ndarray, codec: MomentCodec
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """quantize_int's blockwise row codec in-register: per-row scale/zero over
    the last dim (one quantization block per row).  Same op order and 1e-12
    guards as core.quantizer.compute_scale_zero, so re-encoded payloads match
    the loop path's bit for bit."""
    if codec.sqrt_domain:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    if codec.symmetric:
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = jnp.maximum(absmax, _EPS) / codec.qmax
        zero = jnp.zeros_like(scale)
    else:
        xmin = jnp.min(x, axis=-1, keepdims=True)
        xmax = jnp.max(x, axis=-1, keepdims=True)
        scale = jnp.maximum(xmax - xmin, _EPS) / (codec.qmax - codec.qmin)
        zero = jnp.round(xmin / scale) - codec.qmin
    q = jnp.clip(jnp.round(x / scale) - zero, codec.qmin, codec.qmax)
    return q.astype(jnp.int8), scale, zero


def _adamw_kernel(sc_ref, g_ref, p_ref, q1_ref, s1_ref, z1_ref,
                  q2_ref, s2_ref, z2_ref,
                  po_ref, q1o_ref, s1o_ref, z1o_ref,
                  q2o_ref, s2o_ref, z2o_ref, un_ref, *,
                  m1: MomentCodec, m2: MomentCodec, wd_on: bool):
    clip, lr, b1, b2 = sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3]
    eps, wd, c1, c2 = sc_ref[4], sc_ref[5], sc_ref[6], sc_ref[7]

    g = g_ref[...].astype(jnp.float32) * clip
    p = p_ref[...].astype(jnp.float32)
    mom1 = b1 * _dequant(q1_ref, s1_ref, z1_ref, m1) + (1.0 - b1) * g
    mom2 = b2 * _dequant(q2_ref, s2_ref, z2_ref, m2) \
        + (1.0 - b2) * jnp.square(g)

    upd = (mom1 / c1) / (jnp.sqrt(mom2 / c2) + eps)
    if wd_on:
        upd = upd + wd * p
    delta = lr * upd
    po_ref[...] = (p - delta).astype(po_ref.dtype)
    un_ref[0, 0] = jnp.sum(jnp.square(delta))

    q1o_ref[...], s1o_ref[...], z1o_ref[...] = _requant(mom1, m1)
    q2o_ref[...], s2o_ref[...], z2o_ref[...] = _requant(mom2, m2)


def fused_adamw_blocks(g: jnp.ndarray, p: jnp.ndarray,
                       m1_q: jnp.ndarray, m1_scale: jnp.ndarray,
                       m1_zero: jnp.ndarray,
                       m2_q: jnp.ndarray, m2_scale: jnp.ndarray,
                       m2_zero: jnp.ndarray,
                       scalars: jnp.ndarray, *,
                       m1_codec: MomentCodec, m2_codec: MomentCodec,
                       weight_decay: bool,
                       block_rows: Optional[int] = None,
                       interpret: bool = False):
    """One fused AdamW step over a (rows, block_size) bucket.

    ``g``/``p``: fp (rows, bs); ``m?_q``: int8 (rows, bs); ``m?_scale`` /
    ``m?_zero``: fp32 (rows, 1); ``scalars``: fp32 (8,) in :data:`SCALARS`
    order.  Row count must be a multiple of the tile (adamw.py pads; padded
    rows stream 0 payloads / 0 scales and write exact-0 params).

    Returns (p_new, (m1_q, m1_scale, m1_zero), (m2_q, ..), update_sumsq)
    where ``update_sumsq`` is sum ||lr * upd||^2 over the bucket (the
    update_norm partial -- padding rows contribute exact 0).
    """
    rows, bs = g.shape
    br = min(block_rows or tile_rows(), rows)
    assert rows % br == 0, (rows, br)
    grid = (rows // br,)
    data = pl.BlockSpec((br, bs), lambda i: (i, 0))
    side = pl.BlockSpec((br, 1), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_adamw_kernel, m1=m1_codec, m2=m2_codec,
                          wd_on=weight_decay),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  data, data, data, side, side, data, side, side],
        out_specs=(data, data, side, side, data, side, side, part),
        out_shape=(jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct((rows, bs), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, bs), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((grid[0], 1), jnp.float32)),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(scalars, g, p, m1_q, m1_scale, m1_zero, m2_q, m2_scale, m2_zero)
    p_new, q1, s1, z1, q2, s2, z2, un = out
    return p_new, (q1, s1, z1), (q2, s2, z2), jnp.sum(un)
