"""Int8 x int8 -> int32 tiled matmuls with fused per-row/per-col dequant.

The real-compute path the paper's fake quantization simulates: TPU v5e MXUs
run int8 at ~2x bf16 throughput (394 vs 197 TOPS).  Tiling is MXU-aligned
(128x128x128 by default): A (bm, bk) x B (bk, bn) accumulated in an int32
VMEM scratch across the k grid dim; the epilogue applies the paper's
W-per-channel x A-per-token scale pair -- a rank-1 rescale, which is exactly
why that granularity pairing is the hardware-efficient one (Section 3.2).

Three layouts cover the full training step (Fig. 1):

  * :func:`int8_matmul`    -- y  = Xq  @ Wq   (forward; both operands int8)
  * :func:`int8_matmul_nt` -- dx = Gq  @ Wq^T (backward input-grad)
  * :func:`int8_matmul_tn` -- dW = Xq^T @ Gq  (backward weight-grad)

The transposed kernels take the *fp* gradient plus a fold scale and quantize
it inside the grid (a fused quant prologue): the counterpart operand's scale
is element-folded into g before rounding, which moves every scale off the
contracted axis and keeps the dequant epilogue rank-1 -- see ops.py for the
scale algebra.  The stored int8 forward payloads (w for dx, x for dW) are
consumed directly; no padded int8 intermediate ever lands in HBM.

Scales equal to 0 (zero-padding of non-128-multiple shapes) are guarded to
1.0 in both the quant prologue and the dequant epilogue so ragged shapes
cannot emit NaN/Inf (0/0) from the padding lanes.

TARGET: TPU.  VALIDATED: interpret=True vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

BM, BN, BK = 128, 128, 128


def scale_guard(scale: jnp.ndarray) -> jnp.ndarray:
    """0-scale padding lanes -> 1.0 (their payloads are 0, so the product is
    still 0; the guard only prevents 0/0 NaN in the quant prologue and keeps
    the epilogue multiply clean).  The canonical guard for every kernel that
    consumes zero-padded scale sidecars (matmul epilogues, decode attention,
    q8 prefill); oracles mirror it as ``ref._guard_ref`` and the reference
    KV path as ``models.attention._kv_guard``."""
    return jnp.where(scale == 0.0, 1.0, scale)


def _int8_matmul_kernel(x_ref, w_ref, rs_ref, cs_ref, o_ref, acc_ref, *,
                        nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * scale_guard(rs_ref[...])
                      * scale_guard(cs_ref[...])).astype(o_ref.dtype)


def int8_matmul(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                col_scale: jnp.ndarray, out_dtype=jnp.bfloat16,
                bm: int = BM, bn: int = BN, bk: int = BK,
                interpret: bool = False) -> jnp.ndarray:
    """x: int8 (M, K); w: int8 (K, N); row_scale fp32 (M, 1);
    col_scale fp32 (1, N) -> (M, N) out_dtype.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, row_scale, col_scale)


# ---------------------------------------------------------------------------
# Transposed layouts for the training backward (fused gradient-quant prologue)
# ---------------------------------------------------------------------------

def _int8_matmul_nt_kernel(g_ref, w_ref, fs_ref, qs_ref, o_ref, acc_ref, *,
                           nk: int):
    """dx block: quantize (g * fold) per-token in VMEM, dot against the int8
    weight payload with N contracted, dequant by the row scale."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qs = scale_guard(qs_ref[...].astype(jnp.float32))              # (bm, 1)
    h = g_ref[...].astype(jnp.float32) * fs_ref[...].astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        hq, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * qs).astype(o_ref.dtype)


def int8_matmul_nt(g: jnp.ndarray, w: jnp.ndarray, fold_scale: jnp.ndarray,
                   q_scale: jnp.ndarray, out_dtype=jnp.bfloat16,
                   bm: int = BM, bk: int = BK, bn: int = BN,
                   interpret: bool = False) -> jnp.ndarray:
    """dx = qdq_token(g * fold_scale) @ w^T with real int8 compute.

    g: fp (M, N) output gradient; w: int8 (K, N) stored forward payload;
    fold_scale fp32 (1, N) = the weight's per-channel dequant scales;
    q_scale fp32 (M, 1) = per-token quant scale of g*fold (absmax/127,
    computed by the ops.py wrapper) -> (M, K) out_dtype.

    Shapes must be multiples of the block sizes (ops.py pads); 0-padded
    q_scale rows are guarded inside the kernel.
    """
    m, n = g.shape
    k, n2 = w.shape
    assert n == n2, (g.shape, w.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    grid = (m // bm, k // bk, n // bn)
    return pl.pallas_call(
        functools.partial(_int8_matmul_nt_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(g, w, fold_scale, q_scale)


def _int8_matmul_tn_kernel(x_ref, g_ref, fs_ref, qs_ref, o_ref, acc_ref, *,
                           nk: int):
    """dW block: quantize (g * fold) per-channel in VMEM, dot against the
    int8 activation payload with M (tokens) contracted, dequant by the col
    scale."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qs = scale_guard(qs_ref[...].astype(jnp.float32))              # (1, bn)
    h = g_ref[...].astype(jnp.float32) * fs_ref[...].astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], hq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * qs).astype(o_ref.dtype)


def int8_matmul_tn(x: jnp.ndarray, g: jnp.ndarray, fold_scale: jnp.ndarray,
                   q_scale: jnp.ndarray, out_dtype=jnp.float32,
                   bk: int = BK, bn: int = BN, bm: int = BM,
                   interpret: bool = False) -> jnp.ndarray:
    """dW = x^T @ qdq_channel(g * fold_scale) with real int8 compute.

    x: int8 (M, K) stored forward payload; g: fp (M, N) output gradient;
    fold_scale fp32 (M, 1) = the activation's per-token dequant scales;
    q_scale fp32 (1, N) = per-channel quant scale of g*fold (absmax/127,
    computed by the ops.py wrapper) -> (K, N) out_dtype.

    Shapes must be multiples of the block sizes (ops.py pads); 0-padded
    q_scale cols are guarded inside the kernel.
    """
    m, k = x.shape
    m2, n = g.shape
    assert m == m2, (x.shape, g.shape)
    bk, bn, bm = min(bk, k), min(bn, n), min(bm, m)
    grid = (k // bk, n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_int8_matmul_tn_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, g, fold_scale, q_scale)
