"""Int8 x int8 -> int32 tiled matmul with fused per-row/per-col dequant.

The real-compute path the paper's fake quantization simulates: TPU v5e MXUs
run int8 at ~2x bf16 throughput (394 vs 197 TOPS).  Tiling is MXU-aligned
(128x128x128 by default): A (bm, bk) x B (bk, bn) accumulated in an int32
VMEM scratch across the k grid dim; the epilogue applies the paper's
W-per-channel x A-per-token scale pair -- a rank-1 rescale, which is exactly
why that granularity pairing is the hardware-efficient one (Section 3.2).

TARGET: TPU.  VALIDATED: interpret=True vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

BM, BN, BK = 128, 128, 128


def _int8_matmul_kernel(x_ref, w_ref, rs_ref, cs_ref, o_ref, acc_ref, *,
                        nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * rs_ref[...] * cs_ref[...]).astype(o_ref.dtype)


def int8_matmul(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                col_scale: jnp.ndarray, out_dtype=jnp.bfloat16,
                bm: int = BM, bn: int = BN, bk: int = BK,
                interpret: bool = False) -> jnp.ndarray:
    """x: int8 (M, K); w: int8 (K, N); row_scale fp32 (M, 1);
    col_scale fp32 (1, N) -> (M, N) out_dtype.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, row_scale, col_scale)
