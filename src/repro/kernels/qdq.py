"""Fused fake-quantization Pallas kernel (VPU, one VMEM pass).

The paper's fake-quant op (Eq. 1) is elementwise-plus-row-reduction.  Executed
naively it costs three HBM round trips (absmax reduce, quantize, dequantize);
fused it is one read + one write.  Tiling: (block_rows, features) VMEM tiles,
row-aligned so per-token scales never cross tile boundaries; features padded
to the 128-lane register width by the ops.py wrapper.

TARGET: TPU (pl.pallas_call + BlockSpec).  VALIDATED: interpret=True on CPU
against ref.py (tests/test_kernels.py sweeps shapes/dtypes/bits).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def default_block_rows() -> int:
    """Tile row count used when the caller passes none.  ``REPRO_OPT_BLOCK``
    overrides it here and in kernels/opt_update.py -- one knob, read at call
    time, for block-size autotune sweeps across both VPU kernel families."""
    v = os.environ.get("REPRO_OPT_BLOCK", "")
    return int(v) if v else DEFAULT_BLOCK_ROWS


def _qdq_row_kernel(x_ref, o_ref, *, qmax: int):
    """Per-row (per-token) symmetric fake quantization on one tile."""
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def _qdq_scaled_kernel(x_ref, scale_ref, o_ref, *, qmax: int):
    """Fake quantization with an externally supplied broadcastable scale
    (per-tensor or per-channel: the reduction spans tiles, so the scale is
    computed outside and streamed in)."""
    x = x_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def qdq_row(x: jnp.ndarray, bits: int = 8,
            block_rows: int = 0,
            interpret: bool = False) -> jnp.ndarray:
    """x: (rows, features) -> fake-quantized, per-row scales."""
    rows, feat = x.shape
    qmax = 2 ** (bits - 1) - 1
    block_rows = min(block_rows or default_block_rows(), rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_qdq_row_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, feat), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def qdq_scaled(x: jnp.ndarray, scale: jnp.ndarray, bits: int = 8,
               block_rows: int = 0,
               interpret: bool = False) -> jnp.ndarray:
    """x: (rows, features); scale: (1, features) per-channel or (1, 1)
    per-tensor."""
    rows, feat = x.shape
    qmax = 2 ** (bits - 1) - 1
    block_rows = min(block_rows or default_block_rows(), rows)
    grid = (pl.cdiv(rows, block_rows),)
    scol = scale.shape[1]
    return pl.pallas_call(
        functools.partial(_qdq_scaled_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
                  pl.BlockSpec((1, scol), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
