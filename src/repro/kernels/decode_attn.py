"""Fused int8-KV decode attention: attend directly on the quantized cache.

The serving hot path stores the KV cache as int8 payloads with per-(position,
head) fp32 scale sidecars (``policy.kv_spec()``), but the reference decode
path dequantizes the *whole* buffer to fp and re-casts before attending --
every step, every layer.  At the memory roofline that reads ~5-9x the
quantized bytes (int8 read + fp materialize + fp re-read) and erases the
storage win exactly where it matters most (Jorgensen 2025; Bondarenko et al.
2021 make the same point for kernels generally: the low-bit payload must be
consumed *in-kernel*).

This kernel runs one decode step per (slot, kv-head) grid cell:

  stream in   int8 K/V payload tiles + fp32 scale sidecars (BlockSpec DMA),
              the (G, hd) query tile of the head group, and the step's fresh
              fp K/V rows
  in-register dequantize by folding the per-position scales into the online-
              softmax scores (K) and probabilities (V) -- rank-1 multiplies,
              no fp K/V tile ever materializes; scale==0 padding rows are
              guarded like the NT/TN matmul kernels
  fused       quantize the new K/V row (symmetric nearest per-(position,
              head), the `_kv_quant` codec) and scatter payload + scales into
              the cache row ``pos[b]`` via scalar-prefetch-indexed output
              blocks aliased onto the cache buffers
  write out   the (G, hd) context tile and ONE int8 row + scale pair per
              K/V buffer -- one read of the int8 cache, one int8 row write.

Per-slot ``pos`` (B,) drives both the validity mask (cache rows < pos[b])
and the scatter target, so ragged continuous-batching slots are handled
in-kernel.  ``REPRO_DECODE_BLOCK`` overrides the kv tile length for
block-size autotune sweeps (``benchmarks/serve_throughput.py --sweep``).

TARGET: TPU.  VALIDATED: interpret=True vs the dequantize-whole-buffer
reference path (tests/test_decode_attn.py).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attn import online_softmax_update
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BLOCK_K = 256

#: fp32 VPU register tile is (8 sublanes, 128 lanes); a (G, hd) query tile
#: with G < 8 occupies a ragged partial tile per grid cell.  The wrappers
#: zero-pad the query-head dim up to this so every tile is lane-aligned --
#: pad rows cost nothing real (softmax over zero scores) and are sliced off.
Q_TILE_SUBLANES = 8

_EPS = 1e-12


def default_block_k() -> int:
    """KV tile length used when the caller passes none.  ``REPRO_DECODE_BLOCK``
    overrides it (read at call time) for block-size autotune sweeps, the
    decode-kernel counterpart of ``REPRO_OPT_BLOCK``."""
    v = os.environ.get("REPRO_DECODE_BLOCK", "")
    return int(v) if v else DEFAULT_BLOCK_K


def effective_block_k(s: int, block_k: Optional[int] = None) -> int:
    """The kv tile length :func:`decode_attention` will actually compile for
    an ``s``-row cache: the requested (or ``REPRO_DECODE_BLOCK``/default)
    tile clamped to ``s`` and shrunk to a divisor.  Exposed so reporting
    (``Engine.path_summary``) names the compiled tile, not the request."""
    bk = min(block_k or default_block_k(), s)
    while s % bk:
        bk //= 2
    return bk


def fused_decode_enabled() -> bool:
    """Should the int8-KV attention kernels replace the dequantize-whole-
    buffer reference path?  Default: on TPU (the kernels' target); interpret
    mode is functional but slow, so CPU keeps the reference path unless
    ``REPRO_FUSED_DECODE=1`` forces it (tests/CI pin ``1``; ``0`` forces the
    reference path everywhere)."""
    force = os.environ.get("REPRO_FUSED_DECODE", "")
    if force:
        return force != "0"
    return jax.default_backend() == "tpu"


# never-written cache rows carry scale == 0 sidecars (buffers init to
# zeros); their payloads are 0 and the validity mask excludes them anyway,
# but guard to 1.0 so no reciprocal/dequant on padding lanes can emit
# NaN/Inf -- the canonical guard from the int8 matmul kernel family
from repro.kernels.int8_matmul import scale_guard as _guard


def _lane_align_q(q: jnp.ndarray):
    """Pad the (G, hd) query tile up to :data:`Q_TILE_SUBLANES` rows when the
    GQA group is small (``n_heads // n_kv_heads < 8``): every grid cell then
    streams a full (8, lane) register tile instead of a ragged one.  Pad rows
    are zero queries -- their scores are 0 everywhere, the online softmax
    stays finite, and their context rows are sliced off by
    :func:`_lane_trim_ctx`.  Real rows are bit-identical to the unpadded
    launch (row-independent math).  Returns ``(q_padded, g_padded, g)``."""
    b, kh, g, hd = q.shape
    if g >= Q_TILE_SUBLANES:
        return q, g, g
    gp = Q_TILE_SUBLANES
    q = jnp.concatenate(
        [q, jnp.zeros((b, kh, gp - g, hd), q.dtype)], axis=2)
    return q, gp, g


def _lane_trim_ctx(ctx: jnp.ndarray, g_real: int) -> jnp.ndarray:
    return ctx if ctx.shape[2] == g_real else ctx[:, :, :g_real]


def _decode_attn_kernel(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                        nk_ref, nv_ref,
                        o_ref, okq_ref, oks_ref, ovq_ref, ovs_ref,
                        m_ref, l_ref, acc_ref, *,
                        bk: int, nblk: int, scale: float,
                        qmin: int, qmax: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tiles entirely past the slot's valid rows contribute nothing: skip
    @pl.when(ki * bk < pos)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
        kt = kq_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
        ksc = _guard(ks_ref[0, :, 0, :].astype(jnp.float32))   # (bk, 1)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * ksc[:, 0][None, :]          # fold K dequant into the scores
        t = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(t < pos, s, -1e30)    # prior rows only; new row below
        vsc = _guard(vs_ref[0, :, 0, :].astype(jnp.float32))   # (bk, 1)
        online_softmax_update(s, vq_ref[0, :, 0, :].astype(jnp.float32),
                              m_ref, l_ref, acc_ref,
                              v_fold=vsc[:, 0][None, :])

    @pl.when(ki == nblk - 1)
    def _done():
        # fused quantize + scatter of the step's K/V row: the attention reads
        # the freshly *quantized* values (parity with the stored form), and
        # the int8 payload + scale land in the cache row ``pos[b]`` through
        # the scalar-prefetch-indexed, cache-aliased output blocks.
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
        knew = nk_ref[0, 0].astype(jnp.float32).reshape(1, -1)  # (1, hd)
        vnew = nv_ref[0, 0].astype(jnp.float32).reshape(1, -1)
        ks_new = jnp.maximum(jnp.max(jnp.abs(knew), axis=-1, keepdims=True),
                             _EPS) / qmax
        kq_new = jnp.clip(jnp.round(knew / ks_new), qmin, qmax)
        vs_new = jnp.maximum(jnp.max(jnp.abs(vnew), axis=-1, keepdims=True),
                             _EPS) / qmax
        vq_new = jnp.clip(jnp.round(vnew / vs_new), qmin, qmax)
        s_new = jax.lax.dot_general(q, kq_new * ks_new,
                                    (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s_new)                     # (G, 1)
        alpha = jnp.exp(m_prev - m_new)
        p_new = jnp.exp(s_new - m_new)
        l = alpha * l_ref[...] + p_new
        acc = acc_ref[...] * alpha + p_new * (vq_new * vs_new)
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        okq_ref[0, 0, 0] = kq_new[0].astype(okq_ref.dtype)
        oks_ref[0, 0, 0, 0] = ks_new[0, 0]
        ovq_ref[0, 0, 0] = vq_new[0].astype(ovq_ref.dtype)
        ovs_ref[0, 0, 0, 0] = vs_new[0, 0]


def decode_attention(q: jnp.ndarray,
                     kq: jnp.ndarray, ks: jnp.ndarray,
                     vq: jnp.ndarray, vs: jnp.ndarray,
                     new_k: jnp.ndarray, new_v: jnp.ndarray,
                     pos: jnp.ndarray, *,
                     qmin: int = -128, qmax: int = 127,
                     block_k: Optional[int] = None,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                jnp.ndarray, jnp.ndarray]:
    """One fused decode-attention step on the int8 KV cache.

    q: (B, K, G, hd) fp grouped queries; kq/vq: (B, S, K, hd) int8 payloads;
    ks/vs: (B, S, K, 1) fp32 scale sidecars; new_k/new_v: (B, K, hd) fp rows
    for this step (RoPE already applied); pos: (B,) int32 per-slot validity
    lengths == scatter rows.  Returns ``(ctx, kq', ks', vq', vs')`` where the
    primed buffers are the caches with the new row written (aliased in
    place: the inputs are donated).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, kh, g, hd = q.shape
    q, g, g_real = _lane_align_q(q)
    s = kq.shape[1]
    bk = effective_block_k(s, block_k)
    nblk = s // bk
    scale = 1.0 / math.sqrt(hd)

    def row(pos_ref, bi):
        # scatter target.  pos == s is the degenerate freed-slot case (a
        # length-finished slot keeps decoding in the batch with its stale
        # position until readmission): clamp to the last row, matching the
        # reference path's dynamic_update_slice semantics -- the row is
        # never read back (masks stop at the slot's next admitted length)
        # and the slot's output is discarded by the scheduler.
        return jnp.minimum(pos_ref[bi], s - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, k, j, pos_ref: (b, k, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, k, j, pos_ref: (b, j, k, 0)),
            pl.BlockSpec((1, bk, 1, 1), lambda b, k, j, pos_ref: (b, j, k, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, k, j, pos_ref: (b, j, k, 0)),
            pl.BlockSpec((1, bk, 1, 1), lambda b, k, j, pos_ref: (b, j, k, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, k, j, pos_ref: (b, k, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, k, j, pos_ref: (b, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, k, j, pos_ref: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, k, j, pos_ref: (b, row(pos_ref, b), k, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, k, j, pos_ref: (b, row(pos_ref, b), k, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, k, j, pos_ref: (b, row(pos_ref, b), k, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, k, j, pos_ref: (b, row(pos_ref, b), k, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # running sum
            pltpu.VMEM((g, hd), jnp.float32),     # accumulator
        ],
    )
    ctx, okq, oks, ovq, ovs = pl.pallas_call(
        functools.partial(_decode_attn_kernel, bk=bk, nblk=nblk, scale=scale,
                          qmin=qmin, qmax=qmax),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
            jax.ShapeDtypeStruct(kq.shape, kq.dtype),
            jax.ShapeDtypeStruct(ks.shape, ks.dtype),
            jax.ShapeDtypeStruct(vq.shape, vq.dtype),
            jax.ShapeDtypeStruct(vs.shape, vs.dtype),
        ],
        # the int8 caches and scale sidecars update in place: only the
        # pos[b] row blocks are DMA'd back
        input_output_aliases={2: 1, 3: 2, 4: 3, 5: 4},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, kq, ks, vq, vs, new_k, new_v)
    return _lane_trim_ctx(ctx, g_real), okq, oks, ovq, ovs


def _paged_decode_attn_kernel(pos_ref, pt_ref, *refs, **kw):
    """Paged wrapper: identical compute to :func:`_decode_attn_kernel` -- the
    page table is consumed purely by the BlockSpec index maps (physical-page
    DMA routing), never by the kernel body, so the in-register
    dequant-into-softmax and the fused row quantize+scatter are reused
    verbatim."""
    del pt_ref
    _decode_attn_kernel(pos_ref, *refs, **kw)


def decode_attention_paged(q: jnp.ndarray,
                           kq: jnp.ndarray, ks: jnp.ndarray,
                           vq: jnp.ndarray, vs: jnp.ndarray,
                           new_k: jnp.ndarray, new_v: jnp.ndarray,
                           pos: jnp.ndarray, page_table: jnp.ndarray, *,
                           qmin: int = -128, qmax: int = 127,
                           interpret: Optional[bool] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray, jnp.ndarray]:
    """One fused decode-attention step on the *paged* int8 KV pool.

    q: (B, K, G, hd) fp grouped queries; kq/vq: (P, page, K, hd) int8 page
    pools (no slot axis -- pages are shared across slots); ks/vs:
    (P, page, K, 1) fp32 scale sidecar pools; new_k/new_v: (B, K, hd) fp rows;
    pos: (B,) validity lengths; page_table: (B, max_pages) int32 mapping each
    slot's logical page j to a physical pool page (unmapped entries point at
    the trash page 0).

    The grid is the dense kernel's ``(slots, kv_heads, kv_tiles)`` with the
    kv tile pinned to one page: both ``pos`` and the page table are scalar-
    prefetched, and the *input* index maps route logical tile ``j`` to
    physical page ``page_table[b, min(j, ceil(pos[b]/page)-1)]`` -- tiles
    past the slot's live length are clamped to the last live page, so no
    slot ever DMAs more than ``ceil(pos[b]/page)`` distinct pages (their
    compute is skipped by the ``ki*bk < pos`` guard regardless).  The fused
    new-row scatter targets ``(page_table[b, pos[b]//page], pos[b]%page)``.
    Returns ``(ctx, kq', ks', vq', vs')`` with the pools aliased in place.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, kh, g, hd = q.shape
    q, g, g_real = _lane_align_q(q)
    npages, page = kq.shape[0], kq.shape[1]
    maxp = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    last = maxp * page - 1

    def rd(bi, j, pos_ref, pt_ref):
        live_last = jnp.maximum((pos_ref[bi] + page - 1) // page - 1, 0)
        return pt_ref[bi, jnp.minimum(j, live_last)]

    def wr(bi, pos_ref, pt_ref):
        # clamp like the dense kernel: pos == maxp*page is the degenerate
        # freed-slot case; the row lands in the slot's last mapped page and
        # is never read back
        p = jnp.minimum(pos_ref[bi], last)
        return pt_ref[bi, p // page], p % page

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b, k, j, pos_ref, pt_ref: (b, k, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref:
                         (rd(b, j, pos_ref, pt_ref), 0, k, 0)),
            pl.BlockSpec((1, page, 1, 1),
                         lambda b, k, j, pos_ref, pt_ref:
                         (rd(b, j, pos_ref, pt_ref), 0, k, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref:
                         (rd(b, j, pos_ref, pt_ref), 0, k, 0)),
            pl.BlockSpec((1, page, 1, 1),
                         lambda b, k, j, pos_ref, pt_ref:
                         (rd(b, j, pos_ref, pt_ref), 0, k, 0)),
            pl.BlockSpec((1, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref: (b, k, 0)),
            pl.BlockSpec((1, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref: (b, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b, k, j, pos_ref, pt_ref: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref:
                         wr(b, pos_ref, pt_ref) + (k, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, k, j, pos_ref, pt_ref:
                         wr(b, pos_ref, pt_ref) + (k, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, k, j, pos_ref, pt_ref:
                         wr(b, pos_ref, pt_ref) + (k, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, k, j, pos_ref, pt_ref:
                         wr(b, pos_ref, pt_ref) + (k, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # running sum
            pltpu.VMEM((g, hd), jnp.float32),     # accumulator
        ],
    )
    ctx, okq, oks, ovq, ovs = pl.pallas_call(
        functools.partial(_paged_decode_attn_kernel, bk=page, nblk=maxp,
                          scale=scale, qmin=qmin, qmax=qmax),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
            jax.ShapeDtypeStruct(kq.shape, kq.dtype),
            jax.ShapeDtypeStruct(ks.shape, ks.dtype),
            jax.ShapeDtypeStruct(vq.shape, vq.dtype),
            jax.ShapeDtypeStruct(vs.shape, vs.dtype),
        ],
        # pools alias in place (operands 0/1 are the prefetched scalars):
        # only the one written row block per slot is DMA'd back
        input_output_aliases={3: 1, 4: 2, 5: 3, 6: 4},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, page_table, q, kq, ks, vq, vs, new_k, new_v)
    return _lane_trim_ctx(ctx, g_real), okq, oks, ovq, ovs


# ---------------------------------------------------------------------------
# SPMD dispatch: shard_map the decode kernels over the KV-head axis
# ---------------------------------------------------------------------------

def spmd_head_shardable(n_kv_heads: int, rules) -> bool:
    """Can the fused decode kernels run per-shard over the kv-head axis of
    ``rules.mesh``?  True when the rules map ``kv`` to exactly one mesh axis
    whose size divides the head count -- each shard then launches the
    unchanged Pallas kernel on its local ``K // tp`` head slice (the grid's
    kv-head dim is embarrassingly parallel: no cross-head reduction
    anywhere).  Otherwise callers fall back to the gather/reference path."""
    if rules is None:
        return False
    ax = rules.axis_map.get("kv") or ()
    if len(ax) != 1:
        return False
    size = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))[ax[0]]
    return n_kv_heads % size == 0


def decode_attention_spmd(q, kq, ks, vq, vs, new_k, new_v, pos, *,
                          mesh, kv_axis: str = "model",
                          qmin: int = -128, qmax: int = 127,
                          block_k: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """:func:`decode_attention` under SPMD: shard_map over the kv-head axis,
    each shard running the Pallas kernel on its local head slice of the
    cache (payloads AND scale sidecars arrive pre-sharded -- the per-shard
    BlockSpec DMA never crosses chips).  Math is bitwise identical to the
    single-device kernel: per-(slot, head) online softmax has no cross-shard
    reduction.  ``pos`` is replicated (host-side slot bookkeeping)."""
    from repro.parallel.compat import shard_map
    from jax.sharding import PartitionSpec as P
    kv4 = P(None, None, kv_axis, None)

    def f(q_, kq_, ks_, vq_, vs_, nk_, nv_, pos_):
        return decode_attention(q_, kq_, ks_, vq_, vs_, nk_, nv_, pos_,
                                qmin=qmin, qmax=qmax, block_k=block_k,
                                interpret=interpret)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(None, kv_axis, None, None), kv4, kv4, kv4, kv4,
                  P(None, kv_axis, None), P(None, kv_axis, None), P()),
        out_specs=(P(None, kv_axis, None, None), kv4, kv4, kv4, kv4),
    )(q, kq, ks, vq, vs, new_k, new_v, pos)


def decode_attention_paged_spmd(q, kq, ks, vq, vs, new_k, new_v, pos,
                                page_table, *,
                                mesh, kv_axis: str = "model",
                                qmin: int = -128, qmax: int = 127,
                                interpret: Optional[bool] = None):
    """:func:`decode_attention_paged` under SPMD: the page pools shard over
    their kv-head dim (``PAGED_POOL_AXES``), the page table and ``pos`` are
    replicated scalar bookkeeping, and every shard DMAs pages of its local
    head slice only."""
    from repro.parallel.compat import shard_map
    from jax.sharding import PartitionSpec as P
    pool4 = P(None, None, kv_axis, None)

    def f(q_, kq_, ks_, vq_, vs_, nk_, nv_, pos_, pt_):
        return decode_attention_paged(q_, kq_, ks_, vq_, vs_, nk_, nv_,
                                      pos_, pt_, qmin=qmin, qmax=qmax,
                                      interpret=interpret)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(None, kv_axis, None, None), pool4, pool4, pool4, pool4,
                  P(None, kv_axis, None), P(None, kv_axis, None), P(), P()),
        out_specs=(P(None, kv_axis, None, None), pool4, pool4, pool4, pool4),
    )(q, kq, ks, vq, vs, new_k, new_v, pos, page_table)


def decode_kv_read_bytes(mode: str, batch: int, max_seq: int,
                         n_kv_heads: int, head_dim: int, *,
                         n_layers: int = 1, fp_bytes: int = 2) -> int:
    """Analytic HBM bytes moved per decode step by the KV read, per the
    attention path's access pattern (the benchmark's roofline claim):

      * ``fp``      -- read the fp K+V buffers once.
      * ``dequant`` -- dequantize-on-read reference: read the int8 payloads
        and fp32 scale sidecars, materialize fp K+V copies (one write), and
        the attention reads those copies back (one read).
      * ``fused``   -- the kernel's BlockSpec DMA schedule: read the int8
        payloads + scale sidecars, nothing materialized.

    The fused path's one int8-row write (and q/ctx tiles) is O(1/max_seq) of
    the cache read and is excluded from all three for comparability.
    """
    elems = batch * max_seq * n_kv_heads * head_dim      # per buffer (K or V)
    scales = batch * max_seq * n_kv_heads                # fp32 sidecar elems
    if mode == "fp":
        per_layer = 2 * elems * fp_bytes
    elif mode == "dequant":
        per_layer = 2 * (elems * (1 + 2 * fp_bytes) + 4 * scales)
    elif mode == "fused":
        per_layer = 2 * (elems + 4 * scales)
    else:
        raise ValueError(f"unknown mode {mode!r} (fp | dequant | fused)")
    return per_layer * n_layers
