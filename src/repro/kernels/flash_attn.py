"""Flash attention (forward) as a Pallas TPU kernel.

EXPERIMENTS.md §Perf attributes the dominant memory-roofline term of every
train/prefill cell to the XLA-materialized score/softmax chain (~13 HBM
passes over fp32 (chunk, S) slabs).  This kernel is the structural fix: the
online-softmax tiles live in VMEM scratch; HBM traffic is exactly the
BlockSpec DMA schedule

    bytes = b*h * ( Sq*d (q, once) + Sq*d (o, once)
                    + 2 * Skv*d * ceil(Sq/block_q) (k+v reload per q row) )

computable in closed form via :func:`hbm_traffic_bytes` -- for llama3
train_4k this is ~0.3 GB/layer vs ~13 GB/layer for the materialized chain.

Grid: (b*h, Sq/bq, Skv/bk), kv innermost; scratch carries the running
(m, l, acc) per q tile.  Causal tiles above the diagonal are skipped via
pl.when.  TARGET: TPU.  VALIDATED: interpret=True vs ref (tests).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int8_matmul import scale_guard
from repro.kernels.pallas_compat import CompilerParams

BLOCK_Q = 512
BLOCK_K = 512


def online_softmax_update(s, vt, m_ref, l_ref, acc_ref, v_fold=None):
    """One online-softmax accumulation step: fold the scores tile ``s`` into
    the running (m, l, acc) scratch against the value tile ``vt``.  Shared
    by every forward kernel (fp, LSE-emitting, int8-dequant-prologue, and
    the decode kernel in decode_attn.py) so the recurrence exists once.
    ``v_fold`` multiplies the probabilities by a rank-1 factor -- the V
    dequant scale fold of the quantized variants."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    if v_fold is not None:
        p = p * v_fold
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(vt.dtype), vt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      scale: float, causal: bool, bq: int, bk: int, nk: int,
                      q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: the whole (qi, ki) tile is masked iff its first kv position
    # exceeds the last q position
    first_k = ki * bk
    last_q = q_offset + qi * bq + bq - 1
    live = (first_k <= last_q) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        online_softmax_update(s, v_ref[0], m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, q_offset: int = 0,
                        block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (BH, Sq, d); k, v: (BH, Skv, d) -> (BH, Sq, d).

    Sq % block_q == 0 and Skv % block_k == 0 (callers pad or shrink blocks).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, skv)
    while skv % bk:
        bk //= 2
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, q_offset=q_offset),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum
            pltpu.VMEM((bq, d), jnp.float32),     # accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def _flash_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                          m_ref, l_ref, acc_ref, *, scale, causal, bq, bk,
                          nk, q_offset):
    """Forward that also emits the log-sum-exp rows (for the Pallas bwd)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((ki * bk) <= (q_offset + qi * bq + bq - 1)) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        online_softmax_update(s, v_ref[0], m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


def _flash_bwd_dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                           dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                           bq, bk, nq, q_offset):
    """Grid (bh, nk, nq): accumulate dK/dV for one kv tile over q tiles."""
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    live = ((ki * bk) <= (q_offset + qi * bq + bq - 1)) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        p = jnp.exp(s - lse_ref[0][:, None])               # (bq, bk)
        do = do_ref[0].astype(jnp.float32)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v_ref[0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_acc, *, scale, causal, bq, bk, nk,
                         q_offset):
    """Grid (bh, nq, nk): accumulate dQ for one q tile over kv tiles."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    live = ((ki * bk) <= (q_offset + qi * bq + bq - 1)) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        p = jnp.exp(s - lse_ref[0][:, None])
        do = do_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v_ref[0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _blocks(sq, skv, block_q, block_k):
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, skv)
    while skv % bk:
        bk //= 2
    return bq, bk


def _fwd_with_lse(q, k, v, causal, q_offset, block_q, block_k, interpret):
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq, bk = _blocks(sq, skv, block_q, block_k)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_flash_fwd_lse_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, q_offset=q_offset),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
                   jax.ShapeDtypeStruct((bh, sq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: Optional[bool] = None):
    """Differentiable flash attention: Pallas forward AND backward (the
    classic dKdV / dQ two-kernel recompute scheme with saved LSE rows)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    o, _ = _fwd_with_lse(q, k, v, causal, q_offset, block_q, block_k,
                         interpret)
    return o


def _ref_attend(q, k, v, causal, q_offset):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        kpos = jnp.arange(k.shape[1])
        s = jnp.where(kpos[None, :] <= qpos[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def _fa_fwd(q, k, v, causal, q_offset, block_q, block_k, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    o, lse = _fwd_with_lse(q, k, v, causal, q_offset, block_q, block_k,
                           interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, q_offset, block_q, block_k, interpret, res, g):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, k, v, o, lse = res
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq, bk = _blocks(sq, skv, block_q, block_k)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)
    delta = jnp.sum(g.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                     # (bh, sq)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkdv_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nq=nq, q_offset=q_offset),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),   # k
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),   # v
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),   # do
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),         # lse
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),         # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, skv, d), q.dtype),
                   jax.ShapeDtypeStruct((bh, skv, d), q.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g.astype(q.dtype), lse, delta)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, q_offset=q_offset),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),   # k
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),   # v
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),   # do
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),         # lse
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),         # delta
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g.astype(q.dtype), lse, delta)

    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# int8-KV prefill: the forward kernel with a dequant prologue.  Consumes the
# decode cache's stored form -- (B, Skv, K, hd) int8 payloads + (B, Skv, K, 1)
# fp32 per-(position, head) scale sidecars -- directly, so int8-KV prefill
# stops materializing a full fp K/V copy of the (max_seq-sized) cache buffer.
# GQA rides on the index maps (kv block h // g), no head repeat.  Forward-only
# (serving path); scale==0 padding rows are guarded (see decode_attn._guard).
# ---------------------------------------------------------------------------

def _flash_fwd_q8_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                         bq: int, bk: int, nk: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((ki * bk) <= (q_offset + qi * bq + bq - 1)) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, d)
        kt = kq_ref[0, :, 0, :].astype(jnp.float32)             # (bk, d)
        ksc = scale_guard(ks_ref[0, :, 0, :].astype(jnp.float32))  # (bk, 1)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * ksc[:, 0][None, :]        # fold the K dequant into the scores
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -1e30)
        vsc = scale_guard(vs_ref[0, :, 0, :].astype(jnp.float32))
        online_softmax_update(s, vq_ref[0, :, 0, :].astype(jnp.float32),
                              m_ref, l_ref, acc_ref,
                              v_fold=vsc[:, 0][None, :])

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd_q8(q: jnp.ndarray,
                           kq: jnp.ndarray, ks: jnp.ndarray,
                           vq: jnp.ndarray, vs: jnp.ndarray, *,
                           causal: bool = True, q_offset: int = 0,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); kq/vq: (B, Skv, K, hd) int8; ks/vs: (B, Skv, K, 1)
    fp32 -> (B, Sq, H, hd).  H % K == 0 (GQA/MQA); causal masking makes any
    never-written cache tail (rows >= q_offset + Sq) invisible."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, hd = q.shape
    skv, kh = kq.shape[1], kq.shape[2]
    assert h % kh == 0, (h, kh)
    if not causal and skv != q_offset + sq:
        # nothing but the causal mask hides never-written cache rows (their
        # guarded scale-0 / payload-0 entries would otherwise enter the
        # softmax with exp(0) weight and silently dilute every output)
        raise ValueError(
            f"causal=False requires a fully written cache: Skv={skv} vs "
            f"q_offset+Sq={q_offset + sq}")
    g = h // kh
    bq, bk = _blocks(sq, skv, block_q, block_k)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(hd)
    return pl.pallas_call(
        functools.partial(_flash_fwd_q8_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, q_offset=q_offset),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, hh, i, j: (b, i, hh, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, hh, i, j: (b, j, hh // g, 0)),
            pl.BlockSpec((1, bk, 1, 1),
                         lambda b, hh, i, j: (b, j, hh // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, hh, i, j: (b, j, hh // g, 0)),
            pl.BlockSpec((1, bk, 1, 1),
                         lambda b, hh, i, j: (b, j, hh // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, hh, i, j: (b, i, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, kq, ks, vq, vs)


def hbm_traffic_bytes(bh: int, sq: int, skv: int, d: int,
                      dtype_bytes: int = 2, block_q: int = BLOCK_Q) -> int:
    """Exact DMA traffic implied by the BlockSpec schedule (the kernel's
    memory-roofline claim; used for the §Perf flash projection)."""
    nq = max(sq // min(block_q, sq), 1)
    q_o = 2 * sq * d
    kv = 2 * skv * d * nq
    return bh * (q_o + kv) * dtype_bytes
