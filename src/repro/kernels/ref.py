"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def qdq_row_ref(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    return (q * scale).astype(x.dtype)


def qdq_scaled_ref(x: jnp.ndarray, scale: jnp.ndarray,
                   bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf), -qmax - 1, qmax)
    return (q * sf).astype(x.dtype)


def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                    col_scale: jnp.ndarray, out_dtype=jnp.bfloat16
                    ) -> jnp.ndarray:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * row_scale.astype(jnp.float32)
            * col_scale.astype(jnp.float32)).astype(out_dtype)
