"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def qdq_row_ref(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    return (q * scale).astype(x.dtype)


def qdq_scaled_ref(x: jnp.ndarray, scale: jnp.ndarray,
                   bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf), -qmax - 1, qmax)
    return (q * sf).astype(x.dtype)


def _guard_ref(scale: jnp.ndarray) -> jnp.ndarray:
    """Mirror of the kernels' 0-scale padding guard."""
    return jnp.where(scale == 0.0, 1.0, scale.astype(jnp.float32))


def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                    col_scale: jnp.ndarray, out_dtype=jnp.bfloat16
                    ) -> jnp.ndarray:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * _guard_ref(row_scale)
            * _guard_ref(col_scale)).astype(out_dtype)


def int8_matmul_nt_ref(g: jnp.ndarray, w: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dx = qdq_token(g * fold) @ w^T; w is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(hq, w.astype(jnp.int32).T,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)


def int8_matmul_tn_ref(x: jnp.ndarray, g: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dW = x^T @ qdq_channel(g * fold); x is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(x.astype(jnp.int32).T, hq,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)


# ---------------------------------------------------------------------------
# decode_attn.py oracle: the dequantize-whole-buffer reference path (mirrors
# models/attention.py), shared by tests/test_decode_attn.py and the
# benchmarks/serve_throughput.py CI parity gate so the reference semantics
# exist once.
# ---------------------------------------------------------------------------

def decode_attn_ref(q, kq, ks, vq, vs, new_k, new_v, pos):
    """q: (B, K, G, hd) fp; kq/vq: (B, S, K, hd) int8; ks/vs: (B, S, K, 1)
    fp32; new_k/new_v: (B, K, hd) fp; pos: (B,) validity lengths == scatter
    rows.  Quantizes the new rows with the `_kv_quant` per-(position, head)
    codec, scatters, dequantizes the whole buffer (0-scale guard) and runs
    the masked grouped softmax.  Returns (ctx, (kq', ks', vq', vs'))."""
    import jax
    from repro.core.qconfig import Granularity, QuantSpec
    from repro.core.quantizer import quantize_int
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    b, s, kh, hd = kq.shape
    nkq, nks, _ = quantize_int(new_k, spec)
    nvq, nvs, _ = quantize_int(new_v, spec)
    rows = jnp.arange(b)
    kq = kq.at[rows, pos].set(nkq)
    ks = ks.at[rows, pos].set(nks)
    vq = vq.at[rows, pos].set(nvq)
    vs = vs.at[rows, pos].set(nvs)
    kf = kq.astype(jnp.float32) * _guard_ref(ks)
    vf = vq.astype(jnp.float32) * _guard_ref(vs)
    s_ = jnp.einsum("bkgh,btkh->bkgt", q, kf,
                    preferred_element_type=jnp.float32)
    s_ = s_ / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    t = jnp.arange(s)
    s_ = jnp.where((t[None, :] <= pos[:, None])[:, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bkgt,btkh->bkgh", p, vf), (kq, ks, vq, vs)


def decode_attn_paged_ref(q, kq, ks, vq, vs, new_k, new_v, pos, page_table):
    """Paged-gather oracle for ``decode_attention_paged``: same new-row
    quantize codec as :func:`decode_attn_ref`, scatter into the *pool* at
    ``(page_table[b, pos//page], pos % page)``, then gather each slot's
    logical view ``pool[page_table[b]]`` -> (B, maxp*page, K, hd) and run the
    identical dequant + masked grouped softmax.  Because the gathered view
    lays the same values at the same logical rows as the dense buffer, the
    attention is bitwise identical to the dense oracle wherever
    ``maxp*page == S`` -- the property the engine parity tests pin.

    kq/vq: (P, page, K, hd) int8 pools; ks/vs: (P, page, K, 1) fp32;
    page_table: (B, maxp) int32.  Returns (ctx, (kq', ks', vq', vs'))."""
    import jax
    from repro.core.qconfig import Granularity, QuantSpec
    from repro.core.quantizer import quantize_int
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    b = q.shape[0]
    page = kq.shape[1]
    maxp = page_table.shape[1]
    hd = kq.shape[-1]
    nkq, nks, _ = quantize_int(new_k, spec)
    nvq, nvs, _ = quantize_int(new_v, spec)
    pc = jnp.minimum(pos, maxp * page - 1)
    rows_b = jnp.arange(b)
    pid = page_table[rows_b, pc // page]
    row = pc % page
    kq = kq.at[pid, row].set(nkq)
    ks = ks.at[pid, row].set(nks)
    vq = vq.at[pid, row].set(nvq)
    vs = vs.at[pid, row].set(nvs)
    # gather the logical per-slot views, then dequant (mirrors the kernel's
    # page-at-a-time DMA: only table-mapped pages are ever touched)
    kf = (kq[page_table].astype(jnp.float32)
          * _guard_ref(ks[page_table])).reshape(b, maxp * page, -1, hd)
    vf = (vq[page_table].astype(jnp.float32)
          * _guard_ref(vs[page_table])).reshape(b, maxp * page, -1, hd)
    s_ = jnp.einsum("bkgh,btkh->bkgt", q, kf,
                    preferred_element_type=jnp.float32)
    s_ = s_ / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    t = jnp.arange(maxp * page)
    s_ = jnp.where((t[None, :] <= pc[:, None])[:, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bkgt,btkh->bkgh", p, vf), (kq, ks, vq, vs)


def paged_from_dense(kq, ks, vq, vs, lengths, page, n_extra=1, seed=0):
    """Re-lay a dense ragged cache fixture (B, S, K, hd) as page pools plus
    a per-slot table: slot b's first ceil(lengths[b]/page) logical pages map
    to freshly assigned physical pages (allocation order shuffled by seed so
    physical contiguity is never accidentally relied on), the rest to the
    trash page 0.  ``n_extra`` spare pages pad the pool.  Returns
    (kq_pool, ks_pool, vq_pool, vs_pool, page_table)."""
    import numpy as np
    b, s, kh, hd = kq.shape
    assert s % page == 0
    maxp = s // page
    need = [int(-(-int(l) // page)) for l in lengths]
    # map every slot's full row range: pages holding the write position too
    need = [min(maxp, n + 1) for n in need]
    total = 1 + sum(need) + n_extra
    rng = np.random.RandomState(seed)
    order = list(rng.permutation(np.arange(1, total)))
    table = np.zeros((b, maxp), np.int64)
    kqp = jnp.zeros((total, page, kh, hd), kq.dtype)
    ksp = jnp.zeros((total, page, kh, 1), ks.dtype)
    vqp = jnp.zeros((total, page, kh, hd), vq.dtype)
    vsp = jnp.zeros((total, page, kh, 1), vs.dtype)
    for bi in range(b):
        for j in range(need[bi]):
            pid = order.pop()
            table[bi, j] = pid
            sl = slice(j * page, (j + 1) * page)
            kqp = kqp.at[pid].set(kq[bi, sl])
            ksp = ksp.at[pid].set(ks[bi, sl])
            vqp = vqp.at[pid].set(vq[bi, sl])
            vsp = vsp.at[pid].set(vs[bi, sl])
    return kqp, ksp, vqp, vsp, jnp.asarray(table, jnp.int32)


def decode_attn_inputs(b, s, kh, g, hd, lengths, seed=0):
    """Ragged int8 cache fixture: rows < lengths[i] hold quantized random
    K/V, the rest the never-written state (zero payload AND zero scale);
    plus the step's fresh q / new-row tensors and an fp mirror of the valid
    cache.  Returns (q, kq, ks, vq, vs, kf_valid, vf_valid, new_k, new_v,
    pos)."""
    import jax
    from repro.core.qconfig import Granularity, QuantSpec
    from repro.core.quantizer import quantize_int
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    kf = jax.random.normal(keys[0], (b, s, kh, hd), jnp.float32)
    vf = jax.random.normal(keys[1], (b, s, kh, hd), jnp.float32)
    kq, ks, _ = quantize_int(kf, spec)
    vq, vs, _ = quantize_int(vf, spec)
    pos = jnp.asarray(lengths, jnp.int32)
    valid = (jnp.arange(s)[None, :, None, None] < pos[:, None, None, None])
    kq, vq = jnp.where(valid, kq, 0), jnp.where(valid, vq, 0)
    ks, vs = jnp.where(valid, ks, 0.0), jnp.where(valid, vs, 0.0)
    q = jax.random.normal(keys[2], (b, kh, g, hd), jnp.float32)
    new_k = jax.random.normal(keys[3], (b, kh, hd), jnp.float32)
    new_v = jax.random.normal(keys[4], (b, kh, hd), jnp.float32)
    return q, kq, ks, vq, vs, (kf * valid), (vf * valid), new_k, new_v, pos
