"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def qdq_row_ref(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    return (q * scale).astype(x.dtype)


def qdq_scaled_ref(x: jnp.ndarray, scale: jnp.ndarray,
                   bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf), -qmax - 1, qmax)
    return (q * sf).astype(x.dtype)


def _guard_ref(scale: jnp.ndarray) -> jnp.ndarray:
    """Mirror of the kernels' 0-scale padding guard."""
    return jnp.where(scale == 0.0, 1.0, scale.astype(jnp.float32))


def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                    col_scale: jnp.ndarray, out_dtype=jnp.bfloat16
                    ) -> jnp.ndarray:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * _guard_ref(row_scale)
            * _guard_ref(col_scale)).astype(out_dtype)


def int8_matmul_nt_ref(g: jnp.ndarray, w: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dx = qdq_token(g * fold) @ w^T; w is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(hq, w.astype(jnp.int32).T,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)


def int8_matmul_tn_ref(x: jnp.ndarray, g: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dW = x^T @ qdq_channel(g * fold); x is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(x.astype(jnp.int32).T, hq,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)


# ---------------------------------------------------------------------------
# decode_attn.py oracle: the dequantize-whole-buffer reference path (mirrors
# models/attention.py), shared by tests/test_decode_attn.py and the
# benchmarks/serve_throughput.py CI parity gate so the reference semantics
# exist once.
# ---------------------------------------------------------------------------

def decode_attn_ref(q, kq, ks, vq, vs, new_k, new_v, pos):
    """q: (B, K, G, hd) fp; kq/vq: (B, S, K, hd) int8; ks/vs: (B, S, K, 1)
    fp32; new_k/new_v: (B, K, hd) fp; pos: (B,) validity lengths == scatter
    rows.  Quantizes the new rows with the `_kv_quant` per-(position, head)
    codec, scatters, dequantizes the whole buffer (0-scale guard) and runs
    the masked grouped softmax.  Returns (ctx, (kq', ks', vq', vs'))."""
    import jax
    from repro.core.qconfig import Granularity, QuantSpec
    from repro.core.quantizer import quantize_int
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    b, s, kh, hd = kq.shape
    nkq, nks, _ = quantize_int(new_k, spec)
    nvq, nvs, _ = quantize_int(new_v, spec)
    rows = jnp.arange(b)
    kq = kq.at[rows, pos].set(nkq)
    ks = ks.at[rows, pos].set(nks)
    vq = vq.at[rows, pos].set(nvq)
    vs = vs.at[rows, pos].set(nvs)
    kf = kq.astype(jnp.float32) * _guard_ref(ks)
    vf = vq.astype(jnp.float32) * _guard_ref(vs)
    s_ = jnp.einsum("bkgh,btkh->bkgt", q, kf,
                    preferred_element_type=jnp.float32)
    s_ = s_ / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    t = jnp.arange(s)
    s_ = jnp.where((t[None, :] <= pos[:, None])[:, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bkgt,btkh->bkgh", p, vf), (kq, ks, vq, vs)


def decode_attn_inputs(b, s, kh, g, hd, lengths, seed=0):
    """Ragged int8 cache fixture: rows < lengths[i] hold quantized random
    K/V, the rest the never-written state (zero payload AND zero scale);
    plus the step's fresh q / new-row tensors and an fp mirror of the valid
    cache.  Returns (q, kq, ks, vq, vs, kf_valid, vf_valid, new_k, new_v,
    pos)."""
    import jax
    from repro.core.qconfig import Granularity, QuantSpec
    from repro.core.quantizer import quantize_int
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    kf = jax.random.normal(keys[0], (b, s, kh, hd), jnp.float32)
    vf = jax.random.normal(keys[1], (b, s, kh, hd), jnp.float32)
    kq, ks, _ = quantize_int(kf, spec)
    vq, vs, _ = quantize_int(vf, spec)
    pos = jnp.asarray(lengths, jnp.int32)
    valid = (jnp.arange(s)[None, :, None, None] < pos[:, None, None, None])
    kq, vq = jnp.where(valid, kq, 0), jnp.where(valid, vq, 0)
    ks, vs = jnp.where(valid, ks, 0.0), jnp.where(valid, vs, 0.0)
    q = jax.random.normal(keys[2], (b, kh, g, hd), jnp.float32)
    new_k = jax.random.normal(keys[3], (b, kh, hd), jnp.float32)
    new_v = jax.random.normal(keys[4], (b, kh, hd), jnp.float32)
    return q, kq, ks, vq, vs, (kf * valid), (vf * valid), new_k, new_v, pos
