"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def qdq_row_ref(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    return (q * scale).astype(x.dtype)


def qdq_scaled_ref(x: jnp.ndarray, scale: jnp.ndarray,
                   bits: int = 8) -> jnp.ndarray:
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf), -qmax - 1, qmax)
    return (q * sf).astype(x.dtype)


def _guard_ref(scale: jnp.ndarray) -> jnp.ndarray:
    """Mirror of the kernels' 0-scale padding guard."""
    return jnp.where(scale == 0.0, 1.0, scale.astype(jnp.float32))


def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, row_scale: jnp.ndarray,
                    col_scale: jnp.ndarray, out_dtype=jnp.bfloat16
                    ) -> jnp.ndarray:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * _guard_ref(row_scale)
            * _guard_ref(col_scale)).astype(out_dtype)


def int8_matmul_nt_ref(g: jnp.ndarray, w: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dx = qdq_token(g * fold) @ w^T; w is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(hq, w.astype(jnp.int32).T,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)


def int8_matmul_tn_ref(x: jnp.ndarray, g: jnp.ndarray,
                       fold_scale: jnp.ndarray, q_scale: jnp.ndarray,
                       out_dtype=jnp.float32) -> jnp.ndarray:
    """dW = x^T @ qdq_channel(g * fold); x is the int8 forward payload."""
    qs = _guard_ref(q_scale)
    h = g.astype(jnp.float32) * fold_scale.astype(jnp.float32)
    hq = jnp.clip(jnp.round(h / qs), -128, 127).astype(jnp.int32)
    acc = jnp.matmul(x.astype(jnp.int32).T, hq,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * qs).astype(out_dtype)
