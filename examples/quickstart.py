"""Quickstart: quantized pre-training in ~60 lines.

Trains a mini GPT-2 with the paper's recommended recipe (W8 per-channel +
A8 per-token, Section 4.5) against the fp baseline and prints both curves.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""
import argparse

import jax

from repro.configs import get_smoke_config
from repro.core import fp_baseline, paper_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step


def train(recipe, steps: int):
    cfg = get_smoke_config("gpt2-small")      # the paper's model, reduced
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    loader = Loader(corpus, cfg, batch_size=8, seq_len=128)
    losses = []
    for i in range(steps):
        state, metrics = step(state, next(loader),
                              jax.random.fold_in(jax.random.PRNGKey(0), i))
        losses.append(float(metrics["ce"]))
        if (i + 1) % 10 == 0:
            print(f"  step {i+1:4d}  ce={losses[-1]:.4f}", flush=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    print("== fp32/bf16 baseline ==")
    fp = train(fp_baseline(), args.steps)
    print("== paper recipe: W8 per-channel + A8 per-token ==")
    q = train(paper_recipe(), args.steps)
    print(f"\nfinal ce  baseline={fp[-1]:.4f}  quantized={q[-1]:.4f}  "
          f"delta={q[-1] - fp[-1]:+.4f}")
    print("(the paper's finding: the W8A8 recipe tracks the baseline)")


if __name__ == "__main__":
    main()
