"""Serving example: batched prefill + greedy decode with a quantized model.

Loads the latest checkpoint written by train_quantized_gpt2.py (or trains a
tiny model on the fly) and serves a batch of prompts, measuring per-token
decode latency.

    PYTHONPATH=src python examples/serve_decode.py --tokens 32
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import paper_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import greedy_generate, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--warm-steps", type=int, default=80,
                    help="quick pre-train so generations are non-random")
    args = ap.parse_args()

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    recipe = paper_recipe()
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=args.warm_steps)
    state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    loader = Loader(corpus, cfg, batch_size=args.batch,
                    seq_len=args.prompt_len)
    for i in range(args.warm_steps):
        state, _ = step(state, next(loader), None)

    prompts = next(loader)["tokens"][:, :args.prompt_len]
    t0 = time.perf_counter()
    gen = greedy_generate(model, state.params, {"tokens": prompts},
                          args.tokens, recipe=recipe)
    gen = np.asarray(jax.block_until_ready(gen))
    dt = time.perf_counter() - t0
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({dt / args.tokens * 1e3:.1f} ms/token batched x{args.batch})")
    print("sample:", gen[0][:16].tolist())

    # quality probe: continuation CE of generated vs random tokens under the
    # corpus's own bigram statistics
    succ = corpus.succ
    def hit_rate(seq):
        hits = 0
        for a, b in zip(seq[:-1], seq[1:]):
            hits += int(b in succ[a])
        return hits / (len(seq) - 1)
    model_rate = np.mean([hit_rate(g) for g in gen])
    rand = np.random.RandomState(0).randint(0, cfg.vocab_size,
                                            gen.shape)
    rand_rate = np.mean([hit_rate(g) for g in rand])
    print(f"bigram-consistency: model={model_rate:.2f} random={rand_rate:.2f}"
          f"  (higher = learned the corpus transitions)")


if __name__ == "__main__":
    main()
