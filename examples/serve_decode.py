"""Serving example: the quantized inference engine on a freshly-trained
mini GPT-2.

Trains a tiny model, then serves a mixed bag of requests through
``repro.infer.Engine``: weights are quantized ONCE into stored int8 payloads
(per the policy), the KV cache optionally stores int8, and requests of
different lengths share the fixed decode slots via continuous batching.

    PYTHONPATH=src python examples/serve_decode.py --tokens 32 \
        --policy 'kv_cache=a8t,*=w8c+a8t'
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import Loader, SyntheticCorpus
from repro.infer import Engine, Request, SamplingParams, params_nbytes
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="decode slots (max concurrent requests)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--policy", default="*=w8c+a8t",
                    help="QuantPolicy string; try 'kv_cache=a8t,*=w8c+a8t' "
                         "for the int8 KV cache, '*=fp' for the fp baseline")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--warm-steps", type=int, default=80,
                    help="quick pre-train so generations are non-random")
    args = ap.parse_args()

    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=args.warm_steps)
    state = init_train_state(model, jax.random.PRNGKey(0), args.policy, opt)
    step = jax.jit(make_train_step(model, args.policy, opt))
    loader = Loader(corpus, cfg, batch_size=args.batch,
                    seq_len=args.prompt_len)
    for i in range(args.warm_steps):
        state, _ = step(state, next(loader), None)

    engine = Engine(
        model, state.params, args.policy,
        max_slots=args.batch,
        max_seq=args.prompt_len + args.tokens + 1,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p))
    print(f"engine: policy [{engine.policy.describe()}] "
          f"params {params_nbytes(engine.params) / 1e6:.2f} MB "
          f"kv-state {engine.kv_cache_nbytes() / 1e6:.2f} MB")
    print(f"engine: path [{engine.path_summary()}] "
          f"kv-read/step {engine.kv_decode_read_bytes() / 1e6:.2f} MB")

    # a mixed bag: 2x slots requests with varied prompt lengths, so slots
    # turn over and admission backfills (continuous batching)
    prompts = np.asarray(next(loader)["tokens"])
    rng = np.random.RandomState(0)
    for i in range(2 * args.batch):
        plen = int(rng.randint(args.prompt_len // 4, args.prompt_len + 1))
        engine.submit(Request(tokens=prompts[i % args.batch, :plen].tolist(),
                              max_new_tokens=args.tokens))
    t0 = time.perf_counter()
    responses = engine.run()
    dt = time.perf_counter() - t0
    gen_tokens = sum(len(r.tokens) for r in responses)
    print(f"served {len(responses)} requests / {gen_tokens} tokens "
          f"in {dt:.2f}s ({gen_tokens / dt:.1f} tok/s on {args.batch} slots)")
    print("sample:", responses[0].tokens[:16])

    # quality probe: continuation consistency under the corpus's own bigram
    # statistics (higher = learned the corpus transitions)
    succ = corpus.succ
    def hit_rate(seq):
        if len(seq) < 2:
            return 0.0
        return sum(int(b in succ[a]) for a, b in zip(seq[:-1], seq[1:])) \
            / (len(seq) - 1)
    model_rate = np.mean([hit_rate(r.tokens) for r in responses])
    rand_rate = np.mean([hit_rate(list(rng.randint(0, cfg.vocab_size,
                                                   args.tokens)))
                         for _ in responses])
    print(f"bigram-consistency: model={model_rate:.2f} random={rand_rate:.2f}")


if __name__ == "__main__":
    main()
