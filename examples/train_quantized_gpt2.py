"""End-to-end driver: quantized pre-training with the full production stack
(checkpointing, preemption handling, validation, quantized optimizer states).

Default trains the mini GPT-2 for a few hundred steps on CPU; pass
``--arch gpt2-small --full`` on real hardware for the paper's 124M config.

    PYTHONPATH=src python examples/train_quantized_gpt2.py \
        --steps 300 --recipe paper --ckpt /tmp/ckpt_gpt2

Per-layer policies (QuantPolicy API): keep the sensitive first/last blocks
fp and run the middle of the stack on the real-int8 Pallas kernel:

    PYTHONPATH=src python examples/train_quantized_gpt2.py --steps 300 \
        --policy 'block[0:1].*=fp,block[-1:].*=fp,*=w8c+a8t@int8_pallas'
"""
import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import get_recipe, parse_policy
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import (LoopConfig, Trainer, init_train_state,
                         make_eval_step, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--recipe", default="paper",
                    help="preset name or compact spec ('w8c,a8t,m1:4c')")
    ap.add_argument("--policy", default="",
                    help="per-layer-role rules, e.g. 'block[0:2].*=fp,"
                         "*=w8c+a8t@int8_pallas' (overrides --recipe)")
    ap.add_argument("--state-storage", default="fake",
                    choices=["fake", "int"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = build_model(cfg)
    recipe = (parse_policy(args.policy) if args.policy
              else get_recipe(args.recipe))
    print(f"arch={cfg.name}  params~{cfg.param_count()/1e6:.1f}M  "
          f"policy=[{recipe.describe()}]")

    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps, state_storage=args.state_storage)
    state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    eval_step = jax.jit(make_eval_step(model, recipe))
    loader = Loader(corpus, cfg, batch_size=args.batch, seq_len=args.seq)
    valid = Loader(corpus, cfg, batch_size=args.batch, seq_len=args.seq,
                   split="valid")
    mgr = CheckpointManager(args.ckpt, keep_n=2, async_write=True)

    trainer = Trainer(step, eval_step, state, loader, ckpt=mgr,
                      valid_loader=valid,
                      loop_cfg=LoopConfig(total_steps=args.steps,
                                          ckpt_every=min(max(args.steps // 3, 10), args.steps),
                                          eval_every=max(args.steps // 6, 25),
                                          log_every=10),
                      metadata={"recipe": recipe.describe(),
                                "arch": cfg.name})
    trainer.install_preemption_handler()
    resumed = trainer.maybe_resume()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")
    history = trainer.run(rng=jax.random.PRNGKey(0))
    for rowd in history:
        extra = (f"  valid={rowd['valid_ce']:.4f}"
                 if "valid_ce" in rowd else "")
        print(f"step {rowd['step']:5d}  ce={rowd['ce']:.4f}  "
              f"lr={rowd['lr']:.2e}  {rowd['sec_per_step']*1e3:.0f}ms/step"
              + extra)
    print(f"checkpoints: {mgr.all_steps()} in {args.ckpt}")


if __name__ == "__main__":
    main()
