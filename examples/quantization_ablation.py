"""Component + per-layer ablation sweep (the paper's controlled-study shape,
extended with the QuantPolicy API): quantize one component / layer band at a
time and compare validation-loss trajectories.

    PYTHONPATH=src python examples/quantization_ablation.py --steps 100
"""
import argparse

import jax

from repro.configs import get_smoke_config
from repro.core import QuantPolicy, parse_policy, parse_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_eval_step, make_train_step

# Single-recipe rows use QuantPolicy.from_recipe (the legacy global scoping);
# policy rows exercise the per-layer-role rules the paper's sensitivity
# analysis calls for.
SWEEP = {
    "baseline": QuantPolicy.from_recipe(None),
    "W8/ch": QuantPolicy.from_recipe(parse_recipe("w8c")),
    "W4/tensor": QuantPolicy.from_recipe(parse_recipe("w4n")),
    "A8/token": QuantPolicy.from_recipe(parse_recipe("a8t")),
    "A4/token": QuantPolicy.from_recipe(parse_recipe("a4t")),
    "G8/token": QuantPolicy.from_recipe(parse_recipe("g8t")),
    "M2-8/ch (paper: diverges)": QuantPolicy.from_recipe(
        parse_recipe("m2:8c")),
    "M2-8 blockwise-sqrt (ours)": QuantPolicy.from_recipe(
        parse_recipe("m2:8c-asym-b128-sqrt")),
    # --- per-layer policies (first/last block fp, middle quantized) -------
    "W8A8 all blocks": parse_policy("*=w8c+a8t"),
    "W8A8 mid, fp ends": parse_policy(
        "block[0:1].*=fp,block[-1:].*=fp,*=w8c+a8t"),
    "W8A8 mid int8-kernel, fp ends": parse_policy(
        "block[0:1].*=fp,block[-1:].*=fp,*=w8c+a8t@int8_pallas"),
    "W4 mid only (harsh)": parse_policy(
        "block[0:1].*=fp,block[-1:].*=fp,*=w4c+a8t"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)

    print(f"{'config':32s} {'final CE':>9s} {'vs base':>8s}")
    base = None
    for name, policy in SWEEP.items():
        opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
        state = init_train_state(model, jax.random.PRNGKey(0), policy, opt)
        step = jax.jit(make_train_step(model, policy, opt))
        eval_step = jax.jit(make_eval_step(model, policy))
        loader = Loader(corpus, cfg, batch_size=8, seq_len=128)
        valid = Loader(corpus, cfg, batch_size=8, seq_len=128, split="valid")
        diverged = False
        for i in range(args.steps):
            state, m = step(state, next(loader), None)
            if not float(m["ce"]) < 30:
                diverged = True
                break
        if diverged:
            print(f"{name:32s} {'DIVERGED':>9s}")
            continue
        ce = float(eval_step(state.params, valid.peek(0))["ce"])
        if base is None:
            base = ce
        print(f"{name:32s} {ce:9.4f} {ce - base:+8.4f}")


if __name__ == "__main__":
    main()
