"""Component ablation sweep (the paper's controlled-study shape): quantize
one component at a time and compare validation-loss trajectories.

    PYTHONPATH=src python examples/quantization_ablation.py --steps 100
"""
import argparse

import jax

from repro.configs import get_smoke_config
from repro.core.qconfig import Granularity, QuantRecipe, QuantSpec
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import init_train_state, make_eval_step, make_train_step

SWEEP = {
    "baseline": QuantRecipe(),
    "W8/ch": QuantRecipe(weights=QuantSpec(8, Granularity.PER_CHANNEL)),
    "W4/tensor": QuantRecipe(weights=QuantSpec(4, Granularity.PER_TENSOR)),
    "A8/token": QuantRecipe(acts=QuantSpec(8, Granularity.PER_TOKEN)),
    "A4/token": QuantRecipe(acts=QuantSpec(4, Granularity.PER_TOKEN)),
    "G8/token": QuantRecipe(grads=QuantSpec(8, Granularity.PER_TOKEN)),
    "M2-8/ch (paper: diverges)": QuantRecipe(
        adam_m2=QuantSpec(8, Granularity.PER_CHANNEL)),
    "M2-8 blockwise-sqrt (ours)": QuantRecipe(
        adam_m2=QuantSpec(8, Granularity.PER_CHANNEL, symmetric=False,
                          block_size=128, sqrt_domain=True)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)

    print(f"{'config':30s} {'final CE':>9s} {'vs base':>8s}")
    base = None
    for name, recipe in SWEEP.items():
        opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
        state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
        step = jax.jit(make_train_step(model, recipe, opt))
        eval_step = jax.jit(make_eval_step(model, recipe))
        loader = Loader(corpus, cfg, batch_size=8, seq_len=128)
        valid = Loader(corpus, cfg, batch_size=8, seq_len=128, split="valid")
        diverged = False
        for i in range(args.steps):
            state, m = step(state, next(loader), None)
            if not float(m["ce"]) < 30:
                diverged = True
                break
        if diverged:
            print(f"{name:30s} {'DIVERGED':>9s}")
            continue
        ce = float(eval_step(state.params, valid.peek(0))["ce"])
        if base is None:
            base = ce
        print(f"{name:30s} {ce:9.4f} {ce - base:+8.4f}")


if __name__ == "__main__":
    main()
