"""Sharded AOT serving-engine tests (dp4 x tp2 forced host mesh).

The multi-chip claims of the serving stack are bitwise claims: FSDP weight
placement, tensor-parallel KV heads, AOT prefill/decode executables and the
shard_map'ed decode kernels must all reproduce the single-device greedy
tokens exactly.  Each test runs in a child interpreter via the conftest
``forced8_run`` fixture so the main pytest process keeps one real device.
"""


def test_sharded_tokens_bit_identical_and_no_retrace(forced8_run):
    """Greedy tokens on a (4, 2) data x model mesh == single-device tokens,
    for fp, dense fused int8-KV and paged fused int8-KV serving -- and the
    AOT engine's prefill/decode trace counters do not move while serving
    (every prompt bucket hit a pre-compiled executable)."""
    print(forced8_run("""
        import dataclasses
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.infer import Engine, Request

        cfg = dataclasses.replace(get_smoke_config("gpt2-small"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        prompts = [[5, 6, 7], [11, 12, 13, 14, 15], [3] * 20]
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))

        def toks(eng):
            for p in prompts:
                eng.submit(Request(tokens=p, max_new_tokens=6))
            return {r.request_id: r.tokens for r in eng.run()}

        for kw in (dict(),
                   dict(policy="kv_cache=a8t,*=w8c"),
                   dict(policy="kv_cache=a8t,*=w8c", paged=True,
                        page_size=16)):
            ref = toks(Engine(model, params, max_slots=4, max_seq=64,
                              prefill_bucket=16, **kw))
            eng = Engine(model, params, max_slots=4, max_seq=64,
                         prefill_bucket=16, mesh=mesh, **kw)
            before = dict(eng._trace_counts)
            got = toks(eng)
            assert got == ref, (kw, ref, got)
            assert eng._trace_counts == before, (kw, before,
                                                 eng._trace_counts)
            summary = eng.path_summary()
            assert "mesh=dp4xtp2" in summary, summary
            assert "aot=" in summary, summary
            print("OK", kw.get("policy", "fp"), "paged" if kw.get("paged")
                  else "dense", summary)
        print("SHARDED-PARITY-OK")
    """, extra_env={"REPRO_FUSED_DECODE": "1"}))


def test_sharded_placement_and_warmup_report(forced8_run):
    """Prepared-weight scale sidecars land co-sharded with their int8
    payloads, KV cache scale sidecars share the cache's kv-head sharding,
    and the warmup report accounts for every AOT executable."""
    print(forced8_run("""
        import dataclasses
        import numpy as np, jax
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.core.qadam import QState
        from repro.infer import Engine

        cfg = dataclasses.replace(get_smoke_config("gpt2-small"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        eng = Engine(model, params, policy="kv_cache=a8t,*=w8c",
                     max_slots=4, max_seq=64, prefill_bucket=16, mesh=mesh)

        w = eng.params["blocks"]["attn"]["wq"]
        assert isinstance(w, QState), type(w)
        # payload: FSDP over data on the embed dim, TP over model on heads;
        # the scale sidecar keeps the payload's surviving (non-size-1) dims
        assert w.q.sharding.spec == P(None, "data", "model"), \\
            w.q.sharding.spec
        assert w.scale.sharding.spec == P(None, None, "model"), \\
            w.scale.sharding.spec

        kq = eng._state["caches"]["k"]
        ksc = eng._state["caches"]["k_scale"]
        assert kq.sharding.spec == P(None, None, None, "model", None), \\
            kq.sharding.spec
        assert ksc.sharding.spec == kq.sharding.spec, ksc.sharding.spec

        rep = eng.warmup_report()
        names = [e["name"] for e in rep["executables"]]
        assert "decode" in names, names
        assert any(n.startswith("prefill") for n in names), names
        assert rep["n_executables"] == len(names) >= 2, rep
        assert rep["total_compile_s"] > 0, rep
        # warmup is idempotent: a second call compiles nothing new
        n = rep["n_executables"]
        eng.warmup()
        assert eng.warmup_report()["n_executables"] == n
        print("SHARDED-PLACEMENT-OK")
    """, extra_env={"REPRO_FUSED_DECODE": "1"}))
