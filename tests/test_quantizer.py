"""Unit + property tests for the core quantizer (paper Eq. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis, optional (see conftest)

from repro.core.qconfig import Granularity, QuantSpec, RoundMode
from repro.core.quantizer import (compute_scale_zero, dequantize_int,
                                  fake_quant, fake_quant_nograd, quant_error,
                                  quantize_int)

KEY = jax.random.PRNGKey(0)
GRANS = list(Granularity)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("gran", GRANS)
@pytest.mark.parametrize("symmetric", [True, False])
def test_qdq_error_bound(bits, gran, symmetric):
    x = jax.random.normal(KEY, (6, 10, 16)) * 3.0
    spec = QuantSpec(bits, gran, symmetric=symmetric)
    err = quant_error(x, spec)
    scale, _ = compute_scale_zero(x, spec)
    # max error is half an LSB of the per-group scale
    bound = jnp.broadcast_to(scale, x.shape) * 0.5 + 1e-5
    assert bool(jnp.all(err <= bound)), float(jnp.max(err - bound))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("gran", GRANS)
def test_qdq_idempotent(bits, gran):
    x = jax.random.normal(KEY, (8, 32))
    spec = QuantSpec(bits, gran)
    q1 = fake_quant_nograd(x, spec)
    q2 = fake_quant_nograd(q1, spec)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-6, atol=1e-6)


def test_absmax_preserved_symmetric():
    x = jax.random.normal(KEY, (64,)).reshape(1, 64)
    spec = QuantSpec(8, Granularity.PER_TENSOR)
    q = fake_quant_nograd(x, spec)
    np.testing.assert_allclose(float(jnp.max(jnp.abs(q))),
                               float(jnp.max(jnp.abs(x))), rtol=1e-6)


def test_ste_gradient_is_identity():
    x = jax.random.normal(KEY, (4, 8))
    spec = QuantSpec(8, Granularity.PER_TOKEN)
    g = jax.grad(lambda z: jnp.sum(fake_quant(z, spec) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(g),
                               rtol=1e-6)


def test_int_roundtrip_matches_fake():
    x = jax.random.normal(KEY, (16, 32)) * 2
    for spec in [QuantSpec(8, Granularity.PER_CHANNEL),
                 QuantSpec(4, Granularity.PER_TOKEN),
                 QuantSpec(8, Granularity.PER_TENSOR, symmetric=False),
                 QuantSpec(8, Granularity.PER_TOKEN, block_size=64)]:
        q, s, z = quantize_int(x, spec)
        deq = dequantize_int(q, s, z, spec, shape=x.shape)
        fq = fake_quant_nograd(x, spec)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(fq),
                                   rtol=1e-5, atol=1e-5)


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3).reshape(1, -1)
    spec = QuantSpec(2, Granularity.PER_TENSOR,
                     round_mode=RoundMode.STOCHASTIC)
    # scale = 0.3 (absmax/1); value sits at 0.3/0.3 = 1.0 exactly -> trivial.
    # Use a mix so values land between grid points.
    x = jnp.concatenate([x, jnp.full((1, 1), 1.0)], axis=1)
    q = fake_quant_nograd(x, spec, key=jax.random.PRNGKey(3))
    mean = float(jnp.mean(q[0, :-1]))
    assert abs(mean - 0.3) < 0.02, mean      # E[q] == x


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.integers(1, 6), st.integers(1, 64),
       st.booleans())
def test_property_error_bound_hypothesis(bits, rows, cols, symmetric):
    rng = np.random.RandomState(bits * 1000 + rows * 64 + cols)
    x = jnp.asarray(rng.randn(rows, cols).astype(np.float32) * 10)
    spec = QuantSpec(bits, Granularity.PER_TOKEN, symmetric=symmetric)
    err = np.asarray(quant_error(x, spec))
    scale, _ = compute_scale_zero(x, spec)
    bound = np.broadcast_to(np.asarray(scale), x.shape) * 0.5 + 1e-4
    assert (err <= bound).all()


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 100.0))
def test_property_positive_scale_equivariance(alpha):
    """Symmetric per-tensor qdq commutes with positive scaling."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(4, 16).astype(np.float32))
    spec = QuantSpec(8, Granularity.PER_TENSOR)
    a = jnp.float32(alpha)
    left = fake_quant_nograd(x * a, spec)
    right = fake_quant_nograd(x, spec) * a
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=2e-4, atol=2e-4)


def test_zero_tensor_safe():
    x = jnp.zeros((4, 4))
    for gran in GRANS:
        q = fake_quant_nograd(x, QuantSpec(8, gran))
        assert bool(jnp.all(q == 0)) and not bool(jnp.any(jnp.isnan(q)))
