"""repro.lint: HLO parser/graph analysis, every contract rule (positive AND
negative -- each must fire on a deliberately broken module), the jaxpr
scale-placement rule, the AST env-read lint, and end-to-end contracts on the
gpt2-small paths.

Golden modules live in ``tests/fixtures/hlo`` -- hand-written HLO text
exercising while/fusion/donation/convert patterns, so the parser and rules
have fast unit tests that compile nothing.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.lint import HloModule, RuleSpec, Severity, run_rules
from repro.lint.hlo_graph import (nbytes, nelems, operand_names,
                                  operand_types, shape_of)
from repro.parallel.hlo_count import (count_module, count_ops,
                                      entry_name, parse_module,
                                      reachable_computations)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# parser / graph analysis
# ---------------------------------------------------------------------------

def test_entry_and_reachability_cross_while_attrs():
    """``condition=%c, body=%b`` on ONE line must contribute BOTH callees
    (a greedy attr regex swallows ``body=`` into the condition value and
    loses the loop body -- the bug that hid every op inside while loops)."""
    comps = parse_module(fixture("while_dead.hlo"))
    assert entry_name(comps) == "main"
    reach = set(reachable_computations(comps))
    assert {"main", "cond", "body"} <= reach
    assert "dead" not in reach


def test_count_ops_skips_dead_computations():
    hlo = fixture("while_dead.hlo")
    assert count_ops(hlo, "round-nearest") == 1              # body only
    assert count_ops(hlo, "round-nearest", include_unreachable=True) == 3


def test_operand_parsing_tuple_typed():
    """Tuple-typed operands nest parens inside the operand list; a naive
    split loses them -- and donation chains go through get-tuple-element."""
    mod = HloModule(fixture("donated_copy.hlo"))
    gte = mod.defs("main")["gte"]
    assert operand_names(gte) == ["p1"]
    kd = HloModule(fixture("cache_dequant.hlo")).defs("main")["kd"]
    assert operand_types(kd) == [("s8", (2, 16, 2, 8))]


def test_shape_helpers():
    assert shape_of("f32[2,16,2,8]{3,2,1,0}") == ("f32", (2, 16, 2, 8))
    assert nelems("s8[64,32]{1,0}") == 2048
    assert nbytes("f32[64,64]{1,0}") == 16384
    assert shape_of("pred[]") == ("pred", ())


def test_donated_params_multi_entry_alias_map():
    """Nested ``{output_index}`` / ``{param_index}`` braces inside the alias
    map must not truncate the scan (brace-balanced, not regex-greedy)."""
    assert HloModule(fixture("donated_copy.hlo")).donated_params() == {0, 1}
    assert HloModule(fixture("while_dead.hlo")).donated_params() == set()


def test_walk_back_through_aliasing_ops():
    from repro.lint.hlo_graph import ALIASING_OPS
    mod = HloModule(fixture("donated_copy.hlo"))
    chain = mod.walk_back("main", mod.defs("main")["copy.view"],
                          through=ALIASING_OPS)
    assert any(i.name == "p1" and i.op == "parameter" for i in chain)


def test_count_module_custom_call_charges_operand_bytes():
    """Pallas launches read their operands from HBM like a fusion boundary:
    result 128*32*4 + operands 128*64*4 + 64*32*1."""
    counts = count_module(fixture("custom_call.hlo"), 1)
    assert counts["bytes"] == 16384 + 32768 + 2048


# ---------------------------------------------------------------------------
# rules, each positive + negative
# ---------------------------------------------------------------------------

def test_rule_no_weight_quant_rounds():
    # fires: three live rounds against a zero contract
    bad = run_rules(fixture("double_quant.hlo"),
                    [RuleSpec("no-weight-quant-rounds")])
    assert len(bad) == 3 and all(f.severity == Severity.ERROR for f in bad)
    # clean: a module with no rounds on the live path (dead comp has two)
    assert run_rules(fixture("while_dead.hlo"),
                     [RuleSpec("no-weight-quant-rounds",
                               {"max_rounds": 1})]) == []


def test_rule_no_whole_cache_dequant():
    hlo = fixture("cache_dequant.hlo")
    bad = run_rules(hlo, [RuleSpec("no-whole-cache-dequant",
                                   {"min_elems": 512})])
    assert len(bad) == 1 and bad[0].instr == "kd"    # scalar convert passes
    # dims pin: another buffer shape is not this rule's business
    assert run_rules(hlo, [RuleSpec("no-whole-cache-dequant",
                                    {"min_elems": 1, "dims": (4, 4)})]) == []
    assert run_rules(hlo, [RuleSpec("no-whole-cache-dequant",
                                    {"min_elems": 512,
                                     "dims": (2, 16, 2, 8)})])


def test_rule_int8_compute_present():
    hlo = fixture("int8_dots.hlo")
    assert run_rules(hlo, [RuleSpec("int8-compute-present",
                                    {"min_dots": 1})]) == []
    short = run_rules(hlo, [RuleSpec("int8-compute-present",
                                     {"min_dots": 2})])
    assert len(short) == 1 and "only 1" in short[0].message
    # fp module: zero integer dots
    assert run_rules(fixture("double_quant.hlo"),
                     [RuleSpec("int8-compute-present", {"min_dots": 1})])


def test_rule_copy_free_aliasing():
    hlo = fixture("donated_copy.hlo")
    bad = run_rules(hlo, [RuleSpec("copy-free-aliasing")])
    # 16 KiB copy of donated param 0 fires; the 512 B view copy is under
    # the bookkeeping threshold
    assert [f.instr for f in bad] == ["copy.big"]
    both = run_rules(hlo, [RuleSpec("copy-free-aliasing",
                                    {"min_bytes": 256})])
    assert {f.instr for f in both} == {"copy.big", "copy.view"}
    # clean: copies of COMPUTED values are fine; donation alias held
    assert run_rules(fixture("clean_donated.hlo"),
                     [RuleSpec("copy-free-aliasing")]) == []


def test_rule_double_quantize():
    bad = run_rules(fixture("double_quant.hlo"), [RuleSpec("double-quantize")])
    # r2 re-rounds r1 through an elementwise multiply; r3 is fed by a dot
    # (a genuinely new value), so it does NOT fire
    assert [f.instr for f in bad] == ["r2"]
    # reachability-aware: the dead computation's back-to-back rounds are
    # not live code
    assert run_rules(fixture("while_dead.hlo"),
                     [RuleSpec("double-quantize")]) == []


def test_rule_op_count_bounds():
    hlo = fixture("double_quant.hlo")
    assert run_rules(hlo, [RuleSpec("op-count",
                                    {"op_prefix": "round-nearest",
                                     "min_count": 3, "max_count": 3})]) == []
    over = run_rules(hlo, [RuleSpec("op-count",
                                    {"op_prefix": "round-nearest",
                                     "max_count": 2})])
    assert len(over) == 1
    missing = run_rules(hlo, [RuleSpec("op-count", {"op_prefix": "dot",
                                                    "result_type": "s32",
                                                    "min_count": 1})])
    assert len(missing) == 1


def test_rule_severity_override_and_ordering():
    hlo = fixture("double_quant.hlo")
    out = run_rules(hlo, [
        RuleSpec("no-weight-quant-rounds", severity=Severity.WARNING),
        RuleSpec("double-quantize", severity=Severity.ERROR)])
    assert out[0].severity == Severity.ERROR          # most severe first
    assert {f.severity for f in out} == {Severity.ERROR, Severity.WARNING}


# ---------------------------------------------------------------------------
# jaxpr rule: scale-off-contracted-axis
# ---------------------------------------------------------------------------

def _qstate(K, N, scale_shape):
    from repro.core.qadam import QState
    return QState(q=jnp.ones((K, N), jnp.int8),
                  scale=jnp.ones(scale_shape, jnp.float32),
                  zero=jnp.zeros((), jnp.float32))


def test_jaxpr_rule_clean_factorizations_pass():
    from repro.lint.jaxpr_rules import check_scale_contraction
    M, K, N = 8, 32, 16
    x = jnp.zeros((M, K), jnp.float32)

    def post_scale(x, w):           # scale multiplies the dot RESULT
        y = jax.lax.dot_general(x.astype(jnp.int32), w.q.astype(jnp.int32),
                                (((1,), (0,)), ((), ())))
        return y.astype(jnp.float32) * w.scale[None, :]

    assert check_scale_contraction(post_scale, x, _qstate(K, N, (N,))) == []

    def pre_scale_out_channel(x, w):    # dequant-before-dot, but the scale
        wf = w.q.astype(jnp.float32) * w.scale[None, :]   # varies OFF the
        return x @ wf                                     # contracted axis
    assert check_scale_contraction(pre_scale_out_channel, x,
                                   _qstate(K, N, (N,))) == []

    def per_tensor(x, w):           # scalar scales commute with the dot
        wf = w.q.astype(jnp.float32) * w.scale
        return x @ wf
    assert check_scale_contraction(per_tensor, x, _qstate(K, N, ())) == []


def test_jaxpr_rule_fires_on_contracted_axis_scale():
    from repro.lint.jaxpr_rules import check_scale_contraction
    M, K, N = 8, 32, 16
    x = jnp.zeros((M, K), jnp.float32)

    def bad(x, w):                  # per-K scale multiplied in pre-dot:
        wf = w.q.astype(jnp.float32) * w.scale[:, None]   # invalid int8
        return x @ wf                                     # factorization
    found = check_scale_contraction(bad, x, _qstate(K, N, (K,)))
    assert len(found) == 1 and found[0].severity == Severity.ERROR
    assert "contracted" in found[0].message


def test_jaxpr_rule_real_backward_closure_clean():
    """The int8 custom-vjp backward: residual scales stay off both backward
    dots' contracted axes (this is what makes dx/dW real int8 dots)."""
    from repro.core.qadam import QState
    from repro.core.qlinear import _qlinear_int8_bwd
    from repro.core.qpolicy import LinearCtx, as_policy
    from repro.lint.jaxpr_rules import check_scale_contraction
    recipe = as_policy("*=w8c+a8t+g8t@int8_pallas") \
        .resolve(LinearCtx("mlp_up")).recipe
    M, K, N = 4, 64, 48
    zero = jnp.zeros((), jnp.float32)
    xs = QState(jnp.zeros((M, K), jnp.int8),
                jnp.ones((M, 1), jnp.float32), zero)
    ws = QState(jnp.zeros((K, N), jnp.int8),
                jnp.ones((1, N), jnp.float32), zero)
    g = jnp.zeros((M, N), jnp.float32)
    proto = jnp.zeros((0,), jnp.float32)

    def bwd(xs_, ws_, g_):
        return _qlinear_int8_bwd(recipe, (xs_, ws_, None, (M, K),
                                          proto, proto), g_)

    assert check_scale_contraction(bwd, xs, ws, g) == []


# ---------------------------------------------------------------------------
# AST env-read lint
# ---------------------------------------------------------------------------

def test_ast_lint_flags_env_read_in_jitted_def():
    from repro.lint.pylint_rules import lint_source
    src = ("import os, jax\n"
           "@jax.jit\n"
           "def step(x):\n"
           "    if os.environ.get('FLAG') == '1':\n"
           "        return x * 2\n"
           "    return x\n")
    found = lint_source(src)
    assert len(found) == 1 and "step" in found[0].message


def test_ast_lint_flags_jit_wrapped_nested_def():
    """The exact shape of the PR-5 bug: a closure defined in __init__ and
    handed to jax.jit later reads the env at trace time."""
    from repro.lint.pylint_rules import lint_source
    src = ("import os, jax\n"
           "def outer(self):\n"
           "    def _decode(p, s):\n"
           "        blk = int(os.getenv('REPRO_DECODE_BLOCK', '256'))\n"
           "        return p\n"
           "    return jax.jit(_decode, donate_argnums=(1,))\n")
    assert len(lint_source(src)) == 1


def test_ast_lint_allowlists_pinning_pattern():
    from repro.lint.pylint_rules import lint_source
    ctxmgr = ("import os, contextlib\n"
              "@contextlib.contextmanager\n"
              "def _pinned_env(values):\n"
              "    old = {k: os.environ.get(k) for k in values}\n"
              "    os.environ.update(values)\n"
              "    yield\n")
    assert lint_source(ctxmgr) == []
    marked = ("import os, jax\n"
              "@jax.jit\n"
              "def step(x):\n"
              "    dbg = os.environ.get('DBG')  # lint: env-ok\n"
              "    return x\n")
    assert lint_source(marked) == []
    untraced = ("import os\n"
                "def helper():\n"
                "    return os.environ.get('X')\n")
    assert lint_source(untraced) == []


def test_ast_lint_repo_is_clean():
    from repro.lint.pylint_rules import lint_tree
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    assert lint_tree(root) == []


# ---------------------------------------------------------------------------
# end-to-end contracts on the gpt2-small paths
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gpt2():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = dataclasses.replace(get_smoke_config("gpt2-small"),
                              dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init_params(jax.random.PRNGKey(0))


def test_contract_decode_paths_green(gpt2, monkeypatch):
    """Both decode contracts hold on the real paths -- including
    copy-free-aliasing on the donated decode state, closing the ROADMAP
    carried-over invariant."""
    import repro.lint.contracts as contracts
    cfg, model, params = gpt2
    monkeypatch.setattr(contracts, "_MODEL_CACHE",
                        {"gpt2-small": (cfg, model, params)})
    for contract in contracts.contracts_for("decode"):
        assert contract.check("gpt2_small") == [], contract.name


def test_contract_fused_kv_fires_when_fused_disabled(gpt2, monkeypatch):
    """Negative e2e: REPRO_FUSED_DECODE=0 under the fused contract's rules
    -> the dequant-on-read fallback is caught as whole-cache converts."""
    from repro.infer import Engine
    cfg, model, params = gpt2
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    eng = Engine(model, params, "kv_cache=a8t,*=w8c",
                 max_slots=2, max_seq=32)
    _, b, s, kh, hd = eng._state["caches"]["k"].shape
    found = run_rules(eng.lowered_decode_hlo(),
                      [RuleSpec("no-whole-cache-dequant",
                                {"min_elems": b * s * kh * hd,
                                 "dims": (b, s, kh, hd)})])
    assert found and all(f.rule_id == "no-whole-cache-dequant"
                         for f in found)


def test_contract_prepared_fires_on_unprepared_weights(gpt2):
    """Negative e2e: raw (unprepared) weights under the prepared contract's
    rules -> in-trace quant rounds are caught."""
    from repro.core.qpolicy import as_policy
    cfg, model, params = gpt2
    policy = as_policy("*=w8c")
    state = model.init_decode_state(2, 16, 0, jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 4, jnp.int32)
    hlo = jax.jit(
        lambda p, s, t, q: model.decode(p, s, t, q, policy=policy)
    ).lower(params, state, tok, pos).compile().as_text()
    assert run_rules(hlo, [RuleSpec("no-weight-quant-rounds")])


# ---------------------------------------------------------------------------
# AST broad-except lint (recovery-path modules)
# ---------------------------------------------------------------------------

def test_except_lint_flags_swallowed_broad_handler():
    from repro.lint.pylint_rules import lint_excepts
    src = ("def restore():\n"
           "    try:\n"
           "        load()\n"
           "    except Exception:\n"
           "        pass\n")
    found = lint_excepts(src)
    assert len(found) == 1 and "swallows" in found[0].message
    bare = ("def f():\n"
            "    try:\n"
            "        g()\n"
            "    except:\n"
            "        return None\n")
    assert len(lint_excepts(bare)) == 1


def test_except_lint_passes_reraise_marker_and_narrow():
    from repro.lint.pylint_rules import lint_excepts
    wraps = ("def verify():\n"
             "    try:\n"
             "        load()\n"
             "    except Exception as e:\n"
             "        raise Corrupt(str(e)) from e\n")
    assert lint_excepts(wraps) == []
    marked = ("def writer():\n"
              "    try:\n"
              "        write()\n"
              "    except BaseException as e:  # lint: except-ok\n"
              "        park(e)\n")
    assert lint_excepts(marked) == []
    narrow = ("def f():\n"
              "    try:\n"
              "        g()\n"
              "    except (OSError, ValueError):\n"
              "        return None\n")
    assert lint_excepts(narrow) == []


def test_except_lint_scope_covers_recovery_modules():
    from repro.lint.pylint_rules import in_except_scope
    assert in_except_scope("src/repro/checkpoint/manager.py")
    assert in_except_scope("src/repro/train/loop.py")
    assert in_except_scope("src/repro/train/faults.py")
    assert in_except_scope("src/repro/infer/scheduler.py")
    assert in_except_scope("src/repro/infer/engine.py")
    assert not in_except_scope("src/repro/core/quantizer.py")
    assert not in_except_scope("benchmarks/run.py")
