"""Stability sentinel, fault injection, and the guarded train loop.

Unit layer: FaultPlan parsing, the in-jit gradient fault, the sentinel's
detection rules and escalation ladder, fallback_policy structure compat.
Integration layer: a real (smoke-config) trainer driven through the full
recovery ladder -- NaN gradients injected mid-run, skip, rollback to the
checkpoint, fp/fake fallback window, re-engage -- and SIGTERM preemption
resume producing a bit-identical loss curve.
"""
import math
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Granularity, QuantSpec, beyond_paper_recipe, \
    fallback_policy
from repro.core.qadam import QState
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig, init_adam_state
from repro.train import (FaultPlan, LoopConfig, SentinelConfig,
                         StabilitySentinel, Trainer, Verdict,
                         init_train_state, make_train_step)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# FaultPlan parsing + in-jit injection
# ---------------------------------------------------------------------------

def test_fault_plan_parse():
    plan = FaultPlan.parse(
        "nan_grad@3; sat_grad@5:factor=1e7 ;corrupt_ckpt@1:mode=truncate;"
        "sigterm_save@2;dead_sched@4")
    kinds = [f.kind for f in plan.faults]
    assert kinds == ["nan_grad", "sat_grad", "corrupt_ckpt", "sigterm_save",
                     "dead_sched"]
    assert plan.faults[1].arg("factor", "1e6") == "1e7"
    assert plan.faults[2].arg("mode", "flip") == "truncate"
    assert plan.has_grad_faults()
    assert plan.grad_fault_steps() == [3, 5]
    assert bool(plan)
    assert not bool(FaultPlan.parse(""))
    assert not bool(FaultPlan.parse(None))


@pytest.mark.parametrize("bad", [
    "nan_grad",                     # no @step
    "frobnicate@3",                 # unknown kind
    "nan_grad@x",                   # non-integer step
    "nan_grad@3:factor",            # arg without =
    "corrupt_ckpt@1:mode=shred",    # unknown corrupt mode
])
def test_fault_plan_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_grad_fault_fires_only_at_its_step():
    plan = FaultPlan.parse("nan_grad@3;sat_grad@5:factor=10")
    grads = {"w": jnp.ones((2, 2), jnp.float32)}
    poisoned = jax.jit(lambda s, g: plan.apply_grads(s, g))
    ok = poisoned(jnp.int32(2), grads)["w"]
    np.testing.assert_array_equal(np.asarray(ok), 1.0)   # bitwise no-op
    nan = poisoned(jnp.int32(3), grads)["w"]
    assert np.all(np.isnan(np.asarray(nan)))
    sat = poisoned(jnp.int32(5), grads)["w"]
    np.testing.assert_array_equal(np.asarray(sat), 10.0)


def test_note_step_marks_fired_and_delivers_sigterm():
    plan = FaultPlan.parse("nan_grad@2;sigterm_run@4")
    hits = []
    old = signal.signal(signal.SIGTERM, lambda *_: hits.append(True))
    try:
        for s in range(6):
            plan.note_step(s)
    finally:
        signal.signal(signal.SIGTERM, old)
    assert hits == [True]
    assert plan.fired == ["nan_grad@2", "sigterm_run@4"]


# ---------------------------------------------------------------------------
# Sentinel detection + ladder (pure host-side units)
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(window=16, min_history=4, spike_sigma=6.0, spike_floor=0.5,
                skip_limit=2, fallback_steps=8, max_rollbacks=2)
    base.update(kw)
    return SentinelConfig(**base)


def _feed_healthy(s, n, start=0, loss=2.0, gnorm=1.0, sat=0.05):
    for i in range(n):
        assert s.observe(start + i, {"loss": loss, "grad_norm": gnorm,
                                     "grad_sat": sat}) is Verdict.OK
    return start + n


def test_sentinel_healthy_run_is_all_ok():
    s = StabilitySentinel(_cfg())
    _feed_healthy(s, 20)
    assert s.counts["spikes"] == 0
    assert not s.in_fallback(20)


def test_sentinel_nonfinite_skips_immediately():
    s = StabilitySentinel(_cfg())
    assert s.observe(0, {"loss": float("nan")}) is Verdict.SKIP
    assert s.observe(1, {"loss": 2.0,
                         "grad_norm": float("inf")}) is Verdict.SKIP
    assert s.spike_reasons == {"nonfinite-loss": 1, "nonfinite-grad": 1}


def test_sentinel_loss_spike_needs_history():
    # no history yet: a big (finite) loss is not judged...
    s = StabilitySentinel(_cfg(min_history=4))
    assert s.observe(0, {"loss": 50.0}) is Verdict.OK
    # ...but with a healthy window behind it, the same loss is a spike
    s = StabilitySentinel(_cfg(min_history=4))
    step = _feed_healthy(s, 6)
    assert s.observe(step, {"loss": 50.0}) is Verdict.SKIP
    assert "loss-spike" in s.spike_reasons


def test_sentinel_grad_norm_and_saturation_triggers():
    s = StabilitySentinel(_cfg(sat_threshold=0.25))
    step = _feed_healthy(s, 6)          # sat baseline 0.05 in the window
    assert s.observe(step, {"loss": 2.0,
                            "grad_norm": 100.0}) is Verdict.SKIP
    assert s.observe(step + 1, {"loss": 2.0, "grad_norm": 1.0,
                                "grad_sat": 0.5}) is Verdict.SKIP
    assert set(s.spike_reasons) == {"grad-norm-spike", "moment-saturation"}


def test_sentinel_saturation_needs_step_change_over_ambient():
    # a warm-up plateau above the absolute floor is NOT a spike: the rate
    # must also jump sat_factor-x over its own rolling median
    s = StabilitySentinel(_cfg(sat_threshold=0.25))
    step = _feed_healthy(s, 6, sat=0.3)
    assert s.observe(step, {"loss": 2.0, "grad_norm": 1.0,
                            "grad_sat": 0.35}) is Verdict.OK
    assert s.observe(step + 1, {"loss": 2.0, "grad_norm": 1.0,
                                "grad_sat": 0.9}) is Verdict.SKIP
    assert s.spike_reasons == {"moment-saturation": 1}
    # unarmed window (no sat history yet): never judged
    s2 = StabilitySentinel(_cfg())
    assert s2.observe(0, {"loss": 2.0, "grad_sat": 0.9}) is Verdict.OK


def test_sentinel_escalates_after_skip_limit_then_fallback_absorbs():
    s = StabilitySentinel(_cfg(skip_limit=2, fallback_steps=8))
    step = _feed_healthy(s, 6)
    bad = {"loss": float("nan")}
    assert s.observe(step, bad) is Verdict.SKIP          # spike 1
    assert s.observe(step + 1, bad) is Verdict.SKIP      # spike 2
    v = s.observe(step + 2, bad)                         # spike 3 > limit
    assert v is Verdict.ROLLBACK
    assert s.in_fallback(step + 3)
    # inside the fallback window further spikes only skip (no thrash)
    assert s.observe(step + 3, bad) is Verdict.SKIP
    assert s.counts["rollbacks"] == 1
    # the window closes on schedule
    assert not s.in_fallback(step + 2 + 8)


def test_sentinel_rollback_budget_exhausts():
    s = StabilitySentinel(_cfg(skip_limit=0, fallback_steps=1,
                               max_rollbacks=1))
    bad = {"loss": float("nan")}
    assert s.observe(0, bad) is Verdict.ROLLBACK
    # past the (1-step) window, next spike would escalate -- budget spent
    assert s.observe(5, bad) is Verdict.SKIP
    assert s.exhausted
    assert s.observe(9, bad) is Verdict.SKIP
    assert s.summary()["exhausted"] is True


def test_sentinel_notify_rollback_extends_window():
    s = StabilitySentinel(_cfg(skip_limit=0, fallback_steps=8))
    assert s.observe(20, {"loss": float("nan")}) is Verdict.ROLLBACK
    assert s.fallback_until == 28             # armed at spike step + window
    # the restored step needs the window to cover the whole replayed region
    s.notify_rollback(25)
    assert s.fallback_until == 33


# ---------------------------------------------------------------------------
# fallback_policy keeps the optimizer-state structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fake_quant", "fp"])
def test_fallback_policy_preserves_adam_state_structure(mode):
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(KEY, jnp.float32)
    primary = beyond_paper_recipe()
    degraded = fallback_policy(primary, mode=mode)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                    state_storage="int")
    a = init_adam_state(params, primary, opt)
    b = init_adam_state(params, degraded, opt)
    assert (jax.tree_util.tree_structure(a)
            == jax.tree_util.tree_structure(b))
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype
    # and the degraded policy really is degraded: no int8 kernel backends
    for role in ("attn_qkv", "mlp_up"):
        backend, caps = degraded.effective_backend(role)
        assert backend in ("fp", "fake_quant")
        if mode == "fp":
            r = degraded.resolve(role).recipe
            assert r is None or (r.weights is None and r.acts is None)


# ---------------------------------------------------------------------------
# Guarded trainer integration: the full recovery ladder
# ---------------------------------------------------------------------------

def _smoke_trainer_parts():
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = beyond_paper_recipe()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                    state_storage="int")
    loader = Loader(corpus, cfg, batch_size=4, seq_len=32)
    state = init_train_state(model, KEY, recipe, opt)
    return cfg, model, recipe, opt, loader, state


def test_trainer_recovery_ladder(tmp_path):
    """nan_grad mid-run: skip, escalate to rollback (restores the newest
    checkpoint), run the fallback window past the fault, re-engage, and
    finish with finite loss and a full complement of applied updates."""
    _, model, recipe, opt, loader, state = _smoke_trainer_parts()
    faults = FaultPlan.parse("nan_grad@5")
    step = jax.jit(make_train_step(model, recipe, opt, faults=faults,
                                   health=True))
    fb = jax.jit(make_train_step(model, fallback_policy(recipe), opt,
                                 health=True))
    sentinel = StabilitySentinel(SentinelConfig(
        window=8, min_history=2, skip_limit=1, fallback_steps=4,
        max_rollbacks=3))
    mgr = CheckpointManager(str(tmp_path))
    t = Trainer(step, None, state, loader, ckpt=mgr,
                loop_cfg=LoopConfig(total_steps=12, ckpt_every=3,
                                    log_every=1),
                sentinel=sentinel, fallback_step=fb, faults=faults)
    t.run(rng=KEY)
    summary = t.resilience_summary()
    assert summary["sentinel"]["rollbacks"] == 1
    assert summary["restores"] == 1
    assert summary["skipped_batches"] >= 1
    assert summary["sentinel"]["fallback_steps_run"] >= 1
    assert "nan_grad@5" in summary["faults_fired"]
    # the fault's update never landed, recovery re-ran the region, and the
    # run completed every scheduled update
    assert int(t.state.opt.step) == 12
    for leaf in jax.tree_util.tree_leaves(t.state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert math.isfinite(t.history[-1]["ce"])


def test_trainer_skip_without_checkpoint_degrades(tmp_path):
    """No checkpoint to roll back to: the ladder degrades to skip + fallback
    window instead of dying."""
    _, model, recipe, opt, loader, state = _smoke_trainer_parts()
    faults = FaultPlan.parse("nan_grad@3")
    step = jax.jit(make_train_step(model, recipe, opt, faults=faults,
                                   health=True))
    fb = jax.jit(make_train_step(model, fallback_policy(recipe), opt,
                                 health=True))
    sentinel = StabilitySentinel(SentinelConfig(
        window=8, min_history=2, skip_limit=0, fallback_steps=4))
    t = Trainer(step, None, state, loader, ckpt=None,
                loop_cfg=LoopConfig(total_steps=8, ckpt_every=10**9,
                                    log_every=1),
                sentinel=sentinel, fallback_step=fb, faults=faults)
    t.run(rng=KEY)
    s = t.resilience_summary()
    assert s["skipped_batches"] >= 1          # rollback degraded to skip
    assert s["restores"] == 0
    for leaf in jax.tree_util.tree_leaves(t.state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_preemption_resume_bit_exact_curve(tmp_path):
    """SIGTERM delivered mid-run (sigterm_run fault): emergency save, then a
    fresh process resumes and the remaining loss curve and final params are
    bit-identical to an uninterrupted run."""
    _, model, recipe, opt, loader, state = _smoke_trainer_parts()
    step = jax.jit(make_train_step(model, recipe, opt))
    lcfg = dict(total_steps=10, ckpt_every=10**9, log_every=1)

    # reference: uninterrupted
    ref = Trainer(step, None, state, loader,
                  loop_cfg=LoopConfig(**lcfg))
    ref_hist = ref.run(rng=KEY)

    # interrupted at loop step 4 -> emergency checkpoint at 5
    _, _, _, _, loader2, state2 = _smoke_trainer_parts()
    faults = FaultPlan.parse("sigterm_run@4")
    mgr = CheckpointManager(str(tmp_path))
    t1 = Trainer(step, None, state2, loader2, ckpt=mgr,
                 loop_cfg=LoopConfig(**lcfg), faults=faults)
    old = signal.getsignal(signal.SIGTERM)
    try:
        t1.install_preemption_handler()
        t1.run(rng=KEY)
    finally:
        signal.signal(signal.SIGTERM, old)
    assert t1._preempted
    assert "sigterm_run@4" in faults.fired
    assert mgr.all_steps() == [5]

    # resume and finish
    _, _, _, _, loader3, state3 = _smoke_trainer_parts()
    t2 = Trainer(step, None, state3, loader3, ckpt=mgr,
                 loop_cfg=LoopConfig(**lcfg))
    assert t2.maybe_resume() == 5
    t2.run(rng=KEY)

    ref_tail = [r["ce"] for r in ref_hist if r["step"] > 5]
    got_tail = [r["ce"] for r in t2.history if r["step"] > 5]
    assert got_tail == ref_tail               # bit-identical curve
    for a, b in zip(jax.tree_util.tree_leaves(ref.state.params),
                    jax.tree_util.tree_leaves(t2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # int8 optimizer moments resumed bit-exactly too
    for a, b in zip(jax.tree_util.tree_leaves(ref.state.opt),
                    jax.tree_util.tree_leaves(t2.state.opt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Quant-health counters feeding the sentinel
# ---------------------------------------------------------------------------

def test_moment_saturation_rate_counts_overflow():
    from repro.core.diagnostics import moment_saturation_rate
    spec = QuantSpec(8, Granularity.PER_CHANNEL, block_size=4)
    g = jnp.full((2, 4), 10.0, jnp.float32)
    m = QState(q=jnp.zeros((2, 4), jnp.int8),
               scale=jnp.full((2, 1), 0.001, jnp.float32),
               zero=jnp.zeros((2, 1), jnp.float32))
    grads = {"w": g}
    moments = {"w": m}
    # candidate = 0.9 * 0 + 0.1 * 10 = 1.0 > qmax * 0.001 = 0.127: all over
    assert float(moment_saturation_rate(grads, moments, spec)) == 1.0
    # a scale fitted to the candidate's regime: nothing saturates
    ok = QState(q=m.q, scale=jnp.full((2, 1), 1.0, jnp.float32), zero=m.zero)
    assert float(moment_saturation_rate(grads, {"w": ok}, spec)) == 0.0
    # never-fitted (zero-scale) blocks are excluded, not counted saturated
    fresh = QState(q=m.q, scale=jnp.zeros((2, 1), jnp.float32), zero=m.zero)
    assert float(moment_saturation_rate(grads, {"w": fresh}, spec)) == 0.0
    # no integer-stored moments -> nothing can saturate
    assert moment_saturation_rate(grads, {"w": g}, spec) is None
    assert moment_saturation_rate(grads, moments, None) is None
