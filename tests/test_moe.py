"""MoE dispatch correctness: capacity dispatch == brute-force gated sum."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.common import ACT_FNS, init_from_spec
from repro.core.qpolicy import FP_POLICY, LinearCtx
from repro.models.moe import _capacity, _local_moe, _route, moe_apply, moe_spec

KEY = jax.random.PRNGKey(4)


def _brute_force(x2, params, cfg):
    """For every token: run its top-k experts densely, combine with gates."""
    gates, top_e, _, _ = _route(x2, params["w_router"], cfg, FP_POLICY,
                                LinearCtx("router"))
    act = ACT_FNS[cfg.act]
    outs = []
    for e in range(cfg.n_experts):
        g = act(x2 @ params["w_gate"][e]) * (x2 @ params["w_up"][e])
        outs.append(g @ params["w_down"][e])
    outs = jnp.stack(outs)                           # (E, T, d)
    t = x2.shape[0]
    y = jnp.zeros_like(x2)
    for slot in range(cfg.top_k):
        e_idx = top_e[:, slot]
        w = gates[:, slot]
        y = y + w[:, None] * outs[e_idx, jnp.arange(t)]
    return y


def test_local_dispatch_matches_brute_force_no_drops():
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_from_spec(KEY, moe_spec(cfg))
    t = 64
    x2 = jax.random.normal(KEY, (t, cfg.d_model)) * 0.5
    # capacity = all tokens -> nothing dropped -> exact match
    y, aux, z = _local_moe(x2, params, cfg, FP_POLICY, t * cfg.top_k,
                           None, 0)
    want = _brute_force(x2, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0 and float(z) >= 0


def test_capacity_drops_fall_back_to_zero():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = init_from_spec(KEY, moe_spec(cfg))
    t = 32
    x2 = jax.random.normal(KEY, (t, cfg.d_model)) * 0.5
    y_small, _, _ = _local_moe(x2, params, cfg, FP_POLICY, 1, None, 0)
    y_big, _, _ = _local_moe(x2, params, cfg, FP_POLICY, t * cfg.top_k,
                             None, 0)
    # with capacity 1 most contributions are dropped -> smaller norm
    assert float(jnp.linalg.norm(y_small)) < float(jnp.linalg.norm(y_big))
    assert not bool(jnp.any(jnp.isnan(y_small)))


def test_moe_apply_single_device_path():
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_from_spec(KEY, moe_spec(cfg))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model)) * 0.5
    y, aux, z = moe_apply(params, x, cfg, policy=None, rules=None)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))


def test_router_gates_normalized():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = init_from_spec(KEY, moe_spec(cfg))
    x2 = jax.random.normal(KEY, (16, cfg.d_model))
    gates, top_e, _, _ = _route(x2, params["w_router"], cfg, FP_POLICY,
                                LinearCtx("router"))
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)),
                               np.ones(16), rtol=1e-5)
    assert int(jnp.max(top_e)) < cfg.n_experts


def test_quantized_experts():
    from repro.core import paper_recipe
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_from_spec(KEY, moe_spec(cfg))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model)) * 0.5
    y_fp, _, _ = moe_apply(params, x, cfg, policy=None, rules=None)
    y_q, _, _ = moe_apply(params, x, cfg, policy=paper_recipe(), rules=None)
    delta = float(jnp.max(jnp.abs(y_fp - y_q)))
    assert 0 < delta < 0.5 * float(jnp.max(jnp.abs(y_fp)) + 1e-6)
