"""Pallas kernel validation: interpret-mode sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis, optional (see conftest)

from repro.core.qconfig import Granularity, QuantSpec
from repro.core.quantizer import fake_quant_nograd
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(2)


@pytest.mark.parametrize("shape", [(8, 128), (200, 300), (1024, 64),
                                   (7, 513), (256, 4096)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qdq_row_sweep(shape, bits, dtype):
    x = (jax.random.normal(KEY, shape) * 5).astype(dtype)
    got = ops.fused_fake_quant(x, QuantSpec(bits, Granularity.PER_TOKEN))
    want = ref.qdq_row_ref(x, bits)
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    else:
        # bf16: (a) jit-fused vs eager rounding can resolve ties one grid
        # step apart (both valid quantizations); (b) the bf16 OUTPUT adds a
        # representation error of ~|v|*2^-8.  Assert one-LSB agreement under
        # that combined tolerance, with large agreement in the half-LSB band.
        qmax = 2 ** (bits - 1) - 1
        xf = np.asarray(x, np.float32)
        scale = np.abs(xf).max(-1, keepdims=True) / qmax
        tol = 1.05 * scale + np.abs(w) * 2.0 ** -7 + 1e-6
        err = np.abs(g - w)
        assert (err <= tol).all(), float((err - tol).max())
        assert (err > 0.51 * scale + np.abs(w) * 2.0 ** -7
                ).mean() < 0.01


@pytest.mark.parametrize("gran", [Granularity.PER_CHANNEL,
                                  Granularity.PER_TENSOR])
@pytest.mark.parametrize("bits", [4, 8])
def test_qdq_scaled_matches_core(gran, bits):
    x = jax.random.normal(KEY, (96, 257)) * 2
    spec = QuantSpec(bits, gran)
    got = ops.fused_fake_quant(x, spec)
    want = fake_quant_nograd(x, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (100, 300, 257), (64, 64, 64),
                                   (130, 257, 90)])
def test_int8_matmul_sweep(m, k, n):
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (m, k), jnp.float32) * 2
    w = jax.random.normal(kw, (k, n), jnp.float32)
    got = ops.int8_quantized_matmul(x, w, out_dtype=jnp.float32)

    # Quantizer-contract bound (cross-implementation equality is flaky on
    # round ties): |got - x@w| <= rs_i*cs_j*(0.5*sum|wq| + 0.5*sum|xq| + K/4)
    xf, wf = np.asarray(x, np.float64), np.asarray(w, np.float64)
    rs = np.maximum(np.abs(xf).max(1, keepdims=True), 1e-12) / 127
    cs = np.maximum(np.abs(wf).max(0, keepdims=True), 1e-12) / 127
    bound = (0.5 * rs * cs * (np.abs(wf / cs).sum(0, keepdims=True)
                              + np.abs(xf / rs).sum(1, keepdims=True))
             + rs * cs * k * 0.25) * 1.05 + 1e-5
    err = np.abs(np.asarray(got, np.float64) - xf @ wf)
    assert (err <= bound).all(), float((err - bound).max())
    # and the int core is exact (test_int8_matmul_ref_consistency); here
    # additionally require decent fidelity vs fp
    rel = err.max() / np.abs(xf @ wf).max()
    assert rel < 0.05, rel


def test_int8_matmul_ref_consistency():
    """kernel(int payloads) == ref.int8_matmul_ref exactly."""
    from repro.kernels.int8_matmul import int8_matmul
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randint(-128, 128, (128, 256)), jnp.int8)
    w = jnp.asarray(rng.randint(-128, 128, (256, 128)), jnp.int8)
    rs = jnp.asarray(rng.rand(128, 1).astype(np.float32))
    cs = jnp.asarray(rng.rand(1, 128).astype(np.float32))
    got = int8_matmul(x, w, rs, cs, out_dtype=jnp.float32, interpret=True)
    want = ref.int8_matmul_ref(x, w, rs, cs, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int8_matmul_batched_input():
    x = jax.random.normal(KEY, (2, 10, 64))
    w = jax.random.normal(KEY, (64, 32))
    got = ops.int8_quantized_matmul(x, w, out_dtype=jnp.float32)
    assert got.shape == (2, 10, 32)
    rel = float(jnp.max(jnp.abs(got - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05, rel


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (130, 257, 90),
                                   (64, 300, 100)])
def test_int8_matmul_nt_matches_ref(m, k, n):
    """dx-path kernel (fused g-quant prologue) vs the pure-jnp oracle."""
    from repro.kernels.int8_matmul import int8_matmul_nt
    rng = np.random.RandomState(3)
    g = jnp.asarray(rng.randn(m, n).astype(np.float32))
    wq = jnp.asarray(rng.randint(-128, 128, (k, n)), jnp.int8)
    fold = jnp.asarray(rng.rand(1, n).astype(np.float32) + 0.01)
    qs = jnp.maximum(jnp.max(jnp.abs(g) * fold, axis=1, keepdims=True),
                     1e-12) / 127.0
    pad_r, pad_c = (-m) % 128, (-n) % 128
    pk = (-k) % 128
    gp = jnp.pad(g, ((0, pad_r), (0, pad_c)))
    got = int8_matmul_nt(gp, jnp.pad(wq, ((0, pk), (0, pad_c))),
                         jnp.pad(fold, ((0, 0), (0, pad_c))),
                         jnp.pad(qs, ((0, pad_r), (0, 0))),
                         out_dtype=jnp.float32, interpret=True)[:m, :k]
    want = ref.int8_matmul_nt_ref(g, wq, fold, qs, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (130, 257, 90),
                                   (100, 64, 300)])
def test_int8_matmul_tn_matches_ref(m, k, n):
    """dW-path kernel (fused g-quant prologue) vs the pure-jnp oracle."""
    from repro.kernels.int8_matmul import int8_matmul_tn
    rng = np.random.RandomState(4)
    xq = jnp.asarray(rng.randint(-128, 128, (m, k)), jnp.int8)
    g = jnp.asarray(rng.randn(m, n).astype(np.float32))
    fold = jnp.asarray(rng.rand(m, 1).astype(np.float32) + 0.01)
    qs = jnp.maximum(jnp.max(jnp.abs(g) * fold, axis=0, keepdims=True),
                     1e-12) / 127.0
    pm, pk, pn = (-m) % 128, (-k) % 128, (-n) % 128
    got = int8_matmul_tn(jnp.pad(xq, ((0, pm), (0, pk))),
                         jnp.pad(g, ((0, pm), (0, pn))),
                         jnp.pad(fold, ((0, pm), (0, 0))),
                         jnp.pad(qs, ((0, 0), (0, pn))),
                         out_dtype=jnp.float32, interpret=True)[:k, :n]
    want = ref.int8_matmul_tn_ref(xq, g, fold, qs, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


def test_zero_scale_padding_guard():
    """0-scale rows/cols (zero-padding of ragged shapes) must not emit
    NaN/Inf from the quant prologue's division or the dequant epilogue."""
    from repro.kernels.int8_matmul import int8_matmul, int8_matmul_nt
    rng = np.random.RandomState(5)
    # forward epilogue: one all-zero scale row / col
    x = jnp.asarray(rng.randint(-128, 128, (128, 128)), jnp.int8)
    w = jnp.asarray(rng.randint(-128, 128, (128, 128)), jnp.int8)
    rs = jnp.asarray(rng.rand(128, 1).astype(np.float32)).at[7, 0].set(0.0)
    cs = jnp.asarray(rng.rand(1, 128).astype(np.float32)).at[0, 9].set(0.0)
    out = int8_matmul(x, w, rs, cs, out_dtype=jnp.float32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    # nt prologue: a 0 q_scale row divides g/0 without the guard
    g = jnp.asarray(rng.randn(128, 128).astype(np.float32))
    fold = jnp.asarray(rng.rand(1, 128).astype(np.float32))
    qs = jnp.maximum(jnp.max(jnp.abs(g) * fold, 1, keepdims=True),
                     1e-12) / 127.0
    qs = qs.at[3, 0].set(0.0)
    out = int8_matmul_nt(g, w, fold, qs, out_dtype=jnp.float32,
                         interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    # the dx/dW ops wrappers pad ragged shapes with exactly such 0 scales
    dx = ops.int8_bwd_dx(g[:100, :90], w[:60, :90],
                         jnp.abs(fold[:, :90]) + 0.01)
    dw = ops.int8_bwd_dw(x[:100, :60], jnp.asarray(rng.rand(100, 1),
                                                   jnp.float32),
                         g[:100, :90])
    assert np.isfinite(np.asarray(dx, np.float32)).all()
    assert np.isfinite(np.asarray(dw)).all()
    assert dx.shape == (100, 60) and dw.shape == (60, 90)


def test_fused_fake_quant_routing(monkeypatch):
    """REPRO_FUSED_FQ=1 routes eligible training-path qdq calls through the
    fused Pallas kernel; the reference stays the oracle."""
    from repro.core.qconfig import QuantRecipe, RoundMode
    from repro.core.qlinear import _train_fake_quant, quantized_linear
    x = jax.random.normal(KEY, (96, 257)) * 2
    spec = QuantSpec(8, Granularity.PER_CHANNEL)
    monkeypatch.setenv("REPRO_FUSED_FQ", "1")
    got = _train_fake_quant(x, spec)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fake_quant_nograd(x, spec)),
                               rtol=1e-5, atol=1e-5)
    assert ops.fused_fake_quant_eligible(spec, x)
    # ineligible specs keep the reference: stochastic (needs a key stream)...
    sr = QuantSpec(8, Granularity.PER_TOKEN, round_mode=RoundMode.STOCHASTIC)
    assert not ops.fused_fake_quant_eligible(sr, x)
    # ...asymmetric, blockwise, and 1-D inputs
    assert not ops.fused_fake_quant_eligible(
        QuantSpec(8, Granularity.PER_TOKEN, symmetric=False), x)
    assert not ops.fused_fake_quant_eligible(
        QuantSpec(8, Granularity.PER_TOKEN, block_size=64), x)
    assert not ops.fused_fake_quant_eligible(
        QuantSpec(8, Granularity.PER_TOKEN), x[0])
    # end-to-end: routed fwd+bwd of the fake-quant linear matches unrouted
    r = QuantRecipe(weights=QuantSpec(8, Granularity.PER_CHANNEL),
                    acts=QuantSpec(8, Granularity.PER_TOKEN),
                    grads=QuantSpec(8, Granularity.PER_TOKEN))
    w = jax.random.normal(KEY, (257, 64)) * 0.2

    def loss(xx, ww):
        return jnp.sum(quantized_linear(xx, ww, r) ** 2)

    dx_f, dw_f = jax.grad(loss, argnums=(0, 1))(x, w)
    monkeypatch.setenv("REPRO_FUSED_FQ", "0")
    dx_r, dw_r = jax.grad(loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dx_f), np.asarray(dx_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_f), np.asarray(dw_r),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 300), st.integers(2, 8))
def test_property_qdq_row_any_shape(rows, cols, bits):
    rng = np.random.RandomState(rows * 301 + cols)
    x = jnp.asarray(rng.randn(rows, cols).astype(np.float32))
    got = ops.fused_fake_quant(x, QuantSpec(bits, Granularity.PER_TOKEN))
    want = ref.qdq_row_ref(x, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
