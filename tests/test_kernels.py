"""Pallas kernel validation: interpret-mode sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis, optional (see conftest)

from repro.core.qconfig import Granularity, QuantSpec
from repro.core.quantizer import fake_quant_nograd
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(2)


@pytest.mark.parametrize("shape", [(8, 128), (200, 300), (1024, 64),
                                   (7, 513), (256, 4096)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qdq_row_sweep(shape, bits, dtype):
    x = (jax.random.normal(KEY, shape) * 5).astype(dtype)
    got = ops.fused_fake_quant(x, QuantSpec(bits, Granularity.PER_TOKEN))
    want = ref.qdq_row_ref(x, bits)
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    else:
        # bf16: (a) jit-fused vs eager rounding can resolve ties one grid
        # step apart (both valid quantizations); (b) the bf16 OUTPUT adds a
        # representation error of ~|v|*2^-8.  Assert one-LSB agreement under
        # that combined tolerance, with large agreement in the half-LSB band.
        qmax = 2 ** (bits - 1) - 1
        xf = np.asarray(x, np.float32)
        scale = np.abs(xf).max(-1, keepdims=True) / qmax
        tol = 1.05 * scale + np.abs(w) * 2.0 ** -7 + 1e-6
        err = np.abs(g - w)
        assert (err <= tol).all(), float((err - tol).max())
        assert (err > 0.51 * scale + np.abs(w) * 2.0 ** -7
                ).mean() < 0.01


@pytest.mark.parametrize("gran", [Granularity.PER_CHANNEL,
                                  Granularity.PER_TENSOR])
@pytest.mark.parametrize("bits", [4, 8])
def test_qdq_scaled_matches_core(gran, bits):
    x = jax.random.normal(KEY, (96, 257)) * 2
    spec = QuantSpec(bits, gran)
    got = ops.fused_fake_quant(x, spec)
    want = fake_quant_nograd(x, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (100, 300, 257), (64, 64, 64),
                                   (130, 257, 90)])
def test_int8_matmul_sweep(m, k, n):
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (m, k), jnp.float32) * 2
    w = jax.random.normal(kw, (k, n), jnp.float32)
    got = ops.int8_quantized_matmul(x, w, out_dtype=jnp.float32)

    # Quantizer-contract bound (cross-implementation equality is flaky on
    # round ties): |got - x@w| <= rs_i*cs_j*(0.5*sum|wq| + 0.5*sum|xq| + K/4)
    xf, wf = np.asarray(x, np.float64), np.asarray(w, np.float64)
    rs = np.maximum(np.abs(xf).max(1, keepdims=True), 1e-12) / 127
    cs = np.maximum(np.abs(wf).max(0, keepdims=True), 1e-12) / 127
    bound = (0.5 * rs * cs * (np.abs(wf / cs).sum(0, keepdims=True)
                              + np.abs(xf / rs).sum(1, keepdims=True))
             + rs * cs * k * 0.25) * 1.05 + 1e-5
    err = np.abs(np.asarray(got, np.float64) - xf @ wf)
    assert (err <= bound).all(), float((err - bound).max())
    # and the int core is exact (test_int8_matmul_ref_consistency); here
    # additionally require decent fidelity vs fp
    rel = err.max() / np.abs(xf @ wf).max()
    assert rel < 0.05, rel


def test_int8_matmul_ref_consistency():
    """kernel(int payloads) == ref.int8_matmul_ref exactly."""
    from repro.kernels.int8_matmul import int8_matmul
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randint(-128, 128, (128, 256)), jnp.int8)
    w = jnp.asarray(rng.randint(-128, 128, (256, 128)), jnp.int8)
    rs = jnp.asarray(rng.rand(128, 1).astype(np.float32))
    cs = jnp.asarray(rng.rand(1, 128).astype(np.float32))
    got = int8_matmul(x, w, rs, cs, out_dtype=jnp.float32, interpret=True)
    want = ref.int8_matmul_ref(x, w, rs, cs, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int8_matmul_batched_input():
    x = jax.random.normal(KEY, (2, 10, 64))
    w = jax.random.normal(KEY, (64, 32))
    got = ops.int8_quantized_matmul(x, w, out_dtype=jnp.float32)
    assert got.shape == (2, 10, 32)
    rel = float(jnp.max(jnp.abs(got - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05, rel


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 300), st.integers(2, 8))
def test_property_qdq_row_any_shape(rows, cols, bits):
    rng = np.random.RandomState(rows * 301 + cols)
    x = jnp.asarray(rng.randn(rows, cols).astype(np.float32))
    got = ops.fused_fake_quant(x, QuantSpec(bits, Granularity.PER_TOKEN))
    want = ref.qdq_row_ref(x, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
