"""End-to-end system tests: the paper's recipe exercised through the full
stack (data -> quantized train -> checkpoint -> serve)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import fp_baseline, get_recipe, paper_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import (LoopConfig, Trainer, greedy_generate,
                         init_train_state, make_eval_step, make_train_step)

KEY = jax.random.PRNGKey(0)


def _train(recipe, steps=30, arch="gpt2-small", lr=2e-3, storage="fake"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=lr, warmup_steps=5, total_steps=max(steps, 10),
                    state_storage=storage)
    state = init_train_state(model, KEY, recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    loader = Loader(corpus, cfg, batch_size=8, seq_len=64)
    losses = []
    for i in range(steps):
        state, m = step(state, next(loader), jax.random.fold_in(KEY, i))
        losses.append(float(m["ce"]))
    return cfg, model, state, losses


def test_fp_training_learns():
    _, _, _, losses = _train(fp_baseline())
    assert losses[-1] < losses[0] - 0.15, (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_paper_recipe_trains_comparably_to_fp():
    """W8 per-channel + A8 per-token tracks the fp baseline (Section 4.5)."""
    _, _, _, fp = _train(fp_baseline())
    _, _, _, q = _train(paper_recipe())
    assert q[-1] < q[0] - 0.15
    # final losses within a modest band of each other at this tiny scale
    assert abs(q[-1] - fp[-1]) < 0.35, (fp[-1], q[-1])


def test_beyond_recipe_with_int_states_trains():
    _, _, _, q = _train(get_recipe("beyond"), storage="int")
    assert q[-1] < q[0] - 0.1
    assert all(np.isfinite(q))


def test_full_pipeline_train_checkpoint_serve(tmp_path):
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = paper_recipe()
    opt = OptConfig(lr=2e-3, warmup_steps=5, total_steps=100)
    state = init_train_state(model, KEY, recipe, opt)
    step = jax.jit(make_train_step(model, recipe, opt))
    loader = Loader(corpus, cfg, batch_size=8, seq_len=64)
    valid = Loader(corpus, cfg, batch_size=8, seq_len=64, split="valid")
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    tr = Trainer(step, jax.jit(make_eval_step(model, recipe)), state, loader,
                 ckpt=mgr, valid_loader=valid,
                 loop_cfg=LoopConfig(total_steps=20, ckpt_every=10,
                                     eval_every=10, log_every=5))
    hist = tr.run(rng=KEY)
    mgr.wait()
    assert mgr.latest_step() == 20
    assert any("valid_ce" in row for row in hist)

    # restore into a fresh state and serve
    state2 = init_train_state(model, jax.random.PRNGKey(9), recipe, opt)
    restored, _ = mgr.restore(20, state2)
    prompt = next(loader)["tokens"][:, :32]
    gen = greedy_generate(model, restored.params, {"tokens": prompt}, 8,
                          recipe=recipe)
    assert gen.shape == (8, 8)
    assert int(gen.max()) < cfg.vocab_size
    # generation deterministic
    gen2 = greedy_generate(model, restored.params, {"tokens": prompt}, 8,
                           recipe=recipe)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(gen2))


def test_elastic_restore_respects_target_structure(tmp_path):
    """Restore is mesh/structure-agnostic: same tree, fresh process-style."""
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    opt = OptConfig()
    state = init_train_state(model, KEY, None, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state)
    other = init_train_state(model, jax.random.PRNGKey(99), None, opt)
    restored, _ = mgr.restore(3, other)
    a = jax.tree_util.tree_leaves(state.params)
    b = jax.tree_util.tree_leaves(restored.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
