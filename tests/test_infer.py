"""Inference-engine tests: prepared weights, int8 KV cache, continuous
batching, sampling, and the serving compatibility shim.

The parity and HLO assertions run the gpt2-small smoke config with a float32
carrier: in f32 the prepared-weights dequant grid is bit-identical to
in-trace fake quantization, so greedy outputs must match the legacy loop
exactly (bf16 carriers agree only to rounding noise -- fusion order differs
between the two graphs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (QState, QuantPolicy, QuantRecipe, QuantSpec,
                        Granularity, RoundMode, as_policy, paper_recipe,
                        parse_policy)
from repro.infer import (Engine, Request, SamplingParams, params_nbytes,
                         prepare_params, sample)
from repro.models import build_model
from repro.train import greedy_generate, greedy_generate_reference

KEY = jax.random.PRNGKey(0)


def _setup(dtype="float32"):
    cfg = dataclasses.replace(get_smoke_config("gpt2-small"), dtype=dtype)
    model = build_model(cfg)
    params = model.init_params(KEY)
    return cfg, model, params


@pytest.fixture(scope="module")
def gpt2():
    return _setup()


# ---------------------------------------------------------------------------
# Prepared weights
# ---------------------------------------------------------------------------

def test_prepare_quantizes_policy_scoped_roles(gpt2):
    cfg, model, params = gpt2
    prep = prepare_params(cfg, params, "*=w8c")
    wq = prep["blocks"]["attn"]["wq"]
    assert isinstance(wq, QState)
    assert wq.q.dtype == jnp.int8
    # stacked layout: per-layer per-out-channel scales
    assert wq.q.shape == params["blocks"]["attn"]["wq"].shape
    assert wq.scale.shape == (cfg.n_layers, 1, wq.q.shape[-1])
    # fp-scoped roles stay raw
    assert not isinstance(prep["embed"], QState)
    assert params_nbytes(prep) < params_nbytes(params)


def test_prepare_skips_depth_banded_stacks(gpt2):
    cfg, model, params = gpt2
    prep = prepare_params(cfg, params, "block[0:1].*=fp,*=w8c")
    # mixed-depth resolution -> the stacked weight cannot be uniformly typed
    assert not isinstance(prep["blocks"]["attn"]["wq"], QState)


def test_prepared_matches_fake_quant_grid(gpt2):
    """Dequantized prepared weights == in-trace fake_quant, bit-exact."""
    from repro.core import fake_quant_nograd
    cfg, model, params = gpt2
    prep = prepare_params(cfg, params, "*=w8c")
    w = params["blocks"]["attn"]["wq"]
    qs = prep["blocks"]["attn"]["wq"]
    spec = QuantSpec(8, Granularity.PER_CHANNEL)
    for layer in (0, cfg.n_layers - 1):
        ref = fake_quant_nograd(w[layer], spec)
        deq = ((qs.q[layer].astype(jnp.float32) + qs.zero[layer])
               * qs.scale[layer]).astype(w.dtype)
        assert jnp.array_equal(ref, deq)


def test_prepared_decode_has_no_weight_quant_ops(gpt2):
    """Acceptance criterion: with an int8 weight policy the jitted decode
    step contains ZERO quantize ops (no rounds) -- weights enter as stored
    integer payloads + scales.  The legacy qdq path keeps its rounds (the
    same no-weight-quant-rounds contract must fire on it)."""
    from repro.lint import RuleSpec, run_rules
    cfg, model, params = gpt2
    policy = as_policy("*=w8c")
    prep = prepare_params(cfg, params, policy)
    state = model.init_decode_state(2, 16, 0, jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 4, jnp.int32)

    def dec(p, s, t, q):
        return model.decode(p, s, t, q, policy=policy)

    prepared = jax.jit(dec).lower(prep, state, tok, pos).compile().as_text()
    legacy = jax.jit(dec).lower(params, state, tok, pos).compile().as_text()
    spec = RuleSpec("no-weight-quant-rounds", {"max_rounds": 0})
    assert run_rules(prepared, [spec]) == []
    assert run_rules(legacy, [spec])


def test_engine_parity_with_legacy_greedy(gpt2):
    """Engine greedy decode == legacy fori-loop, fp and W8A8 policies."""
    cfg, model, params = gpt2
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                cfg.vocab_size)
    for recipe in (None, paper_recipe(), "*=w8c"):
        ref = greedy_generate_reference(model, params, {"tokens": prompt}, 6,
                                        recipe=recipe, max_seq=14)
        eng = greedy_generate(model, params, {"tokens": prompt}, 6,
                              recipe=recipe, max_seq=14)
        assert np.array_equal(np.asarray(ref), np.asarray(eng)), recipe


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------

def test_int8_kv_cache_logit_tolerance(gpt2):
    """int8-KV decode tracks fp-KV decode within the documented tolerance
    (|logit diff| < 0.5 on the untrained f32 smoke config; see README) while
    actually quantizing (nonzero difference, smaller cache)."""
    cfg, model, params = gpt2
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    pf = as_policy("*=w8c")
    pq = as_policy("kv_cache=a8t,*=w8c")
    l1, s1 = model.prefill(params, {"tokens": prompt}, policy=pf, max_seq=16)
    l2, s2 = model.prefill(params, {"tokens": prompt}, policy=pq, max_seq=16)
    tok = jnp.argmax(l1, -1)[:, None].astype(jnp.int32)
    d1, _ = model.decode(params, s1, tok, jnp.int32(12), policy=pf)
    d2, _ = model.decode(params, s2, tok, jnp.int32(12), policy=pq)
    diff = float(jnp.max(jnp.abs(d1 - d2)))
    assert 0.0 < diff < 0.5, diff
    # storage really is int8 + scale sidecars, and smaller than fp
    kc = s2["caches"]
    assert kc["k"].dtype == jnp.int8 and "k_scale" in kc
    int8_bytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(s2))
    fp_bytes = sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(s1))
    assert int8_bytes < fp_bytes


def test_fused_decode_no_whole_cache_dequant(gpt2, monkeypatch):
    """Acceptance criterion: with ``kv_cache=a8t`` and the fused kernels on,
    the compiled decode step contains ZERO whole-cache dequantize converts
    (s8 cache -> fp at the full (B, S, K, hd) buffer shape); the reference
    path keeps exactly its K and V buffer converts (the same
    no-whole-cache-dequant contract must fire on it)."""
    from repro.lint import RuleSpec, run_rules
    cfg, model, params = gpt2
    policy = as_policy("kv_cache=a8t,*=w8c")
    prep = prepare_params(cfg, params, policy)
    B, S = 2, 16
    state = model.init_decode_state(B, S, 0, jnp.float32, policy=policy)
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), 4, jnp.int32)
    dims = (B, S, cfg.n_kv_heads, cfg.head_dim)
    spec = RuleSpec("no-whole-cache-dequant",
                    {"min_elems": B * S * cfg.n_kv_heads * cfg.head_dim,
                     "dims": dims})
    found = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED_DECODE", env)

        # distinct closure per env: jit caches by function identity, and the
        # fused switch is read at trace time
        def dec(p, s_, t, q, _env=env):
            return model.decode(p, s_, t, q, policy=policy)

        hlo = jax.jit(dec).lower(prep, state, tok, pos).compile().as_text()
        found[env] = run_rules(hlo, [spec])
    assert found["1"] == [], found
    assert found["0"], found


def test_fused_int8_kv_logit_tolerance(gpt2, monkeypatch):
    """Fused decode tracks fp-KV decode within the same documented tolerance
    as the dequant-on-read reference (|logit diff| < 0.5 on the untrained
    f32 smoke config) -- the fused kernel changes where the dequant runs,
    not the int8-KV approximation itself."""
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1")
    cfg, model, params = gpt2
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    pf = as_policy("*=w8c")
    pq = as_policy("kv_cache=a8t,*=w8c")
    l1, s1 = model.prefill(params, {"tokens": prompt}, policy=pf, max_seq=16)
    l2, s2 = model.prefill(params, {"tokens": prompt}, policy=pq, max_seq=16)
    tok = jnp.argmax(l1, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((2,), 12, jnp.int32)
    d1, _ = model.decode(params, s1, tok, pos, policy=pf)
    d2, _ = model.decode(params, s2, tok, pos, policy=pq)
    diff = float(jnp.max(jnp.abs(d1 - d2)))
    assert 0.0 < diff < 0.5, diff


def test_kv_cache_role_fp_by_default():
    # legacy recipes / wildcard policies must NOT quantize the cache
    assert QuantPolicy.from_recipe(paper_recipe()).kv_spec() is None
    assert parse_policy("*=w8c+a8t").kv_spec() is None
    spec = parse_policy("kv_cache=a8t,*=w8c").kv_spec()
    assert spec is not None and spec.bits == 8
    with pytest.raises(ValueError):
        parse_policy("kv_cache=a8c,*=fp").kv_spec()   # per-channel scales


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_batch_invariance(gpt2):
    """A request's greedy tokens are identical whether it runs alone or
    shares slots with neighbours of different lengths."""
    cfg, model, params = gpt2
    req = [5, 6, 7, 8]

    eng = Engine(model, params, "*=w8c", max_slots=4, max_seq=32, seed=3)
    eng.submit(Request(tokens=req, max_new_tokens=6))
    alone = eng.run()[0].tokens

    eng = Engine(model, params, "*=w8c", max_slots=4, max_seq=32, seed=9)
    ids = [eng.submit(Request(tokens=list(t), max_new_tokens=6))
           for t in ([1, 2], req, [9, 10, 11], [3, 1, 4, 1, 5], [2, 7, 1, 8])]
    crowded = {r.request_id: r.tokens for r in eng.run()}[ids[1]]
    assert alone == crowded


def test_batch_invariance_per_tensor_kv(gpt2):
    """Per-tensor KV specs scale per *slot* write block -- a request's
    stored precision (hence tokens) never depends on batch neighbours."""
    cfg, model, params = gpt2
    req = [5, 6, 7, 8]
    pol = "kv_cache=a8n,*=fp"
    eng = Engine(model, params, pol, max_slots=3, max_seq=24)
    eng.submit(Request(tokens=req, max_new_tokens=5))
    alone = eng.run()[0].tokens
    eng = Engine(model, params, pol, max_slots=3, max_seq=24)
    ids = [eng.submit(Request(tokens=list(t), max_new_tokens=5))
           for t in ([200, 201], req, [9, 10, 11])]
    crowded = {r.request_id: r.tokens for r in eng.run()}[ids[1]]
    assert alone == crowded


def test_generate_raises_on_cache_truncation(gpt2):
    """generate() must not fabricate pad tokens when the cache runs out."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=10)
    with pytest.raises(ValueError, match="truncated"):
        eng.generate(np.arange(8)[None, :] % cfg.vocab_size, 8)


def test_slot_turnover_and_finish_reasons(gpt2):
    """More requests than slots: admit-on-free recycles slots; eos and
    length finishes are reported; responses come back in submit order."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=32)
    probe = Engine(model, params, max_slots=1, max_seq=32)
    probe.submit(Request(tokens=[1, 2, 3], max_new_tokens=1))
    eos = probe.run()[0].tokens[0]           # force an eos hit on request 0

    ids = [eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=5,
                              eos_id=eos))]
    for t in ([4, 5], [6, 7, 8, 9], [2, 2], [3, 1]):
        ids.append(eng.submit(Request(tokens=list(t), max_new_tokens=4)))
    out = eng.run()
    assert [r.request_id for r in out] == sorted(ids)
    by_id = {r.request_id: r for r in out}
    assert by_id[ids[0]].finish_reason == "eos"
    assert by_id[ids[0]].tokens == []        # eos was the FIRST sampled token
    for rid in ids[1:]:
        assert by_id[rid].finish_reason == "length"
        assert len(by_id[rid].tokens) == 4


def test_first_token_eos_regression(gpt2):
    """Regression (legacy path): the first sampled token honours eos_id --
    when the prefill argmax is the eos, the whole row is eos."""
    cfg, model, params = gpt2
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    free = greedy_generate_reference(model, params, {"tokens": prompt}, 5,
                                     max_seq=13)
    eos = int(free[0, 0])
    for fn in (greedy_generate_reference, greedy_generate):
        out = np.asarray(fn(model, params, {"tokens": prompt}, 5,
                            eos_id=eos, max_seq=13))
        assert (out[0] == eos).all(), out[0]
        # rows stopping mid-way pad with eos after the stop
        row = out[1]
        stops = np.where(row == eos)[0]
        if stops.size:
            assert (row[stops[0]:] == eos).all()


@pytest.mark.parametrize("arch", ["mamba2-130m", "granite-moe-3b-a800m"])
def test_engine_other_families(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)
    eng = Engine(model, params, "*=w8c", max_slots=2, max_seq=24)
    eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=4))
    eng.submit(Request(tokens=[5, 6], max_new_tokens=3))
    out = eng.run()
    assert [len(r.tokens) for r in out] == [4, 3]


def test_engine_rejects_unsupported(gpt2):
    cfg, model, params = gpt2
    enc = build_model(get_smoke_config("seamless-m4t-medium"))
    with pytest.raises(ValueError):
        Engine(enc, None)
    eng = Engine(model, params, max_slots=1, max_seq=8)
    with pytest.raises(ValueError):
        eng.submit(Request(tokens=[], max_new_tokens=1))
    with pytest.raises(ValueError):
        eng.submit(Request(tokens=list(range(8)), max_new_tokens=1))


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def test_sampling_params():
    logits = jnp.asarray([[0.0, 3.0, 1.0, 2.0], [5.0, 0.0, 1.0, 0.5]])
    key = jax.random.PRNGKey(0)
    assert sample(logits, SamplingParams(), key).tolist() == [1, 0]
    # top_k=1 at any temperature is greedy
    t = sample(logits, SamplingParams(temperature=2.0, top_k=1), key)
    assert t.tolist() == [1, 0]
    # tiny top_p keeps only the argmax nucleus
    t = sample(logits, SamplingParams(temperature=1.0, top_p=1e-6), key)
    assert t.tolist() == [1, 0]
    # temperature sampling stays within top-k support
    sp = SamplingParams(temperature=1.0, top_k=2)
    draws = {int(sample(logits, sp, jax.random.PRNGKey(i))[0])
             for i in range(32)}
    assert draws <= {1, 3}
    # top_k beyond the vocab clamps instead of raising
    t = sample(logits, SamplingParams(temperature=1.0, top_k=50), key)
    assert all(0 <= int(v) < logits.shape[-1] for v in t)
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)


def test_engine_temperature_sampling(gpt2):
    """Stochastic sampling produces valid tokens and differs across seeds."""
    cfg, model, params = gpt2
    outs = []
    for seed in (0, 1):
        eng = Engine(model, params, max_slots=1, max_seq=24, seed=seed,
                     sampling=SamplingParams(temperature=1.0, top_k=50))
        eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=8))
        outs.append(eng.run()[0].tokens)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    assert outs[0] != outs[1]


# ---------------------------------------------------------------------------
# Backward-path stochastic-rounding keys (satellite fix)
# ---------------------------------------------------------------------------

def test_qlinear_bwd_independent_stochastic_keys():
    """With both gradient paths stochastic, the dx and dW noise streams are
    independent (derived subkeys), and seeded runs stay deterministic."""
    from repro.core.qlinear import quantized_linear
    spec = QuantSpec(8, Granularity.PER_TOKEN, round_mode=RoundMode.STOCHASTIC)
    recipe = QuantRecipe(grads=spec, grads_dx=spec)
    x = jnp.eye(64) * 0.773
    w = jnp.eye(64)

    def loss(x, w, key):
        return jnp.sum(quantized_linear(x, w, recipe, key) * _G)

    _G = jax.random.normal(jax.random.PRNGKey(7), (64, 64)) * 0.371
    key = jax.random.PRNGKey(0)
    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w, key)
    dx2, dw2 = jax.grad(loss, argnums=(0, 1))(x, w, key)
    assert jnp.array_equal(dx, dx2) and jnp.array_equal(dw, dw2)
    # w == I and x == 0.773*I (weights/acts unquantized here), so
    # dx == qdq_dx(G) and dw == 0.773 * qdq_dw(G): a shared key would make
    # the two quantized-G draws coincide elementwise
    assert not jnp.allclose(dx, dw / 0.773, atol=1e-6)
    # different parent keys -> different noise
    dx3, _ = jax.grad(loss, argnums=(0, 1))(x, w, jax.random.PRNGKey(1))
    assert not jnp.array_equal(dx, dx3)
