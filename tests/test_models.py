"""Per-arch smoke tests: reduced config, one train step on CPU, shapes +
no-NaN assertions; decode-vs-full-forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.core import paper_recipe
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, s=S, extra=1):
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(
                    KEY, (B, cfg.num_patches, cfg.d_model)),
                "tokens": jax.random.randint(
                    KEY, (B, s - cfg.num_patches + extra), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(
                    KEY, (B, max(s // cfg.frame_ratio, 1), cfg.d_model)),
                "tokens": jax.random.randint(KEY, (B, s + extra), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(KEY, (B, s + extra), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg, s=32)
    loss, metrics = jax.jit(
        lambda p, b: model.train_loss(p, b, recipe=paper_recipe()))(
            params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0
    grads = jax.jit(jax.grad(
        lambda p: model.train_loss(p, _batch(cfg, s=32),
                                   recipe=paper_recipe())[0]))(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert not bool(jnp.any(jnp.isnan(leaf))), arch


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)

    if cfg.family == "vlm":
        p = cfg.num_patches
        toks = jax.random.randint(KEY, (B, 9), 0, cfg.vocab_size)
        patches = jax.random.normal(KEY, (B, p, cfg.d_model))
        max_seq = p + 12
        _, st = model.prefill(params, {"patches": patches,
                                       "tokens": toks[:, :8]},
                              max_seq=max_seq)
        step_logits, _ = model.decode(params, st, toks[:, 8:9],
                                      jnp.int32(p + 8))
        full_logits, _ = model.prefill(params, {"patches": patches,
                                                "tokens": toks},
                                       max_seq=max_seq)
    elif cfg.family == "encdec":
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
        frames = jax.random.normal(KEY, (B, 4, cfg.d_model))
        _, st = model.prefill(params, {"frames": frames,
                                       "tokens": toks[:, :S]}, max_seq=S + 4)
        step_logits, _ = model.decode(params, st, toks[:, S:S + 1],
                                      jnp.int32(S))
        full_logits, _ = model.prefill(params, {"frames": frames,
                                                "tokens": toks},
                                       max_seq=S + 4)
    else:
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
        _, st = model.prefill(params, {"tokens": toks[:, :S]}, max_seq=S + 4)
        step_logits, _ = model.decode(params, st, toks[:, S:S + 1],
                                      jnp.int32(S))
        full_logits, _ = model.prefill(params, {"tokens": toks},
                                       max_seq=S + 4)
    err = float(jnp.max(jnp.abs(step_logits - full_logits)))
    assert err < 0.15, (arch, err)


def test_full_configs_match_assignment():
    """The exact published numbers (the dry-run exercises the full configs)."""
    from repro.configs import get_config
    c = get_config("granite-moe-3b-a800m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == \
        (32, 1536, 24, 8, 512, 49155, 40, 8)
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == \
        (32, 4096, 32, 8, 6400, 32064, 16, 2)
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.ssm_state) == (54, 2560, 32, 32, 10240, 32000, 64)
    c = get_config("paligemma-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (18, 2048, 8, 1, 16384, 257216)
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.head_dim) == (18, 2048, 8, 1, 16384, 256000, 256)
    c = get_config("qwen3-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (64, 5120, 64, 8, 25600, 151936, True)
    c = get_config("llama3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 8, 14336, 128256)
    c = get_config("yi-6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 4, 11008, 64000)
    c = get_config("seamless-m4t-medium")
    assert (c.n_layers, c.enc_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (12, 12, 1024, 16, 16, 4096, 256206)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.vocab_size, c.ssm_state) == \
        (24, 768, 50280, 128)


def test_param_counts_plausible():
    from repro.configs import get_config
    approx = {
        "llama3-8b": 8.0e9, "yi-6b": 6.1e9, "gemma-2b": 2.5e9,
        "qwen3-32b": 32.8e9, "phi3.5-moe-42b-a6.6b": 41.9e9,
        "granite-moe-3b-a800m": 3.3e9, "mamba2-130m": 0.13e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.7 * want < got < 1.4 * want, (arch, got, want)
