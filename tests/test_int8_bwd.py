"""Real-int8 training backward: transposed-kernel parity vs the fake-quant
vjp, int8 custom_vjp residuals, contract gating + bit-identical fallback,
and the HLO-level acceptance assertions (int8 dots in the backward, no
duplicate quantize in the forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (Granularity, LinearCtx, QuantRecipe, QuantSpec,
                        RoundMode, parse_policy, parse_recipe, quantize_int)
from repro.core.qadam import QState
from repro.core.qlinear import (_qlinear_int8_fwd, int8_backend_supported,
                                int8_bwd_supported)
from repro.models import build_model
from repro.optim import OptConfig
from repro.lint import RuleSpec, run_rules
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(7)
R_FULL = parse_recipe("w8c,a8t,g8t")          # full int8 fwd+bwd contract
POL_INT8 = parse_policy("*=w8c+a8t+g8t@int8_pallas")
POL_FAKE = parse_policy("*=w8c+a8t+g8t")
CTX = LinearCtx("mlp_up")


def _xw(m=128, k=192, n=256, batch=(), scale=0.2, key=KEY):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (*batch, m, k))
    w = jax.random.normal(kw, (k, n)) * scale
    return x, w


def _grads(pol, x, w):
    def loss(xx, ww):
        return jnp.sum(pol.linear(CTX, xx, ww) ** 2)
    return jax.grad(loss, argnums=(0, 1))(x, w)


# ---------------------------------------------------------------------------
# contract
# ---------------------------------------------------------------------------

def test_bwd_contract_gating():
    assert int8_bwd_supported(R_FULL)
    # forward contract alone is not enough: the dW path needs a G spec
    assert not int8_bwd_supported(parse_recipe("w8c,a8t"))
    # out-of-contract gradient codecs fall back
    for bad in ("w8c,a8t,g8t-sr",          # stochastic rounding
                "w8c,a8t,g4t",             # sub-8-bit g
                "w8c,a8t,g8c",             # per-channel g (kernel is per-token)
                "w8c,a8t,g8t,gx8t"):       # grads_dx instability ablation
        r = parse_recipe(bad)
        assert int8_backend_supported(r), bad
        assert not int8_bwd_supported(r), bad
    assert not int8_bwd_supported(None)


# ---------------------------------------------------------------------------
# backward parity vs the fake-quant reference (gpt2-small block shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 512), (256, 128, 384)])
def test_bwd_parity_gpt2_small_shapes(m, k, n):
    """int8-bwd dx/dW track the fake-quant-vjp dx/dW: the only extra noise is
    the 8-bit rounding of the (scale-folded) gradient inside the kernels."""
    x, w = _xw(m, k, n)
    (dx_i, dw_i) = _grads(POL_INT8, x, w)
    (dx_f, dw_f) = _grads(POL_FAKE, x, w)
    for name, a, b in (("dx", dx_i, dx_f), ("dw", dw_i, dw_f)):
        rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(b)))
        assert rel < 0.05, (name, rel)
        assert np.isfinite(np.asarray(a)).all(), name


def test_bwd_parity_batched_and_ragged():
    """Non-block-multiple M/K/N and a leading batch dim: padding lanes carry
    0 scales through the kernels without NaN/Inf."""
    x, w = _xw(33, 257, 90, batch=(3,))
    (dx_i, dw_i) = _grads(POL_INT8, x, w)
    (dx_f, dw_f) = _grads(POL_FAKE, x, w)
    assert dx_i.shape == x.shape and dw_i.shape == w.shape
    for name, a, b in (("dx", dx_i, dx_f), ("dw", dw_i, dw_f)):
        assert np.isfinite(np.asarray(a)).all(), name
        rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(b)))
        assert rel < 0.05, (name, rel)


# ---------------------------------------------------------------------------
# residuals: int8 payloads + scales, quantized exactly once
# ---------------------------------------------------------------------------

def test_residual_payloads_match_quantize_int():
    x, w = _xw(64, 96, 80)
    y, (xs, ws, key, x_shape, xp, wp) = _qlinear_int8_fwd(x, w, None, R_FULL)
    assert isinstance(xs, QState) and isinstance(ws, QState)
    assert xs.q.dtype == jnp.int8 and ws.q.dtype == jnp.int8
    xq_ref, sx_ref, _ = quantize_int(x.reshape(-1, x.shape[-1]), R_FULL.acts)
    wq_ref, sw_ref, _ = quantize_int(w, R_FULL.weights)
    np.testing.assert_array_equal(np.asarray(xs.q), np.asarray(xq_ref))
    np.testing.assert_array_equal(np.asarray(ws.q), np.asarray(wq_ref))
    np.testing.assert_array_equal(np.asarray(xs.scale), np.asarray(sx_ref))
    np.testing.assert_array_equal(np.asarray(ws.scale), np.asarray(sw_ref))
    assert x_shape == x.shape and xp.dtype == x.dtype and wp.dtype == w.dtype


def test_residual_bytes_compressed_4x():
    """Acceptance: custom_vjp residuals of quantized operands are int8
    payloads + scales -- ~4x smaller than the fake path's qdq'd fp copies."""
    x, w = _xw(512, 768, 3072)
    _, res = jax.eval_shape(
        lambda xx, ww: _qlinear_int8_fwd(xx, ww, None, R_FULL), x, w)
    int8_bytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(res)
                     if hasattr(l, "dtype"))
    fake_bytes = (x.size + w.size) * x.dtype.itemsize   # qdq'd fp residuals
    assert int8_bytes < fake_bytes / 3.5, (int8_bytes, fake_bytes)


def test_forward_has_no_duplicate_quantize():
    """Each operand is quantized exactly once in the jitted int8 forward:
    one round op per tensor, and the matmul is a real int8 (s32-result)
    dot."""
    x, w = _xw(64, 96, 80)
    f = jax.jit(lambda xx, ww: POL_INT8.linear(CTX, xx, ww))
    hlo = f.lower(x, w).compile().as_text()
    assert run_rules(hlo, [
        RuleSpec("op-count", {"op_prefix": "round",
                              "min_count": 2, "max_count": 2}),
        RuleSpec("int8-compute-present", {"min_dots": 1}),
        RuleSpec("op-count", {"op_prefix": "dot", "result_type": "s32",
                              "max_count": 1}),
        RuleSpec("double-quantize"),
    ]) == []


def test_backward_hlo_has_int8_dots_for_dx_and_dw():
    """Acceptance: the grad graph holds three s32-result dots -- forward,
    dx (g @ Wq^T) and dW (Xq^T @ g) -- i.e. both backward matmuls hit the
    int8 MXU path, not fp einsums."""
    x, w = _xw(128, 128, 128)

    def loss(xx, ww):
        return jnp.sum(POL_INT8.linear(CTX, xx, ww) ** 2)

    f = jax.jit(jax.grad(loss, argnums=(0, 1)))
    hlo = f.lower(x, w).compile().as_text()
    assert run_rules(hlo, [
        RuleSpec("int8-compute-present", {"min_dots": 3}),
        RuleSpec("op-count", {"op_prefix": "dot", "result_type": "s32",
                              "max_count": 3}),
    ]) == []
    # fake-quant reference: zero integer dots anywhere -- the presence
    # contract must FIRE on it
    g = jax.jit(jax.grad(
        lambda xx, ww: jnp.sum(POL_FAKE.linear(CTX, xx, ww) ** 2),
        argnums=(0, 1)))
    fake_hlo = g.lower(x, w).compile().as_text()
    assert run_rules(fake_hlo, [
        RuleSpec("op-count", {"op_prefix": "dot", "result_type": "s32",
                              "max_count": 0})]) == []
    assert run_rules(fake_hlo, [
        RuleSpec("int8-compute-present", {"min_dots": 1})])


# ---------------------------------------------------------------------------
# fallback: out-of-contract recipes stay bit-identical to the reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["w8c+a8t", "w8c+a8t+g8t+gx8t"])
def test_int8_fwd_fallback_bwd_bit_identical(spec):
    """Recipes inside the forward contract but outside the backward contract
    run the int8 forward with dequantize-on-read residuals; fed the SAME
    output cotangent, the replayed reference vjp must agree with the
    fake-quant backend bit-for-bit (the dequantized payloads reproduce the
    qdq residuals exactly).  The primals themselves only agree to kernel
    tolerance (int32 vs fp32 accumulation) -- that is the forward's
    already-tested contract, not the backward's."""
    x, w = _xw(40, 72, 56)
    pol_i = parse_policy(f"*={spec}@int8_pallas")
    pol_f = parse_policy(f"*={spec}")
    _, vjp_i = jax.vjp(lambda xx, ww: pol_i.linear(CTX, xx, ww), x, w)
    y_f, vjp_f = jax.vjp(lambda xx, ww: pol_f.linear(CTX, xx, ww), x, w)
    g = 2.0 * y_f
    (dx_i, dw_i), (dx_f, dw_f) = vjp_i(g), vjp_f(g)
    np.testing.assert_array_equal(np.asarray(dx_i), np.asarray(dx_f))
    np.testing.assert_array_equal(np.asarray(dw_i), np.asarray(dw_f))


def test_stochastic_grads_fallback_uses_key_bit_identical():
    x, w = _xw(24, 48, 32)
    pol_i = parse_policy("*=w8c+a8t+g8t-sr@int8_pallas")
    pol_f = parse_policy("*=w8c+a8t+g8t-sr")
    rng = jax.random.PRNGKey(11)
    ctx = LinearCtx("mlp_up", rng=rng)
    _, vjp_i = jax.vjp(lambda xx, ww: pol_i.linear(ctx, xx, ww), x, w)
    y_f, vjp_f = jax.vjp(lambda xx, ww: pol_f.linear(ctx, xx, ww), x, w)
    g = 2.0 * y_f
    (dx_i, dw_i), (dx_f, dw_f) = vjp_i(g), vjp_f(g)
    np.testing.assert_array_equal(np.asarray(dx_i), np.asarray(dx_f))
    np.testing.assert_array_equal(np.asarray(dw_i), np.asarray(dw_f))


def test_out_of_forward_contract_falls_back_entirely():
    x, w = _xw(24, 48, 32)
    pol_i = parse_policy("*=w4c+a8t+g8t@int8_pallas")   # 4-bit W: no kernel
    pol_f = parse_policy("*=w4c+a8t+g8t")
    np.testing.assert_array_equal(np.asarray(pol_i.linear(CTX, x, w)),
                                  np.asarray(pol_f.linear(CTX, x, w)))


# ---------------------------------------------------------------------------
# capability plumbing
# ---------------------------------------------------------------------------

def test_effective_backend_capabilities():
    assert POL_INT8.effective_backend("mlp_up") == \
        ("int8_pallas", ("fwd", "bwd"))
    assert parse_policy("*=w8c+a8t@int8_pallas").effective_backend(
        "mlp_up") == ("int8_pallas", ("fwd",))
    assert POL_FAKE.effective_backend("mlp_up") == ("fake_quant", ())
    assert POL_INT8.effective_backend("embed") == ("fp", ())
    # registry fallback applied: 4-bit W on int8_pallas is really fake_quant
    assert parse_policy("*=w4c+a8t@int8_pallas").effective_backend(
        "mlp_up") == ("fake_quant", ())


def test_train_path_summary_strings():
    from repro.train.step import train_path_summary
    s = train_path_summary(POL_INT8)
    assert "int8_pallas(fwd=int8,bwd=int8,res=int8)" in s
    assert "bwd=qdq" in train_path_summary("*=w8c+a8t@int8_pallas")
    assert train_path_summary(None).endswith("=fp")
    # depth-banded policies enumerate the distinct per-layer paths rather
    # than misreporting one band (w4c layers really run the fallback)
    banded = "block[0:2].*=w4c+a8t,*=w8c+a8t+g8t@int8_pallas"
    s = train_path_summary(banded, n_layers=4)
    # w4c layers run the fake-quant fallback; its residuals are int8 QState
    # payloads too (symmetric nearest codec -> dequantize-on-read)
    assert "fake_quant(fwd=qdq,bwd=qdq,res=int8)/int8_pallas" in s
    assert "depth-banded" in train_path_summary(banded)
    # asymmetric codecs keep fp residuals (zero-point breaks the exact
    # int-roundtrip), so the summary reports them honestly
    assert "res=fp" in train_path_summary("*=w8c-asym+a8t-asym")


# ---------------------------------------------------------------------------
# 20-step loss-curve smoke: int8 fwd+bwd vs fake-quant reference
# ---------------------------------------------------------------------------

def test_loss_curve_smoke_int8_bwd_vs_fake():
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=20)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                          cfg.vocab_size)}
    curves = {}
    for name, pol in (("int8", POL_INT8), ("fake", POL_FAKE)):
        state = init_train_state(model, KEY, pol, opt)
        step = jax.jit(make_train_step(model, pol, opt))
        ces = []
        for _ in range(20):
            state, m = step(state, batch, None)
            ces.append(float(m["ce"]))
            assert np.isfinite(ces[-1]) and ces[-1] < 30, (name, ces)
        curves[name] = ces
    # both learn, and the int8 curve tracks the reference
    for name, ces in curves.items():
        assert ces[-1] < ces[0], (name, ces[0], ces[-1])
    assert abs(curves["int8"][-1] - curves["fake"][-1]) < 0.5, curves
