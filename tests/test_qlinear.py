"""Paper Fig-1 forward/backward semantics of the quantized linear."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qconfig import Granularity, QuantRecipe, QuantSpec
from repro.core.qlinear import quantized_linear
from repro.core.quantizer import fake_quant_nograd

KEY = jax.random.PRNGKey(1)
W8 = QuantSpec(8, Granularity.PER_CHANNEL)
A8 = QuantSpec(8, Granularity.PER_TOKEN)
G8 = QuantSpec(8, Granularity.PER_TOKEN)


def _setup():
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (3, 5, 16))
    w = jax.random.normal(kw, (16, 24)) * 0.2
    return x, w


def test_forward_injects_both_errors():
    x, w = _setup()
    r = QuantRecipe(weights=W8, acts=A8)
    y = quantized_linear(x, w, r)
    want = jnp.matmul(fake_quant_nograd(x, A8), fake_quant_nograd(w, W8))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_backward_fig1_semantics():
    """dx uses the REAL gradient + quantized weights; dW uses the QUANTIZED
    gradient + quantized activations."""
    x, w = _setup()
    r = QuantRecipe(weights=W8, acts=A8, grads=G8)

    def loss(xx, ww):
        return jnp.sum(quantized_linear(xx, ww, r) ** 2)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    xq = fake_quant_nograd(x, A8)
    wq = fake_quant_nograd(w, W8)
    g = 2.0 * jnp.matmul(xq, wq)
    dx_ref = jnp.matmul(g, wq.T)                        # real g on dx path
    gq = fake_quant_nograd(g, G8)                       # quantized g on dW
    dw_ref = xq.reshape(-1, 16).T @ gq.reshape(-1, 24)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=2e-5, atol=1e-4)


def test_grads_dx_ablation_quantizes_input_grad_path():
    x, w = _setup()
    r = QuantRecipe(weights=W8, acts=A8, grads_dx=QuantSpec(4, Granularity.PER_TOKEN))

    def loss(xx):
        return jnp.sum(quantized_linear(xx, w, r) ** 2)

    dx = jax.grad(loss)(x)
    xq = fake_quant_nograd(x, A8)
    wq = fake_quant_nograd(w, W8)
    g = 2.0 * jnp.matmul(xq, wq)
    gq = fake_quant_nograd(g, QuantSpec(4, Granularity.PER_TOKEN))
    dx_ref = jnp.matmul(gq, wq.T)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=2e-5, atol=1e-4)


def test_fp_fallback_is_plain_matmul():
    x, w = _setup()
    y = quantized_linear(x, w, QuantRecipe())
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-6)
    y2 = quantized_linear(x, w, None)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(x @ w), rtol=1e-6)


def test_fake_residuals_compressed_when_symmetric_nearest():
    """PR-3's residual trick on the fake-quant reference path: symmetric
    nearest specs store custom-vjp residuals as int8 QState payloads +
    scales (dequantize-on-read), no kernel dependency -- ~4x less live
    memory per linear than the qdq'd fp copies."""
    from repro.core.qlinear import _qlinear_fwd, residual_compressible

    def res_bytes(recipe):
        x = jax.ShapeDtypeStruct((512, 768), jnp.float32)
        w = jax.ShapeDtypeStruct((768, 3072), jnp.float32)
        _, res = jax.eval_shape(
            lambda xx, ww: _qlinear_fwd(xx, ww, None, recipe), x, w)
        return sum(l.size * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(res)
                   if hasattr(l, "dtype"))

    fp_bytes = (512 * 768 + 768 * 3072) * 4
    assert res_bytes(QuantRecipe(weights=W8, acts=A8)) < fp_bytes / 3.5
    # blockwise symmetric codecs compress too (shape recovers tail padding)
    blk = QuantRecipe(weights=QuantSpec(8, Granularity.PER_CHANNEL,
                                        block_size=96), acts=A8)
    assert res_bytes(blk) < fp_bytes / 3.5
    # asymmetric specs keep the fp copy (zero-point breaks the exact
    # int-roundtrip contract); only the eligible operand compresses
    asym_w = QuantSpec(8, Granularity.PER_CHANNEL, symmetric=False)
    assert not residual_compressible(asym_w)
    mixed = res_bytes(QuantRecipe(weights=asym_w, acts=A8))
    assert fp_bytes / 2 < mixed < fp_bytes


def test_fake_residual_roundtrip_grads_exact():
    """Dequantize-on-read residuals reproduce the reference backward
    bit-for-bit, including blockwise specs (padding stripped by shape)."""
    x, w = _setup()
    wblk = QuantSpec(8, Granularity.PER_CHANNEL, block_size=24)
    r = QuantRecipe(weights=wblk, acts=A8, grads=G8)

    def loss(xx, ww):
        return jnp.sum(quantized_linear(xx, ww, r) ** 2)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    xq = fake_quant_nograd(x, A8)
    wq = fake_quant_nograd(w, wblk)
    g = 2.0 * jnp.matmul(xq, wq)
    gq = fake_quant_nograd(g, G8)
    dx_ref = jnp.matmul(g, wq.T)
    dw_ref = xq.reshape(-1, 16).T @ gq.reshape(-1, 24)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=2e-5, atol=1e-4)


def test_quant_noise_shrinks_with_bits():
    x, w = _setup()
    errs = []
    for bits in (2, 4, 8):
        r = QuantRecipe(weights=QuantSpec(bits, Granularity.PER_CHANNEL))
        errs.append(float(jnp.max(jnp.abs(
            quantized_linear(x, w, r) - x @ w))))
    assert errs[0] > errs[1] > errs[2]
