"""Checkpoint manager: atomicity, rotation, async, resume determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import beyond_paper_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import (LoopConfig, Trainer, init_train_state,
                         make_train_step)

KEY = jax.random.PRNGKey(0)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(7, tree, metadata={"note": "x"})
    got, meta = mgr.restore(7, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["note"] == "x"


def test_rotation_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,), jnp.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


def test_resume_bitwise_deterministic(tmp_path):
    """Train 8 steps; separately train 5 + checkpoint + resume 3: identical
    parameters (int8-stored quantized optimizer states included)."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = beyond_paper_recipe()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                    state_storage="int")
    step = jax.jit(make_train_step(model, recipe, opt))

    def fresh():
        return (init_train_state(model, KEY, recipe, opt),
                Loader(corpus, cfg, batch_size=4, seq_len=32))

    # continuous 8 steps
    state, loader = fresh()
    t = Trainer(step, None, state, loader,
                loop_cfg=LoopConfig(total_steps=8, ckpt_every=10**9,
                                    log_every=100))
    t.run(rng=KEY)
    p_cont = t.state.params

    # 5 steps + save, then resume to 8
    state, loader = fresh()
    mgr = CheckpointManager(str(tmp_path))
    t1 = Trainer(step, None, state, loader, ckpt=mgr,
                 loop_cfg=LoopConfig(total_steps=5, ckpt_every=5,
                                     log_every=100))
    t1.run(rng=KEY)
    mgr.wait()

    state2, loader2 = fresh()
    t2 = Trainer(step, None, state2, loader2, ckpt=mgr,
                 loop_cfg=LoopConfig(total_steps=8, ckpt_every=10**9,
                                     log_every=100))
    resumed_at = t2.maybe_resume()
    assert resumed_at == 5
    t2.run(rng=KEY)

    for a, b in zip(jax.tree_util.tree_leaves(p_cont),
                    jax.tree_util.tree_leaves(t2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_saves(tmp_path):
    import signal
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    step = jax.jit(make_train_step(model, None, opt))
    state = init_train_state(model, KEY, None, opt)
    loader = Loader(corpus, cfg, batch_size=4, seq_len=32)
    mgr = CheckpointManager(str(tmp_path))
    t = Trainer(step, None, state, loader, ckpt=mgr,
                loop_cfg=LoopConfig(total_steps=50, ckpt_every=10**9,
                                    log_every=100))
    t._preempted = True           # simulate SIGTERM delivery
    t.run(rng=KEY)
    assert len(mgr.all_steps()) == 1   # emergency checkpoint written
