"""Checkpoint manager: atomicity, rotation, async, resume determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import beyond_paper_recipe
from repro.data import Loader, SyntheticCorpus
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import (LoopConfig, Trainer, init_train_state,
                         make_train_step)

KEY = jax.random.PRNGKey(0)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(7, tree, metadata={"note": "x"})
    got, meta = mgr.restore(7, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["note"] == "x"


def test_rotation_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,), jnp.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


def test_resume_bitwise_deterministic(tmp_path):
    """Train 8 steps; separately train 5 + checkpoint + resume 3: identical
    parameters (int8-stored quantized optimizer states included)."""
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    recipe = beyond_paper_recipe()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                    state_storage="int")
    step = jax.jit(make_train_step(model, recipe, opt))

    def fresh():
        return (init_train_state(model, KEY, recipe, opt),
                Loader(corpus, cfg, batch_size=4, seq_len=32))

    # continuous 8 steps
    state, loader = fresh()
    t = Trainer(step, None, state, loader,
                loop_cfg=LoopConfig(total_steps=8, ckpt_every=10**9,
                                    log_every=100))
    t.run(rng=KEY)
    p_cont = t.state.params

    # 5 steps + save, then resume to 8
    state, loader = fresh()
    mgr = CheckpointManager(str(tmp_path))
    t1 = Trainer(step, None, state, loader, ckpt=mgr,
                 loop_cfg=LoopConfig(total_steps=5, ckpt_every=5,
                                     log_every=100))
    t1.run(rng=KEY)
    mgr.wait()

    state2, loader2 = fresh()
    t2 = Trainer(step, None, state2, loader2, ckpt=mgr,
                 loop_cfg=LoopConfig(total_steps=8, ckpt_every=10**9,
                                     log_every=100))
    resumed_at = t2.maybe_resume()
    assert resumed_at == 5
    t2.run(rng=KEY)

    for a, b in zip(jax.tree_util.tree_leaves(p_cont),
                    jax.tree_util.tree_leaves(t2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_saves(tmp_path):
    import signal
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    step = jax.jit(make_train_step(model, None, opt))
    state = init_train_state(model, KEY, None, opt)
    loader = Loader(corpus, cfg, batch_size=4, seq_len=32)
    mgr = CheckpointManager(str(tmp_path))
    t = Trainer(step, None, state, loader, ckpt=mgr,
                loop_cfg=LoopConfig(total_steps=50, ckpt_every=10**9,
                                    log_every=100))
    t._preempted = True           # simulate SIGTERM delivery
    t.run(rng=KEY)
    assert len(mgr.all_steps()) == 1   # emergency checkpoint written


# ---------------------------------------------------------------------------
# Hardened-checkpoint properties: verification, corruption fallback, async
# error propagation, rotation-vs-restore, atomicity under mid-save faults
# ---------------------------------------------------------------------------

def test_corruption_detected_and_rotation_falls_back(tmp_path):
    """A flipped payload byte fails CRC verification; restore_latest walks
    past the damaged newest checkpoint to the previous intact one."""
    from repro.checkpoint import CheckpointCorrupt
    from repro.train.faults import corrupt_checkpoint

    mgr = CheckpointManager(str(tmp_path))
    # large enough that the flipped mid-file byte lands in payload data,
    # not in zip/npy header padding
    t1 = {"a": jnp.arange(4096.0).reshape(64, 64),
          "nested": {"b": jnp.ones((5,), jnp.int32)}}
    t2 = jax.tree_util.tree_map(lambda x: x + 1, t1)
    mgr.save(1, t1)
    mgr.save(2, t2)
    corrupt_checkpoint(mgr._ckpt_dir(2), mode="flip")
    with pytest.raises(CheckpointCorrupt):
        mgr.verify(2)
    got, _, step = mgr.restore_latest(
        jax.tree_util.tree_map(jnp.zeros_like, t1))
    assert step == 1
    for a, b in zip(jax.tree_util.tree_leaves(t1),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode", ["truncate", "manifest"])
def test_truncation_and_manifest_damage_detected(tmp_path, mode):
    from repro.checkpoint import CheckpointCorrupt
    from repro.train.faults import corrupt_checkpoint

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    corrupt_checkpoint(mgr._ckpt_dir(1), mode=mode)
    with pytest.raises(CheckpointCorrupt):
        mgr.verify(1)
    with pytest.raises(CheckpointCorrupt):
        mgr.restore_latest(jax.tree_util.tree_map(jnp.zeros_like, _tree()))


def test_missing_commit_marker_rejected(tmp_path):
    """The commit marker certifies every earlier byte: a checkpoint dir
    without one (writer died between payload and commit) must not load."""
    from repro.checkpoint import CheckpointCorrupt

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.remove(os.path.join(mgr._ckpt_dir(1), "COMMIT"))
    with pytest.raises(CheckpointCorrupt):
        mgr.verify(1)


def test_async_write_error_propagates_to_next_call(tmp_path):
    """A background-write failure must surface on the next save()/wait(),
    not vanish with the daemon thread."""
    mgr = CheckpointManager(str(tmp_path), async_write=True)

    def boom(step):
        raise RuntimeError("disk on fire")

    mgr.on_mid_write = boom
    mgr.save(1, _tree())                 # starts the doomed background write
    with pytest.raises(RuntimeError, match="disk on fire"):
        mgr.save(2, _tree())             # joins + re-raises before writing
    mgr.on_mid_write = None
    mgr.save(3, _tree())                 # error already consumed; clean write
    mgr.wait()
    assert mgr.all_steps() == [3]


def test_rotation_never_deletes_checkpoint_being_read(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=1)
    mgr.save(1, _tree())
    mgr._reading.add(1)                  # simulate a restore() in flight
    mgr.save(2, _tree())
    mgr.save(3, _tree())
    assert os.path.isdir(mgr._ckpt_dir(1))       # held open: survives
    assert not os.path.isdir(mgr._ckpt_dir(2))   # normal rotation victim
    mgr._reading.discard(1)
    mgr.save(4, _tree())                 # next rotation collects it
    assert mgr.all_steps() == [4]


def test_int8_qstate_tree_roundtrips_bit_exact(tmp_path):
    """Integer optimizer-state sidecar trees (int8 payload + fp32 scale/zero)
    are ordinary leaves: restore returns the stored bytes, no casts."""
    from repro.core import QState

    k1, k2 = jax.random.split(KEY)
    tree = {"m1": QState(
                q=jax.random.randint(k1, (8, 16), -128, 128).astype(jnp.int8),
                scale=jax.random.uniform(k2, (8, 1), jnp.float32),
                zero=jnp.zeros((8, 1), jnp.float32)),
            "w": jnp.linspace(-1.0, 1.0, 32).reshape(4, 8)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    got, _ = mgr.restore(1, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mid_save_abort_leaves_no_partial_checkpoint(tmp_path):
    """A writer dying between payload and commit leaves only a temp dir:
    nothing restorable, prune_incomplete cleans it, the final path never
    appears (atomic-rename contract)."""
    from repro.checkpoint import CheckpointCorrupt

    mgr = CheckpointManager(str(tmp_path))

    def die(step):
        raise KeyboardInterrupt("preempted mid-save")

    mgr.on_mid_write = die
    with pytest.raises(KeyboardInterrupt):
        mgr.save(1, _tree())
    assert mgr.all_steps() == []
    assert not os.path.isdir(mgr._ckpt_dir(1))
    with pytest.raises(CheckpointCorrupt):
        mgr.restore_latest(jax.tree_util.tree_map(jnp.zeros_like, _tree()))
    leftovers = mgr.prune_incomplete()
    assert len(leftovers) == 1 and ".tmp" in leftovers[0]
    assert os.listdir(str(tmp_path)) == []


def test_sigterm_mid_save_keeps_atomicity(tmp_path):
    """The fault harness's sigterm_save lands in the payload/commit window;
    with SIGTERM mapped to an exception the write aborts and the rotation
    still holds only intact checkpoints."""
    import signal
    from repro.train import FaultPlan

    plan = FaultPlan.parse("sigterm_save@1")
    mgr = CheckpointManager(str(tmp_path))
    plan.install(mgr)

    def raise_term(signum, frame):
        raise RuntimeError("SIGTERM")

    old = signal.signal(signal.SIGTERM, raise_term)
    try:
        with pytest.raises(RuntimeError, match="SIGTERM"):
            mgr.save(1, _tree())
    finally:
        signal.signal(signal.SIGTERM, old)
    assert mgr.all_steps() == []                  # nothing half-written
    assert plan.fired == ["sigterm_save@1"]
    mgr.save(2, _tree())                          # fault is one-shot
    assert mgr.all_steps() == [2]
    mgr.restore(2, jax.tree_util.tree_map(jnp.zeros_like, _tree()))
