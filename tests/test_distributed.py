"""Distributed-path tests: run in subprocesses with 8 forced host devices
(the main test process must keep the single real CPU device); the runner is
the shared ``forced8_run`` conftest fixture."""


def test_mini_dryrun_train_compiles_on_mesh(forced8_run):
    """Smoke configs lower+compile+run on a (2,4) data x model mesh; the
    sharded loss equals the single-device loss."""
    print(forced8_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.core import paper_recipe
        from repro.optim import OptConfig
        from repro.parallel.sharding import make_rules
        from repro.train.step import (init_train_state, make_train_step,
                                      state_shardings, batch_shardings)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("llama3-8b", "mamba2-130m"):
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            rules = make_rules(mesh, "train", cfg=cfg)
            recipe = paper_recipe()
            opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
            state = init_train_state(model, jax.random.PRNGKey(0), recipe, opt)
            st_sh = state_shardings(rules, model, jax.eval_shape(lambda: state))
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (8, 33), 0, cfg.vocab_size)}
            b_sh = batch_shardings(rules, jax.eval_shape(lambda: batch))
            step_sh = jax.jit(make_train_step(model, recipe, opt, rules=rules),
                              in_shardings=(st_sh, b_sh, None),
                              out_shardings=(st_sh, None))
            with mesh:
                new_state, metrics = step_sh(state, batch, None)
            step_1d = jax.jit(make_train_step(model, recipe, opt))
            _, metrics_1d = step_1d(state, batch, None)
            d = abs(float(metrics["ce"]) - float(metrics_1d["ce"]))
            print(arch, float(metrics["ce"]), d)
            assert d < 2e-2, (arch, d)
        print("MESH-TRAIN-OK")
    """))


def test_moe_shard_map_modes_match_local(forced8_run):
    """a2a EP / masked EP / ff-sharded outputs == single-device dispatch."""
    print(forced8_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_apply, moe_spec
        from repro.models.common import init_from_spec
        from repro.parallel.sharding import make_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch, s in (("phi3.5-moe-42b-a6.6b", 8),    # E=4 % tp=4 -> a2a
                        ("phi3.5-moe-42b-a6.6b", 3),    # s%tp!=0 -> masked
                        ("granite-moe-3b-a800m", 8)):   # E=8, ff d32%4 -> a2a
            cfg = get_smoke_config(arch)
            params = init_from_spec(jax.random.PRNGKey(0), moe_spec(cfg))
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (4, s, cfg.d_model)) * 0.5
            rules = make_rules(mesh, "train", cfg=cfg)
            with mesh:
                y_sh, aux_sh, z_sh = jax.jit(
                    lambda p, xx: moe_apply(p, xx, cfg, policy=None,
                                            rules=rules))(params, x)
            y_loc, aux_loc, z_loc = moe_apply(params, x, cfg, policy=None,
                                              rules=None)
            err = float(jnp.max(jnp.abs(y_sh - y_loc)))
            rel = err / (float(jnp.max(jnp.abs(y_loc))) + 1e-9)
            print(arch, s, "rel", rel)
            assert rel < 0.05, (arch, s, rel)
        print("MOE-MODES-OK")
    """))


def test_compressed_allreduce_close_to_exact(forced8_run):
    print(forced8_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import shard_map
        from repro.parallel.compress import int8_psum_flat
        mesh = jax.make_mesh((8,), ("d",))
        v = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))

        def body(vb):
            # each rank contributes its own row; compressed psum of the sum
            mine = vb[0]
            return int8_psum_flat(mine, "d")[None, :]

        with mesh:
            got = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d", None),
                                    out_specs=P("d", None),
                                    check_vma=False))(v)
        # every rank's compressed sum approximates the true sum of all rows
        want = jnp.sum(v, axis=0)
        got0 = got[0]
        rel = float(jnp.linalg.norm(got0 - want) / jnp.linalg.norm(want))
        print("rel", rel)
        assert rel < 0.02, rel
        print("COMPRESS-OK")
    """))


def test_compressed_allreduce_tree_matches_fp_psum(forced8_run):
    """compressed_allreduce over a gradient pytree vs the exact fp psum on a
    1-D mesh: same tree structure, <2% relative error per leaf, and the
    ragged leaf exercises the wire padding."""
    print(forced8_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import shard_map
        from repro.parallel.compress import compressed_allreduce
        mesh = jax.make_mesh((8,), ("d",))
        k = jax.random.PRNGKey(0)
        tree = {"w": jax.random.normal(k, (64, 96)),
                "b": jax.random.normal(jax.random.fold_in(k, 1), (131,))}

        got = compressed_allreduce(tree, mesh, "d")

        def fp_body(t):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, "d"), t)

        with mesh:
            want = jax.jit(shard_map(fp_body, mesh=mesh, in_specs=P(),
                                     out_specs=P(), check_vma=False))(tree)
        assert jax.tree_util.tree_structure(got) == \
            jax.tree_util.tree_structure(tree)
        for name in tree:
            g, wnt = got[name], want[name]
            assert g.shape == tree[name].shape, (name, g.shape)
            rel = float(jnp.linalg.norm(g - wnt) / jnp.linalg.norm(wnt))
            print(name, "rel", rel)
            assert rel < 0.02, (name, rel)
        print("COMPRESS-TREE-OK")
    """))


def test_serve_prefill_decode_sharded(forced8_run):
    print(forced8_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.parallel.sharding import make_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("llama3-8b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        rules = make_rules(mesh, "serve", cfg=cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0,
                                  cfg.vocab_size)
        with mesh:
            logits, st = jax.jit(lambda p, b: model.prefill(
                p, b, rules=rules, max_seq=20))(params, {"tokens": toks[:, :16]})
            step_logits, _ = jax.jit(lambda p, s, t, pos: model.decode(
                p, s, t, pos, rules=rules))(params, st, toks[:, 16:17],
                                            jnp.int32(16))
        full_logits, _ = model.prefill(params, {"tokens": toks}, max_seq=20)
        err = float(jnp.max(jnp.abs(step_logits - full_logits)))
        print("err", err)
        assert err < 0.2, err
        print("SERVE-SHARDED-OK")
    """))
