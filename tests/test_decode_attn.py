"""Fused int8-KV decode attention (kernels/decode_attn.py) and the q8
prefill flash kernel: parity with the dequantize-whole-buffer reference
across GQA/MQA head ratios, ragged per-slot lengths, fused quantize+scatter
exactness, tile-size invariance, the REPRO_DECODE_BLOCK hook, capability
reporting, and the fp-KV regression guard.

All kernels run interpret mode here (CPU CI); TPU is the compile target.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import as_policy, parse_policy
from repro.core.qconfig import Granularity, QuantSpec
from repro.core.quantizer import quantize_int
from repro.kernels.decode_attn import (Q_TILE_SUBLANES, decode_attention,
                                       decode_kv_read_bytes, default_block_k,
                                       fused_decode_enabled)
from repro.kernels.flash_attn import flash_attention_fwd_q8
from repro.models import build_model

SPEC = QuantSpec(8, Granularity.PER_TOKEN)

# the dequantize-whole-buffer oracle + ragged-cache fixture live in
# kernels/ref.py (shared with the benchmark's CI parity gate)
from repro.kernels.ref import decode_attn_inputs, decode_attn_ref

_ref_decode = decode_attn_ref


def _inputs(b, s, kh, g, hd, lengths, seed=0):
    q, kq, ks, vq, vs, _, _, nk, nv, pos = decode_attn_inputs(
        b, s, kh, g, hd, lengths, seed)
    return q, kq, ks, vq, vs, nk, nv, pos


# ---------------------------------------------------------------------------
# Kernel-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (3, 1)])  # MHA / GQA / MQA
def test_fused_vs_reference_parity(h, kh):
    g = h // kh
    args = _inputs(3, 12, kh, g, 8, lengths=[1, 5, 11])
    ref, _ = _ref_decode(*args)
    out, *_ = decode_attention(*args, block_k=4, interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_fused_scatter_exact_and_rows_untouched():
    """The in-kernel quantize+scatter writes exactly the `_kv_quant` codec
    (same payload bits, same scales) at row pos[b] and touches nothing else."""
    q, kq, ks, vq, vs, nk, nv, pos = _inputs(2, 8, 2, 2, 8, lengths=[3, 6])
    _, (rkq, rks, rvq, rvs) = _ref_decode(q, kq, ks, vq, vs, nk, nv, pos)
    _, fkq, fks, fvq, fvs = decode_attention(q, kq, ks, vq, vs, nk, nv, pos,
                                             block_k=4, interpret=True)
    assert jnp.array_equal(fkq, rkq) and jnp.array_equal(fvq, rvq)
    np.testing.assert_allclose(np.asarray(fks), np.asarray(rks), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fvs), np.asarray(rvs), rtol=1e-6)


@pytest.mark.parametrize("g", [1, 2, 4])
def test_lane_align_small_g_bitwise_inert(g):
    """Small-GQA query tiles (G < the 8-sublane VPU tile) are zero-padded to
    lane width inside the kernel; the pad rows are softmax-inert, so the
    trimmed output must be *bitwise* what an explicitly lane-wide launch
    computes for the real rows -- and the cache scatter identical."""
    assert g < Q_TILE_SUBLANES
    q, kq, ks, vq, vs, nk, nv, pos = _inputs(2, 12, 2, g, 8,
                                             lengths=[4, 9], seed=7)
    small = decode_attention(q, kq, ks, vq, vs, nk, nv, pos,
                             block_k=4, interpret=True)
    qp = jnp.concatenate(
        [q, jnp.zeros((2, 2, Q_TILE_SUBLANES - g, 8), q.dtype)], axis=2)
    wide = decode_attention(qp, kq, ks, vq, vs, nk, nv, pos,
                            block_k=4, interpret=True)
    assert small[0].shape == q.shape
    assert jnp.array_equal(small[0], wide[0][:, :, :g])
    for a, b in zip(small[1:], wide[1:]):      # scatter payloads + scales
        assert jnp.array_equal(a, b)
    # and the aligned path still matches the dequantize-whole-buffer oracle
    ref, _ = _ref_decode(q, kq, ks, vq, vs, nk, nv, pos)
    np.testing.assert_allclose(np.asarray(small[0]), np.asarray(ref),
                               atol=1e-5)


def test_tile_size_invariance():
    """Online softmax result must not depend on the kv tile length (the
    REPRO_DECODE_BLOCK sweep axis), including non-dividing requests that
    shrink to a divisor."""
    args = _inputs(2, 12, 2, 2, 8, lengths=[4, 9], seed=3)
    outs = [decode_attention(*args, block_k=bk, interpret=True)[0]
            for bk in (2, 4, 6, 12, 5)]       # 5 -> shrinks to 2
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


def test_pos_zero_attends_only_new_row():
    """A slot with no history (pos == 0: free slot riding the batched step)
    attends on exactly the freshly written row -- no NaN from the empty
    prefix, same as the reference mask."""
    args = _inputs(2, 8, 2, 2, 8, lengths=[0, 7], seed=5)
    ref, _ = _ref_decode(*args)
    out, *_ = decode_attention(*args, block_k=4, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_scale_zero_padding_nan_safe():
    """Never-written rows carry scale == 0; plant garbage payloads there to
    prove the guard + validity mask keep the result finite and correct."""
    q, kq, ks, vq, vs, nk, nv, pos = _inputs(2, 8, 2, 2, 8, lengths=[2, 5])
    tail = (jnp.arange(8)[None, :, None, None] >= pos[:, None, None, None])
    kq = jnp.where(tail, 127, kq).astype(jnp.int8)   # garbage payload,
    vq = jnp.where(tail, -128, vq).astype(jnp.int8)  # scale stays 0
    ref, _ = _ref_decode(q, kq, ks, vq, vs, nk, nv, pos)
    out, *_ = decode_attention(q, kq, ks, vq, vs, nk, nv, pos,
                               block_k=4, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_pos_at_max_seq_clamps_scatter():
    """The degenerate freed-slot case: a slot decoding with pos == max_seq
    (stale position of a length-finished slot) must not index past the
    cache.  The scatter clamps to the last row (dynamic_update_slice
    semantics); the slot's own output is discarded by the scheduler, so the
    contract is: finite result, neighbours bit-unaffected."""
    q, kq, ks, vq, vs, nk, nv, _ = _inputs(2, 8, 2, 2, 8, lengths=[3, 6])
    pos_edge = jnp.asarray([8, 6], jnp.int32)          # slot 0 at max_seq
    pos_ok = jnp.asarray([3, 6], jnp.int32)
    edge = decode_attention(q, kq, ks, vq, vs, nk, nv, pos_edge,
                            block_k=4, interpret=True)
    ok = decode_attention(q, kq, ks, vq, vs, nk, nv, pos_ok,
                          block_k=4, interpret=True)
    assert bool(jnp.all(jnp.isfinite(edge[0])))
    # slot 0's write clamped into the last row (same payload the in-bounds
    # launch scattered at its row)
    nkq, _, _ = quantize_int(nk, SPEC)
    assert jnp.array_equal(edge[1][0, 7], nkq[0])
    assert jnp.array_equal(ok[1][0, 3], nkq[0])
    # slot 1 (valid pos) is bit-identical across the two launches
    for a, b in zip(edge[1:], ok[1:]):
        assert jnp.array_equal(a[1], b[1])


def test_block_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_DECODE_BLOCK", raising=False)
    assert default_block_k() == 256
    monkeypatch.setenv("REPRO_DECODE_BLOCK", "32")
    assert default_block_k() == 32
    # the enable switch: forced on/off beats the backend default
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1")
    assert fused_decode_enabled()
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    assert not fused_decode_enabled()


def test_kv_read_bytes_ordering():
    """The analytic counter encodes the roofline claim: fused < fp <<
    dequant-on-read, and fused is < 1/3 of dequant for any fp width."""
    for fpb in (2, 4):
        fused = decode_kv_read_bytes("fused", 8, 2048, 8, 128, fp_bytes=fpb)
        fp = decode_kv_read_bytes("fp", 8, 2048, 8, 128, fp_bytes=fpb)
        deq = decode_kv_read_bytes("dequant", 8, 2048, 8, 128, fp_bytes=fpb)
        assert fused < fp < deq
        assert fused * 3 < deq
    with pytest.raises(ValueError):
        decode_kv_read_bytes("nope", 1, 1, 1, 1)


# ---------------------------------------------------------------------------
# q8 prefill flash kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (2, 1)])
def test_q8_prefill_flash_parity(h, kh):
    """Dequant-prologue flash forward == whole-buffer dequant + causal
    softmax, with the never-written cache tail (rows >= s) hidden by the
    causal mask."""
    b, s, smax, hd = 2, 6, 10, 8
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    kf = jax.random.normal(keys[0], (b, smax, kh, hd), jnp.float32)
    vf = jax.random.normal(keys[1], (b, smax, kh, hd), jnp.float32)
    kq, ks, _ = quantize_int(kf, SPEC)
    vq, vs, _ = quantize_int(vf, SPEC)
    written = (jnp.arange(smax) < s)[None, :, None, None]
    kq, vq = jnp.where(written, kq, 0), jnp.where(written, vq, 0)
    ks, vs = jnp.where(written, ks, 0.0), jnp.where(written, vs, 0.0)
    q = jax.random.normal(keys[2], (b, s, h, hd), jnp.float32)

    out = flash_attention_fwd_q8(q, kq, ks, vq, vs, causal=True,
                                 block_q=4, block_k=2, interpret=True)

    g = h // kh
    kfd = (kq.astype(jnp.float32) * jnp.where(ks == 0, 1.0, ks))
    vfd = (vq.astype(jnp.float32) * jnp.where(vs == 0, 1.0, vs))
    kfd = jnp.repeat(kfd, g, axis=2)
    vfd = jnp.repeat(vfd, g, axis=2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kfd) / np.sqrt(hd)
    causal = jnp.arange(smax)[None, :] <= jnp.arange(s)[:, None]
    s_ = jnp.where(causal[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, vfd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# Capability reporting + model/engine integration
# ---------------------------------------------------------------------------

def test_decode_attn_backend_reporting():
    assert as_policy("kv_cache=a8t,*=w8c").decode_attn_backend() == \
        ("int8_pallas", ("decode", "prefill"))
    # explicit backend rule reports the same capability
    assert parse_policy("kv_cache=a8t@int8_pallas,*=w8c").decode_attn_backend() \
        == ("int8_pallas", ("decode", "prefill"))
    # per-tensor KV scales per slot write block: no kernel fits -> dequant
    assert as_policy("kv_cache=a8n,*=fp").decode_attn_backend() == \
        ("dequant", ())
    # fp cache
    assert as_policy("*=w8c").decode_attn_backend() == ("fp", ())
    assert as_policy(None).decode_attn_backend() == ("fp", ())


@pytest.fixture(scope="module")
def gpt2():
    cfg = dataclasses.replace(get_smoke_config("gpt2-small"), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def test_model_fused_decode_parity(gpt2, monkeypatch):
    """Full-model prefill+decode: fused kernels vs the reference path agree
    to fp-association noise (f32 carrier), and the cache payloads match."""
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 12, jnp.int32)
    outs = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED_DECODE", env)
        lg, st = model.prefill(params, {"tokens": prompt}, policy=pol,
                               max_seq=16)
        dl, st2 = model.decode(params, st, tok, pos, policy=pol)
        outs[env] = (lg, dl, st2)
    assert float(jnp.max(jnp.abs(outs["1"][0] - outs["0"][0]))) < 1e-3
    assert float(jnp.max(jnp.abs(outs["1"][1] - outs["0"][1]))) < 1e-3
    assert jnp.array_equal(outs["1"][2]["caches"]["k"],
                           outs["0"][2]["caches"]["k"])
    assert jnp.array_equal(outs["1"][2]["caches"]["v"],
                           outs["0"][2]["caches"]["v"])


def test_engine_slot_turnover_fused(gpt2, monkeypatch):
    """Continuous batching on the fused path: ragged prompts, more requests
    than slots, slot reuse mid-run -- greedy tokens identical to the
    reference path's."""
    from repro.infer import Engine, Request
    cfg, model, params = gpt2
    outs = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED_DECODE", env)
        eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=2,
                     max_seq=24)
        ids = [eng.submit(Request(tokens=list(t), max_new_tokens=4))
               for t in ([1, 2, 3], [4, 5], [6, 7, 8, 9], [2, 2], [3, 1, 4])]
        outs[env] = {r.request_id: r.tokens for r in eng.run()}
        assert sorted(outs[env]) == sorted(ids)
    assert outs["0"] == outs["1"]


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "granite-moe-3b-a800m"])
def test_fused_other_families_tolerance(arch, monkeypatch):
    """Hybrid (shared attention block) and MoE families on their native bf16
    carrier: fused vs reference agree to bf16 rounding noise (the kernel
    keeps f32 in-register where the reference casts dequantized K/V to the
    carrier), far inside the documented int8-KV tolerance."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    res = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED_DECODE", env)
        lg, st = model.prefill(params, {"tokens": prompt},
                               policy="kv_cache=a8t,*=w8c", max_seq=12)
        dl, _ = model.decode(params, st, tok, pos,
                             policy="kv_cache=a8t,*=w8c")
        res[env] = dl
        assert bool(jnp.all(jnp.isfinite(dl)))
    assert float(jnp.max(jnp.abs(res["1"] - res["0"]))) < 0.05


def test_engine_path_summary_reports_fused(gpt2, monkeypatch):
    from repro.infer import Engine
    cfg, model, params = gpt2
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1")
    eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=2, max_seq=16)
    # b16: the summary names the tile the kernel compiles for 16-row caches
    # (effective_block_k), not the b256 default request
    assert eng.path_summary() == "weights=prepared-int8 kv=int8-fused(b16)"
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    # the mode is snapshotted at construction and pinned around the traces:
    # the live engine keeps reporting (and running) fused
    assert eng.path_summary() == "weights=prepared-int8 kv=int8-fused(b16)"
    deq = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=2, max_seq=16)
    assert deq.path_summary() == "weights=prepared-int8 kv=int8-dequant"
    fp = Engine(model, params, "*=fp", max_slots=2, max_seq=16,
                prepare_weights=False)
    assert fp.path_summary() == "weights=raw kv=fp"
    assert eng.kv_decode_read_bytes() < fp.kv_decode_read_bytes()
    assert fp.kv_decode_read_bytes() < deq.kv_decode_read_bytes()
    # ... and the pin is applied around the (lazy) trace, not just the
    # report: with the env flipped to 0, tracing `eng`'s decode step still
    # compiles the fused path (zero whole-cache dequantize converts)
    from repro.lint import RuleSpec, run_rules
    dims = (2, 16, cfg.n_kv_heads, cfg.head_dim)
    assert run_rules(eng.lowered_decode_hlo(),
                     [RuleSpec("no-whole-cache-dequant",
                               {"min_elems": 2 * 16 * cfg.n_kv_heads
                                             * cfg.head_dim,
                                "dims": dims})]) == []


def test_fp_kv_regression_guard(gpt2, monkeypatch):
    """The non-quantized KV path is untouched by the fused dispatch: an fp
    policy's decode is bit-identical (and structurally int8-free) whether
    the fused switch is on or off."""
    cfg, model, params = gpt2
    pol = as_policy("*=w8c")        # int8 weights, fp KV cache
    state = model.init_decode_state(2, 16, 0, jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.full((2,), 4, jnp.int32)
    outs, hlos = {}, {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED_DECODE", env)

        def dec(p, s_, t, q, _env=env):
            return model.decode(p, s_, t, q, policy=pol)

        outs[env], _ = jax.jit(dec)(params, state, tok, pos)
        hlos[env] = jax.jit(dec).lower(params, state, tok,
                                       pos).compile().as_text()
    assert jnp.array_equal(outs["0"], outs["1"])
    # the fp KV buffers never pass through an int8 cast on either setting
    for hlo in hlos.values():
        assert f"s8[2,16,{cfg.n_kv_heads},{cfg.head_dim}]" not in hlo
