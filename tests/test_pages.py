"""Paged int8 KV cache + async continuous batching tests.

Parity assertions run the gpt2-small smoke config with a float32 carrier
(same reasoning as tests/test_infer.py): greedy decode through the paged
engine must be *bit-identical* to the dense engine -- the page indirection
relocates cache rows, it must never change a single stored byte.  The
freed-page hygiene test is the sharp end of that claim: a request decoding
into recycled pages (LIFO free list, garbage from the previous tenant still
in the payload rows) must produce the same tokens as one decoding into a
never-used pool."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.infer import (CapacityError, Engine, PagePool, Request,
                         init_paged_caches, page_nbytes, pages_for)
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _setup(dtype="float32"):
    cfg = dataclasses.replace(get_smoke_config("gpt2-small"), dtype=dtype)
    model = build_model(cfg)
    params = model.init_params(KEY)
    return cfg, model, params


@pytest.fixture(scope="module")
def gpt2():
    return _setup()


def _tokens(eng, prompts, max_new=5):
    ids = [eng.submit(Request(tokens=list(p), max_new_tokens=max_new))
           for p in prompts]
    by_id = {r.request_id: r.tokens for r in eng.run()}
    return [by_id[i] for i in ids]


PROMPTS = ([1, 2, 3], [7, 8, 9, 10, 11, 12, 13, 14, 15], [4, 5],
           [20, 21, 22, 23, 24, 25])


# ---------------------------------------------------------------------------
# PagePool allocator
# ---------------------------------------------------------------------------

def test_page_pool_alloc_recycle_refcount():
    pool = PagePool(n_pages=6, page_size=4, max_slots=2, max_pages_per_slot=4)
    assert pool.free_pages == 5 and pool.live_pages == 0   # page 0 reserved
    a = pool.alloc(3)
    assert len(set(a)) == 3 and 0 not in a
    assert pool.free_pages == 2 and pool.live_pages == 3
    pool.assign(0, a)
    assert pool.slot_pages(0) == a
    assert list(pool.table[0]) == a + [0]                  # tail -> trash page

    # LIFO: the page released last is handed out first
    freed = pool.release_slot(0)
    assert freed == a and pool.live_pages == 0
    assert pool.alloc(1) == [a[-1]]
    pool.release([a[-1]])

    # prefix sharing: one more ref, no new pages
    pids = pool.alloc(2)
    pool.assign(0, pids)
    shared = pool.share(pids)
    pool.assign(1, shared)
    assert pool.live_pages == 2 and pool.refcount[pids[0]] == 2
    pool.release_slot(0)
    assert pool.live_pages == 2                            # slot 1 still holds
    pool.release_slot(1)
    assert pool.live_pages == 0 and pool.free_pages == 5

    # pinned pages survive release; trash page never enters the free list
    pids = pool.alloc(1)
    pool.pin(pids)
    pool.release(pids)
    assert pool.live_pages == 1 and pids[0] not in pool._free
    with pytest.raises(CapacityError) as ei:
        pool.alloc(99)
    assert ei.value.pages_needed == 99
    assert ei.value.pages_total == 5
    assert ei.value.pages_free == pool.free_pages


def test_pages_for_and_page_nbytes(gpt2):
    cfg, _, _ = gpt2
    assert [pages_for(n, 4) for n in (1, 4, 5, 8, 9)] == [1, 1, 2, 2, 3]
    page = 4
    fp = init_paged_caches(cfg, 3, page, jnp.float32)
    per_page = cfg.n_layers * page * cfg.n_kv_heads * cfg.head_dim * 4
    assert page_nbytes(fp) == 2 * per_page                 # k + v
    from repro.core import parse_policy
    q = init_paged_caches(cfg, 3, page, jnp.float32,
                          kv_spec=parse_policy("kv_cache=a8t,*=fp").kv_spec())
    assert q["k"].dtype == jnp.int8 and "k_scale" in q
    assert page_nbytes(q) < page_nbytes(fp)


# ---------------------------------------------------------------------------
# Capacity accounting
# ---------------------------------------------------------------------------

def test_capacity_error_accounting(gpt2):
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=16, paged=True,
                 page_size=4, n_pages=4)
    with pytest.raises(CapacityError) as ei:
        eng.submit(Request(tokens=list(range(16)), max_new_tokens=1))
    e = ei.value
    assert "pages" in str(e)
    assert (e.tokens, e.max_seq, e.page_size) == (16, 16, 4)
    assert e.pages_needed == pages_for(17, 4)
    assert (e.slots_total, e.slots_free) == (2, 2)

    # fits in a slot but would exhaust the pool even running alone
    with pytest.raises(CapacityError) as ei:
        eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=20))
    assert ei.value.pages_needed == 4 and ei.value.pages_total == 3

    # dense rejection carries the accounting too (and stays a ValueError)
    dense = Engine(model, params, max_slots=1, max_seq=10)
    with pytest.raises(ValueError) as ei:
        dense.submit(Request(tokens=list(range(10)), max_new_tokens=1))
    assert isinstance(ei.value, CapacityError)
    assert (ei.value.tokens, ei.value.max_seq) == (10, 10)


def test_generate_truncation_names_paged_limits(gpt2):
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=16, paged=True,
                 page_size=4)
    with pytest.raises(ValueError, match="truncated") as ei:
        eng.generate(np.arange(8)[None, :] % cfg.vocab_size, 12)
    assert "pages" in str(ei.value) and "n_pages" in str(ei.value)


# ---------------------------------------------------------------------------
# Paged vs dense bit parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["*=w8c", "kv_cache=a8t,*=w8c"])
def test_paged_matches_dense_greedy(gpt2, policy):
    """Greedy tokens through the paged engine == dense engine, bit for bit
    (fp KV and the int8 gather path; mixed prompt lengths exercise packed
    prefill + ragged page counts)."""
    cfg, model, params = gpt2
    dense = Engine(model, params, policy, max_slots=4, max_seq=32)
    paged = Engine(model, params, policy, max_slots=4, max_seq=32,
                   paged=True, page_size=8)
    ref = _tokens(dense, PROMPTS)
    got = _tokens(paged, PROMPTS)
    assert got == ref


def test_paged_matches_dense_fused(gpt2, monkeypatch):
    """Same bit-parity claim on the fused Pallas paged-decode path: the
    dense kernel's tile is pinned to the page size so both engines compile
    identical per-tile reductions."""
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1")
    monkeypatch.setenv("REPRO_DECODE_BLOCK", "8")
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    dense = Engine(model, params, pol, max_slots=2, max_seq=32)
    paged = Engine(model, params, pol, max_slots=2, max_seq=32,
                   paged=True, page_size=8)
    assert "paged-fused" in paged.path_summary()
    prompts = ([1, 2, 3, 4, 5, 6, 7], [9, 10, 11])
    assert _tokens(paged, prompts, 4) == _tokens(dense, prompts, 4)


def test_freed_page_hygiene(gpt2):
    """A request decoding into *recycled* pages (previous tenant's int8
    garbage still in the payload/scale rows) produces tokens bit-identical
    to the same request on a never-used pool."""
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    b_prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]

    eng = Engine(model, params, pol, max_slots=2, max_seq=32, paged=True,
                 page_size=8)
    # tenant A dirties pages across the pool, finishes, pages recycle
    [a_toks] = _tokens(eng, [[11, 12, 13, 14, 15, 16, 17, 18, 19]], 8)
    assert eng.pool.live_pages == 0
    [b_reused] = _tokens(eng, [b_prompt], 8)

    fresh = Engine(model, params, pol, max_slots=2, max_seq=32, paged=True,
                   page_size=8)
    [b_fresh] = _tokens(fresh, [b_prompt], 8)
    assert b_reused == b_fresh
    assert len(a_toks) == 8


def test_live_kv_bytes_scale_with_pages(gpt2):
    """Paged decode memory scales with live tokens, not slots x max_seq."""
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    dense = Engine(model, params, pol, max_slots=4, max_seq=32)
    paged = Engine(model, params, pol, max_slots=4, max_seq=32,
                   paged=True, page_size=8)
    assert paged.live_kv_bytes() == 0
    _tokens(paged, PROMPTS)
    peak = paged.scheduler.peak_live_bytes
    assert 0 < peak < dense.kv_cache_nbytes()
    assert paged.live_kv_bytes() == 0                      # all pages freed


# ---------------------------------------------------------------------------
# Admission: HOL blocking, starvation bound, preemption
# ---------------------------------------------------------------------------

def test_hol_admission_and_starvation_bound(gpt2):
    """A queue-head request that does not fit must not block admissible
    requests behind it -- and every request still completes (the skip
    counter turns the head into a barrier before it can starve)."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=32, paged=True,
                 page_size=8, n_pages=9)                   # 8 allocatable
    big = list(range(1, 21))                               # 20 toks, 3 pages
    ids = [eng.submit(Request(tokens=big, max_new_tokens=8))]
    for t in ([1, 2], [3, 4, 5], [6, 7], [8, 9, 10], [11, 12]):
        ids.append(eng.submit(Request(tokens=list(t), max_new_tokens=6)))
    out = eng.run()
    assert sorted(r.request_id for r in out) == sorted(ids)
    by_id = {r.request_id: r for r in out}
    assert len(by_id[ids[0]].tokens) == 8
    assert all(len(by_id[i].tokens) == 6 for i in ids[1:])
    assert not eng._skips                                  # bound resets


def test_preemption_liveness_and_parity(gpt2):
    """Two requests whose combined page growth exceeds the pool: one is
    preempted mid-decode (pages freed, request requeued with its generated
    prefix) and both finish with the same tokens as solo runs."""
    cfg, model, params = gpt2
    mk = lambda: Engine(model, params, "*=w8c", max_slots=2, max_seq=32,
                        paged=True, page_size=8, n_pages=6)  # 5 allocatable
    reqs = [([5, 6, 7, 8, 9, 10, 11], 12), ([1, 2, 3], 14)]
    solo = [_tokens(mk(), [p], n)[0] for p, n in reqs]

    eng = mk()
    ids = [eng.submit(Request(tokens=list(p), max_new_tokens=n))
           for p, n in reqs]
    by_id = {r.request_id: r for r in eng.run()}
    assert [by_id[i].tokens for i in ids] == solo
    assert eng.pool.live_pages == 0


def test_prefix_sharing_refcounts_and_parity(gpt2):
    """cache_prefix pins full prefix pages once; requests sharing the prefix
    alias them (refcount, no copy) and still match the dense engine."""
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    prefix = [42, 17, 3, 99, 5, 21, 8, 13]                 # exactly one page
    prompts = [prefix + [60, 61, 62], prefix + [70]]

    paged = Engine(model, params, pol, max_slots=2, max_seq=32, paged=True,
                   page_size=8)
    assert paged.cache_prefix(prefix) == 1
    pids = paged._prefixes[tuple(prefix)]
    assert paged.pool.live_pages == 1
    base = int(paged.pool.refcount[pids[0]])               # alloc ref + pin
    assert base == 2

    dense = Engine(model, params, pol, max_slots=2, max_seq=32)
    assert _tokens(paged, prompts, 6) == _tokens(dense, prompts, 6)
    # pinned prefix survives request teardown, ready for the next tenant
    assert paged.pool.live_pages == 1
    assert int(paged.pool.refcount[pids[0]]) == base


# ---------------------------------------------------------------------------
# Paged decode kernel vs reference
# ---------------------------------------------------------------------------

def test_paged_kernel_matches_paged_ref():
    from repro.kernels.decode_attn import (decode_attention,
                                           decode_attention_paged)
    from repro.kernels.ref import (decode_attn_inputs, decode_attn_paged_ref,
                                   paged_from_dense)
    b, s, kh, g, hd, page = 3, 32, 2, 2, 32, 8
    # pos < s: the engine never decodes a full slot (prompt <= max_seq-1 and
    # decode stops at capacity); pos == s scatter-clamp semantics are pinned
    # by test_decode_attn.test_pos_at_max_seq_clamps_scatter
    lengths = [5, 17, 31]
    (q, kq, ks, vq, vs, _, _, new_k, new_v, pos) = decode_attn_inputs(
        b, s, kh, g, hd, lengths, seed=3)
    kqp, ksp, vqp, vsp, table = paged_from_dense(kq, ks, vq, vs, lengths,
                                                 page, seed=11)
    ref_ctx, (rkq, rks, rvq, rvs) = decode_attn_paged_ref(
        q, kqp, ksp, vqp, vsp, new_k, new_v, pos, table)
    ctx, okq, oks, ovq, ovs = decode_attention_paged(
        q, kqp, ksp, vqp, vsp, new_k, new_v, pos, table, interpret=True)
    assert jnp.allclose(ctx, ref_ctx, atol=1e-5), float(
        jnp.max(jnp.abs(ctx - ref_ctx)))
    # the fused scatter writes the identical quantized rows, everywhere
    for got, want in ((okq, rkq), (oks, rks), (ovq, rvq), (ovs, rvs)):
        assert jnp.array_equal(got, want)
    # page indirection only relocates rows: the paged kernel's context is
    # BITWISE equal to the dense kernel on the same logical cache
    dense_ctx, *_ = decode_attention(q, kq, ks, vq, vs, new_k, new_v, pos,
                                     block_k=page, interpret=True)
    assert jnp.array_equal(ctx, dense_ctx)


# ---------------------------------------------------------------------------
# Async scheduler
# ---------------------------------------------------------------------------

def test_scheduler_async_start_wait_stop(gpt2):
    """Background-loop mode: submissions land while the loop runs, results
    arrive via events, latency stats are finite -- and the tokens match a
    synchronous dense run (greedy decode is arrival-invariant)."""
    cfg, model, params = gpt2
    pol = "kv_cache=a8t,*=w8c"
    dense = Engine(model, params, pol, max_slots=2, max_seq=32)
    ref = _tokens(dense, PROMPTS[:3], 5)

    paged = Engine(model, params, pol, max_slots=2, max_seq=32, paged=True,
                   page_size=8)
    sched = paged.scheduler
    sched.start()
    try:
        ids = [paged.submit(Request(tokens=list(p), max_new_tokens=5))
               for p in PROMPTS[:3]]
        sched.wait(ids, timeout=300)
    finally:
        sched.stop()
    out = [sched.result(i) for i in ids]
    assert [r.tokens for r in out] == ref
    assert all(r.text is None for r in out)                # no detokenizer
    stats = sched.latency_stats()
    assert stats["n"] == 3
    assert 0 < stats["p50_s"] <= stats["p99_s"] < float("inf")
    assert sched.peak_live_bytes > 0


def test_scheduler_detokenizer_emits_text(gpt2):
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=16, paged=True,
                 page_size=4,
                 detokenizer=lambda toks: "|".join(map(str, toks)))
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    [r] = eng.run()
    assert r.text == "|".join(map(str, r.tokens))


# ---------------------------------------------------------------------------
# Scheduler robustness: per-request deadlines + the dead-loop watchdog
# ---------------------------------------------------------------------------

def test_running_request_timeout_frees_slot(gpt2):
    """A request whose deadline expires mid-decode is cancelled through the
    normal finish path: reason "timeout", partial tokens kept, slot freed
    so the engine is immediately reusable."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=256)
    rid = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=200,
                             timeout_s=0.01))
    [r] = eng.run()
    assert r.request_id == rid
    assert r.finish_reason == "timeout"
    assert len(r.tokens) < 200                    # cut off, not completed
    assert not eng._running and len(eng._free) == 1
    assert eng.scheduler.timeouts == 1
    # the engine is healthy afterwards: a normal request completes
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=3))
    [r2] = eng.run()
    assert r2.finish_reason == "length" and len(r2.tokens) == 3


def test_queued_request_timeout_cancelled_before_admission(gpt2):
    """With every slot busy, a queued request past its deadline is removed
    by the sweep before it is ever admitted (no tokens generated)."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=256)
    r1 = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=64))
    r2 = eng.submit(Request(tokens=[4, 5, 6], max_new_tokens=64,
                            timeout_s=0.01))
    by_id = {r.request_id: r for r in eng.run()}
    assert by_id[r1].finish_reason == "length"
    assert by_id[r2].finish_reason == "timeout"
    assert by_id[r2].tokens == []                 # never ran
    assert eng.scheduler.timeouts == 1


def test_timeout_on_paged_engine_frees_pages(gpt2):
    cfg, model, params = gpt2
    eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=2,
                 max_seq=64, paged=True, page_size=8)
    free0 = eng.pool.free_pages
    eng.submit(Request(tokens=list(range(1, 20)), max_new_tokens=40,
                       timeout_s=0.01))
    [r] = eng.run()
    assert r.finish_reason == "timeout"
    assert eng.pool.free_pages == free0           # every page returned


def test_dead_scheduler_loop_wakes_waiters(gpt2):
    """If the background scheduling thread dies, blocked wait() callers are
    woken by the watchdog and re-raise the loop's exception; stop()
    re-raises it too.  Without the watchdog both would hang."""
    from repro.train import FaultInjected, FaultPlan

    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=64)
    sched = eng.scheduler
    plan = FaultPlan.parse("dead_sched@2")
    sched.fault_hook = plan.scheduler_hook()
    sched.start()
    rid = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=50))
    with pytest.raises(FaultInjected):
        sched.wait([rid], timeout=60)
    with pytest.raises(FaultInjected):
        sched.stop()
    assert plan.fired == ["dead_sched@2"]
