"""Overload resilience: admission control / load shedding, the serving
degradation ladder, serving fault injection, and the deadline/watchdog
edges around them.

Companion to ``tests/test_pages.py`` (scheduler deadlines + dead-loop
watchdog) and ``benchmarks/resilience.py`` (the e2e scenario gate); this
module pins the unit-level contracts: shed is a first-class outcome (never
an exception escaping the loop), the ladder walks and re-engages exactly
as scripted, quarantine isolates one row, and the monitor's window
arithmetic."""
import time

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.infer import Engine, EngineMonitor, MonitorConfig, Request
from repro.models import build_model
from repro.train import FaultPlan

import jax


def _setup():
    cfg = get_smoke_config("gpt2-small")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def gpt2():
    return _setup()


# ---------------------------------------------------------------------------
# Admission control / load shedding
# ---------------------------------------------------------------------------

def test_shed_on_bounded_queue(gpt2):
    """Submissions past ``max_queue`` are rejected at submit time: finish
    reason "shed", retry-after hint, zero tokens -- and the admitted
    requests are unaffected."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=32, max_queue=2)
    ids = [eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=3))
           for _ in range(5)]
    by_id = {r.request_id: r for r in eng.run()}
    reasons = [by_id[i].finish_reason for i in ids]
    assert reasons == ["length", "length", "shed", "shed", "shed"]
    for i in ids[2:]:
        assert by_id[i].retry_after_s is not None
        assert by_id[i].retry_after_s > 0
        assert by_id[i].tokens == []
        assert by_id[i].prompt == [1, 2, 3]
    assert [len(by_id[i].tokens) for i in ids[:2]] == [3, 3]
    stats = eng.scheduler.latency_stats()
    assert stats["shed"] == 3 and stats["completed"] == 2
    assert stats["n"] == 2           # shed excluded from latency percentiles


def test_max_queue_zero_sheds_everything(gpt2):
    """Degenerate bound: every submission sheds; the cold-engine retry
    hint falls back to the 50ms floor (no step history to estimate from)."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=16, max_queue=0)
    eng.submit(Request(tokens=[1, 2], max_new_tokens=2))
    [r] = eng.run()
    assert r.finish_reason == "shed"
    # 50ms/step floor x 1 (idle depth) x 2 budgeted tokens
    assert r.retry_after_s == pytest.approx(0.1)


def test_idle_inadmissible_sheds_not_raises(gpt2):
    """A queued request the (prefix-pinned) pool can never admit used to
    raise CapacityError out of the scheduling loop; now it is shed after
    the patience window, and the loop keeps running."""
    cfg, model, params = gpt2
    eng = Engine(model, params, "*=w8c", max_slots=2, max_seq=48,
                 paged=True, page_size=8, n_pages=6)      # 5 allocatable
    prefix = list(range(1, 33))                           # pins 4 pages
    eng.cache_prefix(prefix)
    assert eng.pool.free_pages == 1
    # passes submit validation (2 pages <= 5 total) but can never admit
    # against the 1 remaining free page
    eng.submit(Request(tokens=[60, 61, 62, 63, 64, 65, 66, 67],
                       max_new_tokens=8))
    [r] = eng.run()                                       # must not raise
    assert r.finish_reason == "shed"
    assert r.retry_after_s is not None
    assert eng.pool.free_pages == 1                       # nothing leaked


def test_shed_vs_timeout_precedence(gpt2):
    """Same inadmissible setup with a deadline armed: the timeout sweep
    runs first, so the outcome is "timeout", never "shed"."""
    cfg, model, params = gpt2
    eng = Engine(model, params, "*=w8c", max_slots=2, max_seq=48,
                 paged=True, page_size=8, n_pages=6)
    eng.cache_prefix(list(range(1, 33)))
    eng.submit(Request(tokens=[60, 61, 62, 63, 64, 65, 66, 67],
                       max_new_tokens=8, timeout_s=0.01))
    [r] = eng.run()
    assert r.finish_reason == "timeout"
    assert eng.scheduler.timeouts == 1
    assert eng.scheduler.latency_stats()["shed"] == 0


def test_deadline_aware_shed(gpt2):
    """A queued request whose decode-step estimate cannot make its deadline
    is shed immediately instead of burning pages until the timeout sweep."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=64)
    # seed the rolling estimate: 1s/step makes any multi-token budget
    # hopeless against a 2s deadline
    for _ in range(8):
        eng.monitor.record_step(1000.0)
    r1 = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=30))
    r2 = eng.submit(Request(tokens=[4, 5, 6], max_new_tokens=30,
                            timeout_s=2.0))
    by_id = {r.request_id: r for r in eng.run()}
    assert by_id[r2].finish_reason == "shed"
    assert by_id[r2].retry_after_s is not None
    assert by_id[r1].finish_reason == "length"
    assert len(by_id[r1].tokens) == 30
    assert eng.scheduler.timeouts == 0


def test_mixed_queued_running_timeout_sweep(gpt2):
    """One sweep cancels expired deadlines in both states: the running
    request and the queued ones behind it (slots=1) all finish "timeout"
    -- expired deadlines outrank the shed estimate."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=256)
    ids = [eng.submit(Request(tokens=[i + 1, i + 2], max_new_tokens=200,
                              timeout_s=0.05)) for i in range(3)]
    by_id = {r.request_id: r for r in eng.run()}
    assert [by_id[i].finish_reason for i in ids] == ["timeout"] * 3
    assert eng.scheduler.timeouts == 3
    assert eng.scheduler.latency_stats()["shed"] == 0
    assert not eng._running and not eng._queue


# ---------------------------------------------------------------------------
# Scheduler loop edges
# ---------------------------------------------------------------------------

def test_start_twice_is_noop(gpt2):
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=16)
    sched = eng.scheduler
    sched.start()
    t1 = sched._loop_thread
    sched.start()
    assert sched._loop_thread is t1
    sched.stop()


def test_wait_races_timeout_cancellation(gpt2):
    """wait() blocked on a request that the deadline sweep cancels must
    wake with the "timeout" response, not TimeoutError or a hang."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=256)
    sched = eng.scheduler
    sched.start()
    try:
        rid = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=200,
                                 timeout_s=0.05))
        sched.wait([rid], timeout=120)
        r = sched.result(rid)
        assert r.finish_reason == "timeout"
    finally:
        sched.stop()


def test_stop_raises_on_hung_loop(gpt2):
    """stop() must not masquerade a wedged loop thread as a clean
    shutdown: a decode step stuck past the join timeout raises
    RuntimeError (the old behaviour returned silently)."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=64)
    eng.generate(np.asarray([[1, 2, 3]]), 2)      # compile outside the race
    # the decode-step counter is cumulative; pin the fault to the next step
    spec = f"slow_step@{eng._decode_steps}:ms=1500"
    plan = FaultPlan.parse(spec)
    eng.fault_hooks = plan.engine_hooks()
    sched = eng.scheduler
    sched.start()
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    time.sleep(0.4)                               # loop is inside the sleep
    t = sched._loop_thread
    with pytest.raises(RuntimeError, match="failed to join"):
        sched.stop(join_timeout_s=0.2)
    t.join(timeout=30)                            # drains after the fault
    assert not t.is_alive()
    assert plan.fired == [spec]


# ---------------------------------------------------------------------------
# Quarantine + degradation ladder
# ---------------------------------------------------------------------------

def test_quarantine_isolates_row(gpt2):
    """A non-finite logits row evicts only that request; its batchmate's
    greedy tokens are bit-identical to a clean solo run."""
    cfg, model, params = gpt2
    clean = Engine(model, params, max_slots=2, max_seq=32)
    clean.submit(Request(tokens=[4, 5, 6], max_new_tokens=8))
    [oracle] = clean.run()

    eng = Engine(model, params, max_slots=2, max_seq=32)
    plan = FaultPlan.parse("nan_logit@2:slot=0")
    eng.fault_hooks = plan.engine_hooks()
    victim = eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=8))
    other = eng.submit(Request(tokens=[4, 5, 6], max_new_tokens=8))
    by_id = {r.request_id: r for r in eng.run()}
    assert by_id[victim].finish_reason == "numerics"
    assert 0 < len(by_id[victim].tokens) < 8
    assert by_id[other].finish_reason == "length"
    assert by_id[other].tokens == oracle.tokens   # batch-invariance survives
    s = eng.resilience_summary()
    assert s["quarantined"] == 1 and s["rung_index"] == 0
    assert not eng._running and len(eng._free) == 2


def test_ladder_demote_and_reengage(gpt2):
    """A kernel error demotes one rung (dequant -> fp on a dense int8-KV
    engine); the healthy streak re-probes back up; the request finishes."""
    cfg, model, params = gpt2
    eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=1,
                 max_seq=32, monitor=MonitorConfig(reprobe_after=2))
    assert eng._rungs == ["dequant", "fp"]
    plan = FaultPlan.parse("kernel_error@1")
    eng.fault_hooks = plan.engine_hooks()
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=8))
    [r] = eng.run()
    assert r.finish_reason == "length" and len(r.tokens) == 8
    s = eng.resilience_summary()
    assert s["kernel_errors"] == 1
    assert [(d["step"], d["from"], d["to"]) for d in s["demotions"]] \
        == [(1, "dequant", "fp")]
    assert [(p["from"], p["to"]) for p in s["promotions"]] \
        == [("fp", "dequant")]
    assert s["rung"] == "dequant" and s["rung_index"] == 0
    assert "degraded" not in eng.path_summary()
    assert plan.fired == ["kernel_error@1"]


def test_fp_rung_roundtrip_serves_correctly(gpt2):
    """Forcing the engine onto the fp reference rung (dequantized caches)
    and back (requantized) leaves it serving correctly either way, and
    path_summary reports the degraded rung only while degraded."""
    cfg, model, params = gpt2
    eng = Engine(model, params, "kv_cache=a8t,*=w8c", max_slots=1,
                 max_seq=32)
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    [r0] = eng.run()
    assert r0.finish_reason == "length"

    assert eng._demote("test-forced", step=0)
    assert eng._rungs[eng._rung] == "fp"
    assert "degraded=fp" in eng.path_summary()
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    [r1] = eng.run()
    assert r1.finish_reason == "length" and len(r1.tokens) == 4

    assert eng._try_promote(step=0)
    assert eng._rung == 0
    assert "degraded" not in eng.path_summary()
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    [r2] = eng.run()
    assert r2.finish_reason == "length" and len(r2.tokens) == 4


def test_bottom_rung_reraises(gpt2):
    """A decode-step failure on the last rung has nowhere to go: the
    exception propagates (absorbed only while a lower rung exists)."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=1, max_seq=16)   # fp-only engine
    assert eng._rungs == ["fp"]
    plan = FaultPlan.parse("kernel_error@1")
    eng.fault_hooks = plan.engine_hooks()
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    from repro.train import FaultInjected
    with pytest.raises(FaultInjected):
        eng.run()


def test_oom_fault_preempts_and_recovers(gpt2):
    """An injected page-pool drain forces preemption, never CapacityError;
    the held pages come back and every request completes."""
    cfg, model, params = gpt2
    eng = Engine(model, params, max_slots=2, max_seq=64, paged=True,
                 page_size=4, n_pages=6)
    plan = FaultPlan.parse("oom_pages@1:hold=2")
    eng.fault_hooks = plan.engine_hooks()
    free0 = eng.pool.free_pages
    ids = [eng.submit(Request(tokens=[1, 2, 3, 4], max_new_tokens=10)),
           eng.submit(Request(tokens=[5, 6, 7, 8], max_new_tokens=10))]
    by_id = {r.request_id: r for r in eng.run()}
    assert all(by_id[i].finish_reason == "length"
               and len(by_id[i].tokens) == 10 for i in ids)
    assert eng.preemptions >= 1
    assert eng.pool.free_pages == free0
    assert plan.fired == ["oom_pages@1:hold=2"]


# ---------------------------------------------------------------------------
# Fault grammar + monitor arithmetic
# ---------------------------------------------------------------------------

def test_engine_fault_grammar():
    plan = FaultPlan.parse(
        "nan_logit@2:slot=1;oom_pages@3:hold=4;slow_step@1:ms=5;"
        "kernel_error@6")
    assert [f.kind for f in plan.faults] == \
        ["nan_logit", "oom_pages", "slow_step", "kernel_error"]
    assert plan.engine_hooks() is not None
    # plans without serving kinds keep the engine hook-free
    assert FaultPlan.parse("nan_grad@3").engine_hooks() is None
    assert FaultPlan.parse(None).engine_hooks() is None
    with pytest.raises(ValueError):
        FaultPlan.parse("nan_logits@2")            # unknown kind


def test_mangle_finite_is_one_shot_and_copies():
    plan = FaultPlan.parse("nan_logit@2:slot=1")
    hooks = plan.engine_hooks()
    finite = np.ones(4, bool)
    out = hooks.mangle_finite(2, finite)
    assert not out[1] and finite[1]                # input not mutated
    assert plan.fired == ["nan_logit@2:slot=1"]
    again = hooks.mangle_finite(2, np.ones(4, bool))
    assert again.all()                             # one-shot


def test_monitor_window_and_reprobe():
    m = EngineMonitor(MonitorConfig(numeric_window=4, numeric_limit=2,
                                    reprobe_after=3))
    m.record_quarantine(1)
    assert not m.should_demote(1)
    m.record_quarantine(3)
    assert m.should_demote(3)                      # 2 inside the window
    m.record_demotion(3, "fused", "dequant", "test")
    # quarantines at/before the transition no longer count
    assert not m.should_demote(4)
    m.record_quarantine(10)                        # outside window of 3
    assert not m.should_demote(10)
    assert m.mean_step_s() is None
    for _ in range(3):
        m.record_step(10.0)
    assert m.should_reprobe()
    assert m.mean_step_s() == pytest.approx(0.01)
    m.record_promotion(12, "dequant", "fused")
    assert m.healthy_streak == 0                   # re-earn the streak
    s = m.summary()
    assert s["quarantined"] == 3
    assert s["demotions"][0]["why"] == "test"
    assert s["step_ms"]["n"] == 3
