"""Mamba2/SSD correctness: chunked == sequential oracle; streaming decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.ssm import (init_ssm_state, ssd_chunked, ssd_reference,
                              ssm_apply, ssm_decode_step, ssm_spec)
from repro.models.common import init_from_spec

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("chunk", [16, 64, 128])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_chunked_vs_reference(chunk, groups):
    b, s, h, p, n = 2, 256, 4, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, groups, n))
    cm = jax.random.normal(ks[4], (b, s, groups, n))
    y1, f1 = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    y2, f2 = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=3e-3, atol=3e-3)


def test_ssd_initial_state_carried():
    b, s, h, p, n = 1, 64, 2, 8, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, 2 * s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, 2 * s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, 2 * s, 1, n))
    cm = jax.random.normal(ks[4], (b, 2 * s, 1, n))
    # full pass vs two halves with carried state
    y_full, f_full = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y1, f1 = ssd_chunked(x[:, :s], dt[:, :s], a, bm[:, :s], cm[:, :s],
                         chunk=16)
    y2, f2 = ssd_chunked(x[:, s:], dt[:, s:], a, bm[:, s:], cm[:, s:],
                         init_state=f1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full),
                               rtol=3e-3, atol=3e-3)


def test_layer_decode_streaming_equals_full():
    """Running the full layer one token at a time == full-sequence apply."""
    cfg = get_smoke_config("mamba2-130m")
    params = init_from_spec(KEY, ssm_spec(cfg))
    b, s = 2, 12
    u = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.5

    full, _ = ssm_apply(params, u, cfg, policy=None, rules=None)

    state = init_ssm_state(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        y, state = ssm_decode_step(params, u[:, t:t + 1], cfg, policy=None,
                                   rules=None, state=state)
        outs.append(y)
    streamed = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(streamed), np.asarray(full),
                               rtol=5e-2, atol=5e-2)
