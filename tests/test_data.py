"""Data pipeline: determinism, sharding, resumability, learnability."""
import numpy as np

from repro.configs import get_smoke_config
from repro.data import Loader, SyntheticCorpus


def test_deterministic_by_step():
    c = SyntheticCorpus(256, seed=11)
    a = c.batch(5, 0, 4, batch_size=4, seq_len=32)
    b = c.batch(5, 0, 4, batch_size=4, seq_len=32)
    np.testing.assert_array_equal(a, b)


def test_shards_differ():
    c = SyntheticCorpus(256, seed=11)
    a = c.batch(5, 0, 4, batch_size=4, seq_len=32)
    b = c.batch(5, 1, 4, batch_size=4, seq_len=32)
    assert (a != b).any()


def test_splits_disjoint_streams():
    c = SyntheticCorpus(256, seed=11)
    a = c.batch(0, 0, 1, batch_size=2, seq_len=32, split="train")
    b = c.batch(0, 0, 1, batch_size=2, seq_len=32, split="valid")
    assert (a != b).any()


def test_loader_state_resume():
    cfg = get_smoke_config("llama3-8b")
    c = SyntheticCorpus(cfg.vocab_size, seed=3)
    l1 = Loader(c, cfg, batch_size=4, seq_len=16)
    for _ in range(3):
        next(l1)
    st = l1.state_dict()
    want = next(l1)

    l2 = Loader(c, cfg, batch_size=4, seq_len=16)
    l2.load_state_dict(st)
    got = next(l2)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_family_batches_match_model_inputs():
    for arch in ("paligemma-3b", "seamless-m4t-medium", "llama3-8b"):
        cfg = get_smoke_config(arch)
        c = SyntheticCorpus(cfg.vocab_size, seed=3)
        loader = Loader(c, cfg, batch_size=2, seq_len=32)
        b = next(loader)
        if cfg.family == "vlm":
            assert b["patches"].shape == (2, cfg.num_patches, cfg.d_model)
            assert b["tokens"].shape == (2, 32 - cfg.num_patches + 1)
        elif cfg.family == "encdec":
            assert b["frames"].shape == (2, 8, cfg.d_model)
            assert b["tokens"].shape == (2, 33)
        else:
            assert b["tokens"].shape == (2, 33)
        assert b["tokens"].max() < cfg.vocab_size


def test_corpus_learnable():
    c = SyntheticCorpus(256, seed=11)
    floor = c.entropy_floor()
    assert 0.1 < floor < np.log(256) * 0.7   # far below uniform entropy
